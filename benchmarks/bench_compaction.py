"""Slot compaction vs the monolithic batched loop (DESIGN.md §7).

The paper's per-sample step sizes make batched sampling finish raggedly;
the monolithic while_loop keeps the whole batch stepping until the
slowest sample converges, so finished samples burn score-network FLOPs
as frozen passengers. This bench drives the *same* horizon-chunked
device step through the ``DiffusionBatcher`` under both turnover
disciplines:

  * ``monolithic``  — ``compaction=False``: the batch only turns over
    when every occupied slot has converged (the paper's "wait for all
    images" loop);
  * ``compaction``  — ``compaction=True``: converged slots retire and
    refill from the queue at every sync horizon.

Traffic is a timed trickle: a wave of ``max(1, round(o·slots))``
requests is released every ~one mean service time, where ``o`` is the
occupancy level (1.0 = saturating, 0.1 = light). Metrics per mode:

  * ``passenger_nfe`` — frozen-passenger waste: the fraction of
    evaluations issued to *occupied* slots whose sample had already
    converged. This is the acceptance gate (≥1.5× lower with compaction
    at o=0.1): it is the waste only slot turnover discipline can remove.
  * ``wasted_nfe``   — total waste including never-occupied idle slots.
    Idle capacity is a provisioning question — both disciplines pay it
    identically at light traffic — reported for transparency.
  * wall-clock and samples/s.

Low sample dimension on purpose: the ℓ2 scaled error concentrates at
high d (paper Sec. 3.1.3; the repo's dimensionality bench quantifies
it), so the per-sample NFE spread — the raggedness compaction exploits —
is widest in the low-d regime (iters ≈ 70–125 at d=2 vs ±8% at d=64).

  PYTHONPATH=src python -m benchmarks.bench_compaction [--slots 16]
"""

from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import emit
from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
DIM = 2
WAVES = 5
WAVE_GAP_ITERS = 100  # ≈ one mean service time at eps_rel=0.05, d=2
SYNC_HORIZON = 4
OCCUPANCIES = (1.0, 0.5, 0.1)


def _make_step(sde, cfg):
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # signature holder; forward_fn wins
    return make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))


def _run(sde, cfg, step, slots: int, occupancy: float, compaction: bool):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(DIM,),
                         slots=slots, cfg=cfg, sync_horizon=SYNC_HORIZON,
                         compaction=compaction)
    # compile this batcher's jitted chunk outside the timed region (an
    # all-idle carry makes the chunk a no-op, so state is unchanged)
    b._carry = b.step_fn(b.params, b._carry)
    wave_size = max(1, round(occupancy * slots))
    n_total = WAVES * wave_size
    uid = 0
    released = 0
    t0 = time.perf_counter()
    while len(b.finished) < n_total:
        # timed arrivals: wave w is released WAVE_GAP_ITERS·w device
        # iterations into the run (time advances only while work runs,
        # so an idle batch skips straight to the next wave)
        while released < WAVES and (
            b.total_iterations >= released * WAVE_GAP_ITERS
            or (not b.queue and all(r is None for r in b._slot_req))
        ):
            for _ in range(wave_size):
                b.submit(ImageRequest(uid=uid, seed=uid))
                uid += 1
            released += 1
        if b.step() == 0:
            b._sync()
    dt = time.perf_counter() - t0
    assert len(b.finished) == n_total
    return {
        "passenger": b.passenger_nfe_fraction,
        "wasted": b.wasted_nfe_fraction,
        "iters": b.total_iterations,
        "wall_s": dt,
        "sps": n_total / dt,
    }


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags (--only ...) never leak
    # into this parser; direct invocation passes sys.argv[1:] below
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=16)
    args = ap.parse_args(argv)
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    step = _make_step(sde, cfg)

    for occ in OCCUPANCIES:
        mono = _run(sde, cfg, step, args.slots, occ, compaction=False)
        comp = _run(sde, cfg, step, args.slots, occ, compaction=True)
        ratio = mono["passenger"] / max(comp["passenger"], 1e-9)
        for mode, r in (("monolithic", mono), ("compaction", comp)):
            emit(
                f"compaction/occ{occ}/{mode}",
                r["wall_s"] * 1e6,
                f"passenger_nfe={r['passenger']:.3f};"
                f"wasted_nfe={r['wasted']:.3f};iters={r['iters']};"
                f"samples_per_s={r['sps']:.2f}",
            )
        emit(f"compaction/occ{occ}/ratio", 0.0,
             f"passenger_nfe_mono_over_comp={ratio:.2f}x")


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
