"""Device-resident serve loop vs the host-driven chunk chain (DESIGN.md §12).

The host-driven ``DiffusionBatcher`` loop pays O(sync horizons) device→host
round-trips per solve: every horizon pulls the (B,) convergence mask (plus
the iteration counter) even when nothing converged. The device-resident
mode folds retirement polling, compaction, and admission into donated
on-device programs and reads back one scalar event flag per driver call —
host traffic becomes O(delivered requests).

Per sync horizon this bench drains the same request wave through both
modes and reports:

  * ``host_transfers_per_request`` — every ``jax.device_get`` the serve
    loop issued, divided by delivered requests. The acceptance gate from
    the issue: ≥5× lower device-resident at sync_horizon ≤ 8.
  * steady-state ``samples_per_s`` wall-clock, comparable against the
    ``compaction`` suite's numbers (same analytic-score workload family).

The second section times the fused solver-step kernel on the
*trajectory-shaped* rows the planning server feeds it — (H=16, D=6) and
(H=32, D=8) states flatten to 96/256 features, far below the default
512-lane block — comparing the auto-widened batch block
(``kernel._blocks_for``) against the legacy fixed (8, bd) tile.

  PYTHONPATH=src python -m benchmarks.bench_device_serving [--slots 8]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.kernels.solver_step import kernel as _k
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
DIM = 2          # low-d: widest per-sample NFE spread (cf. bench_compaction)
REQUESTS_PER_SLOT = 3
SYNC_HORIZONS = (1, 4, 8)


def _make_step(sde, cfg):
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # signature holder; forward_fn wins
    return make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))


def _run(sde, cfg, step, slots: int, sync_horizon: int,
         device_resident: bool):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(DIM,),
                         slots=slots, cfg=cfg, sync_horizon=sync_horizon,
                         device_resident=device_resident)
    # warmup drain: compiles this batcher's driver/event programs (their
    # jit caches are per-instance closures) outside the timed region
    for uid in range(slots):
        b.submit(ImageRequest(uid=10_000 + uid, seed=10_000 + uid))
    b.run_to_completion()
    t_before, w_before, i_before = (
        b.host_transfers, b.horizon_windows, b.total_iterations)
    n_total = REQUESTS_PER_SLOT * slots
    for uid in range(n_total):
        b.submit(ImageRequest(uid=uid, seed=uid))
    t0 = time.perf_counter()
    done = b.run_to_completion()
    dt = time.perf_counter() - t0
    assert len(done) == slots + n_total
    transfers = b.host_transfers - t_before
    return {
        "transfers": transfers,
        "per_req": transfers / n_total,
        "windows": b.horizon_windows - w_before,
        "iters": b.total_iterations - i_before,
        "wall_s": dt,
        "sps": n_total / dt,
    }


def _bench_serving(slots: int) -> None:
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    step = _make_step(sde, cfg)
    for horizon in SYNC_HORIZONS:
        host = _run(sde, cfg, step, slots, horizon, device_resident=False)
        dev = _run(sde, cfg, step, slots, horizon, device_resident=True)
        ratio = host["per_req"] / max(dev["per_req"], 1e-9)
        for mode, r in (("host", host), ("device", dev)):
            emit(
                f"device_serving/h{horizon}/{mode}",
                r["wall_s"] * 1e6,
                f"host_transfers_per_request={r['per_req']:.2f};"
                f"transfers={r['transfers']};windows={r['windows']};"
                f"iters={r['iters']};samples_per_s={r['sps']:.2f}",
            )
        emit(f"device_serving/h{horizon}/ratio", 0.0,
             f"host_transfers_host_over_device={ratio:.1f}x")


def _time_error_step(B: int, D: int, reps: int, **blocks) -> float:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    mk = lambda k: jax.random.normal(k, (B, D), jnp.float32)
    x, xp, s2, z, xv = (mk(k) for k in ks[:5])
    e0, d1, d2 = (jax.random.normal(k, (B,), jnp.float32) for k in ks[5:])
    fn = functools.partial(
        _k.error_step, eps_abs=0.01, eps_rel=0.05, use_prev=True,
        interpret=jax.default_backend() == "cpu", **blocks,
    )
    out = fn(x, xp, s2, z, xv, e0, d1, d2)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(x, xp, s2, z, xv, e0, d1, d2)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _bench_trajectory_blocks(reps: int = 10) -> None:
    """Auto-widened vs legacy tile on trajectory-shaped (B, flat) rows.

    Shapes mirror the planning server's carries: (H=16, D=6) → 96 flat
    features (lane-padded to 128) and (H=32, D=8) → 256. Passing an
    explicit ``block_d`` equal to the padded width reproduces the legacy
    fixed (8, bd) tile — same bd as the auto path, so the measured gap
    isolates the widened batch block (fewer grid programs per call).
    """
    for name, flat in (("traj16x6", 96), ("traj32x8", 256)):
        B, Dpad = 64, -(-flat // 128) * 128
        legacy = _time_error_step(B, Dpad, reps, block_d=Dpad)
        tuned = _time_error_step(B, Dpad, reps)
        bb_t, _ = _k._blocks_for(jnp.float32, B, Dpad,
                                 _k.DEFAULT_BLOCK_B, _k.DEFAULT_BLOCK_D)
        emit(
            f"device_serving/kernel/{name}",
            tuned * 1e6,
            f"legacy_us={legacy * 1e6:.1f};block_b={bb_t};"
            f"speedup={legacy / max(tuned, 1e-12):.2f}x",
        )


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags never leak into this parser
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args(argv)
    _bench_serving(args.slots)
    _bench_trajectory_blocks()


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
