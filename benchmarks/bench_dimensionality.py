"""Beyond-paper: rejection rate and NFE vs. state dimensionality.

The paper reports its solver 'rarely rejects'. We found that claim is a
concentration effect of the dimension-normalized ℓ2 error: the same
algorithm rejects ~40% of proposals at d=2 and ~1–2% at d=3072. This
bench quantifies that curve (exact Gaussian scores isolate the solver).
"""

from __future__ import annotations

import jax

from repro.core import VESDE, VPSDE, sample
from .common import emit, timed

MU, S0 = 0.3, 0.5


def main() -> None:
    for process, sde in (("vp", VPSDE()), ("ve", VESDE(sigma_max=50.0))):

        def score(x, t):
            m, std = sde.marginal(t)
            m, std = m[:, None], std[:, None]
            return -(x - m * MU) / (m * m * S0 * S0 + std * std)

        for d in (2, 16, 64, 256, 1024, 3072, 12288):
            fn = jax.jit(
                lambda k: sample(sde, score, (32, d), k, method="adaptive",
                                 eps_rel=0.05)
            )
            us, res = timed(fn, jax.random.PRNGKey(0))
            tot = float((res.accepted + res.rejected).sum())
            rej = float(res.rejected.sum()) / max(tot, 1.0)
            emit(
                f"dimensionality/{process}/d{d}", us,
                f"nfe={float(res.mean_nfe):.0f};rej_frac={rej:.3f};"
                f"iters={int(res.iterations)}",
            )


if __name__ == "__main__":
    main()
