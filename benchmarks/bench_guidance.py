"""NFE overhead of the conditioning seam (DESIGN.md §9).

Controlled generation must not tax the paper's headline economy: the
adaptive controller spends 2 NFE per step, and neither classifier-free
guidance (a score-field transform) nor inpainting/colorization
(post-accept projection) should provoke many extra rejections. This
bench solves the analytic OU process unconditionally and under each
conditioner at the same tolerance and reports per-mode mean NFE,
wall-clock, and the NFE ratio against unconditional.

Two shape groups:

  * the **conformance shape** (B, 8) — the gate rows: the same OU
    setting ``tests/test_solver_conformance.py`` gates at ratio ≤ 1.1×;
  * an **image shape** (B, 8, 8, 3) — informational: the projection's
    fresh per-step re-noising of the observed region partially undoes
    the high-dimensional concentration of the scaled-ℓ2 error (paper
    Sec. 3.1.3), so the inpaint/colorize overhead grows with observed
    fraction × dimension (measured ~1.25–1.4× here vs ~1.05× at the
    conformance shape). See DESIGN.md §9.

Note CFG's ratio counts *score-field* evaluations (the solver's NFE
accounting); each guided evaluation internally runs one doubled
(2B-row) network forward, a throughput cost the ``derived`` column
reports separately as ``fwd_rows_x``.

  PYTHONPATH=src python -m benchmarks.bench_guidance [--batch 256]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import AdaptiveConfig, VPSDE, class_conditional, colorize, inpaint, sample
from repro.core.analytic import class_gaussian_score, gaussian_score
from repro.core.guidance import to_gray

MU, S0 = 0.3, 0.5
CONF_DIM = 8           # the conformance suite's vector shape
IMG_SHAPE = (8, 8, 3)  # informational image rows (colorize needs channels)
EPS_REL = 0.05
GATE = 1.1


def _timed_solve(score, shape, key, conditioner, cond):
    cfg = AdaptiveConfig(eps_rel=EPS_REL, conditioner=conditioner)
    fn = jax.jit(lambda k: sample(VPSDE(), score, shape, k,
                                  method="adaptive", config=cfg, cond=cond))
    res = fn(key)  # compile + warm
    jax.block_until_ready(res.x)
    t0 = time.perf_counter()
    res = fn(key)
    jax.block_until_ready(res.x)
    return res, (time.perf_counter() - t0) * 1e6


def _emit_group(tag, modes, shape, key, gate: bool):
    base_nfe = None
    for name, (score, conditioner, cond, fwd_rows) in modes.items():
        res, us = _timed_solve(score, shape, key, conditioner, cond)
        nfe = float(res.mean_nfe)
        if base_nfe is None:
            base_nfe = nfe
        ratio = nfe / base_nfe
        verdict = (
            f"gate_le_{GATE}x={'pass' if ratio <= GATE else 'FAIL'}"
            if gate else "gate=n/a"
        )
        emit(
            f"guidance/{tag}/{name}",
            us,
            f"mean_nfe={nfe:.1f};nfe_ratio={ratio:.3f}x;"
            f"fwd_rows_x={fwd_rows:.0f};{verdict}",
        )


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags (--only ...) never leak
    # into this parser; direct invocation passes sys.argv[1:] below
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args(argv)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    uncond = gaussian_score(sde, MU, S0)

    # gate rows: the conformance shape, bound the suite enforces
    vshape = (args.batch, CONF_DIM)
    vref = MU + S0 * jax.random.normal(jax.random.PRNGKey(1), vshape)
    vmask = jnp.zeros(vshape).at[:, : CONF_DIM // 2].set(1.0)
    _emit_group("conformance", {
        "unconditional": (uncond, None, None, 1.0),
        "inpaint": (uncond, *inpaint(vmask, vref), 1.0),
        "cfg": (
            class_gaussian_score(sde, jnp.linspace(-1, 1, 10), S0, MU),
            *class_conditional(jnp.arange(args.batch) % 10, 1.5),
            2.0,  # guided evals run one 2B-row forward
        ),
    }, vshape, key, gate=True)

    # informational rows: image shape, where projection de-concentrates
    # the ℓ2 error and the overhead grows with the observed fraction
    ishape = (args.batch,) + IMG_SHAPE
    iref = MU + S0 * jax.random.normal(jax.random.PRNGKey(2), ishape)
    imask = jnp.zeros(ishape).at[:, : IMG_SHAPE[0] // 2].set(1.0)
    _emit_group("image", {
        "unconditional": (uncond, None, None, 1.0),
        "inpaint": (uncond, *inpaint(imask, iref), 1.0),
        "colorize": (uncond, *colorize(to_gray(iref)), 1.0),
    }, ishape, key, gate=False)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
