"""Kernel micro-benchmarks: fused solver step vs. unfused jnp, flash vs.
reference attention, fused GroupNorm→SiLU vs. the jnp chain, chunked
SSD vs. sequential scan.

CPU wall-times here validate plumbing only (the TPU picture comes from
the dry-run roofline); the derived column carries the modeled HBM-pass
count — the quantity the fusion actually optimizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.groupnorm_silu import ops as gs_ops
from repro.kernels.groupnorm_silu import ref as gs_ref
from repro.kernels.solver_step import ops as ss_ops
from repro.kernels.solver_step import ref as ss_ref
from repro.kernels.ssd import ref as ssd_ref
from .common import emit, timed


def main() -> None:
    key = jax.random.PRNGKey(0)

    # --- solver step (B=64, D=3072: CIFAR batch) -------------------------
    B, D = 64, 3072
    ks = jax.random.split(key, 8)
    x, xp, s2, z, xv = (jax.random.normal(k, (B, D)) for k in ks[:5])
    e0, d1, d2 = (jax.random.uniform(k, (B,)) for k in ks[5:])
    kw = dict(eps_abs=0.0078, eps_rel=0.05)

    fused = jax.jit(lambda *a: ss_ops.error_step(*a, **kw))
    unfused = jax.jit(lambda *a: ss_ref.error_step(*a, **kw))
    us_f, _ = timed(fused, x, xp, s2, z, xv, e0, d1, d2, repeats=5)
    us_u, _ = timed(unfused, x, xp, s2, z, xv, e0, d1, d2, repeats=5)
    # unfused: ~6 reads + 2 writes of (B,D); fused: 5 reads + 1 write.
    emit("kernels/solver_step/fused", us_f, "hbm_passes=6")
    emit("kernels/solver_step/jnp", us_u, "hbm_passes=8")

    # --- flash attention (S=512) -----------------------------------------
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k_ = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    flash = jax.jit(lambda q, k, v: fa_ops.attention(q, k, v, causal=True))
    refat = jax.jit(lambda q, k, v: fa_ref.attention(q, k, v, causal=True))
    us_f, _ = timed(flash, q, k_, v, repeats=3)
    us_r, _ = timed(refat, q, k_, v, repeats=3)
    emit("kernels/flash_attention/pallas-interpret", us_f,
         "vmem_tiles=128x128")
    emit("kernels/flash_attention/jnp-ref", us_r, "materializes_SxS=1")

    # --- fused GroupNorm→SiLU (B=64, H=32, C=128: traj bottleneck) -------
    Bg, Hg, Cg, G = 64, 32, 128, 8
    kg = jax.random.split(jax.random.PRNGKey(7), 3)
    xg = jax.random.normal(kg[0], (Bg, Hg, Cg))
    sc = 1.0 + 0.1 * jax.random.normal(kg[1], (Cg,))
    bi = 0.1 * jax.random.normal(kg[2], (Cg,))
    fusedg = jax.jit(lambda x, s, b: gs_ops.groupnorm_silu(x, s, b, groups=G))
    unfg = jax.jit(lambda x, s, b: gs_ref.groupnorm_silu(x, s, b, groups=G))
    us_f, _ = timed(fusedg, xg, sc, bi, repeats=5)
    us_u, _ = timed(unfg, xg, sc, bi, repeats=5)
    # unfused chain: read for stats, read for normalize, write norm,
    # read+write SiLU; fused: one read, one write.
    emit("kernels/groupnorm_silu/fused", us_f, "hbm_passes=2")
    emit("kernels/groupnorm_silu/jnp", us_u, "hbm_passes=5")

    # --- SSD (S=2048) ------------------------------------------------------
    Bm, S, H, P, G, N = 2, 2048, 4, 64, 1, 64
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (Bm, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bmat = jax.random.normal(ks[3], (Bm, S, G, N))
    C = jax.random.normal(ks[4], (Bm, S, G, N))

    chunked = jax.jit(lambda *a: ssd_ref.ssd_chunked(*a, chunk=128))
    us_c, _ = timed(chunked, xs, dt, A, Bmat, C, repeats=3)

    def seq(xs, dt, A, Bmat, C):
        y, _ = ssd_ref.ssd_scan(
            jnp.transpose(xs, (0, 2, 1, 3)), jnp.transpose(dt, (0, 2, 1)), A,
            jnp.transpose(Bmat, (0, 2, 1, 3)), jnp.transpose(C, (0, 2, 1, 3)),
        )
        return y

    seqj = jax.jit(seq)
    us_s, _ = timed(seqj, xs, dt, A, Bmat, C, repeats=3)
    emit("kernels/ssd/chunked", us_c, f"depth=log({S // 128})")
    emit("kernels/ssd/sequential", us_s, f"depth={S}")


if __name__ == "__main__":
    main()
