"""Trajectory-diffusion planning benchmark (DESIGN.md §10).

Two groups:

  * **trajectory shapes** — the paper's headline economy on the third
    workload: adaptive-solver NFE and wall-clock vs Euler–Maruyama on
    analytic OU trajectory priors at several (horizon, transition)
    shapes, with the *same* default tolerances as the image workload
    (eps_rel = 0.05, sde-calibrated ε_abs — no per-workload tuning).
    Gate: adaptive reaches EM-1000's error level (W2 vs the analytic
    marginal, + MC floor) at strictly lower NFE — the same claim
    ``tests/test_solver_conformance.py`` gates on the conformance and
    trajectory rows.
  * **planner-loop occupancy sweep** — the closed receding-horizon
    loop (state-pinning conditioner aboard, DESIGN.md §10) through the
    ``DiffusionBatcher`` at several envs-per-slot occupancies,
    reporting plans/s, mean NFE, and the §7 waste accounting that slot
    compaction keeps low while requests re-admit every control round.

  PYTHONPATH=src python -m benchmarks.bench_planning [--batch 128]
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.observability.quality import dynamics_consistency
from repro.core import VPSDE, sample
from repro.core.analytic import (
    class_gaussian_noise_pred, gaussian_marginal_moments, gaussian_score,
    gaussian_w2,
)
from repro.planning import OUEnv, PlannerConfig, RecedingHorizonPlanner

MU, S0 = 0.3, 0.5
EPS_REL = 0.05         # the image workload's default — no retuning
EM_STEPS = 1000        # the paper's equal-error EM baseline
TRAJ_SHAPES = [(16, 6), (32, 8)]
RETURNS_BINS = 5


def _solve(sde, score, shape, key, method, kw):
    fn = jax.jit(lambda k: sample(sde, score, shape, k, method=method,
                                  denoise=False, **kw))
    res = fn(key)  # compile + warm
    jax.block_until_ready(res.x)
    t0 = time.perf_counter()
    res = fn(key)
    jax.block_until_ready(res.x)
    return res, (time.perf_counter() - t0) * 1e6


def bench_trajectory_shapes(batch: int) -> None:
    sde = VPSDE()
    score = gaussian_score(sde, MU, S0)
    mu_a, s_a = gaussian_marginal_moments(sde, MU, S0)
    key = jax.random.PRNGKey(0)
    for H, D in TRAJ_SHAPES:
        shape = (batch, H, D)
        mc_floor = 3.0 * s_a / math.sqrt(batch * H * D)
        res_em, us_em = _solve(sde, score, shape, key, "em",
                               dict(n_steps=EM_STEPS))
        res_ad, us_ad = _solve(sde, score, shape, key, "adaptive",
                               dict(eps_rel=EPS_REL))
        w2 = {}
        for name, res in [("em", res_em), ("adaptive", res_ad)]:
            x = res.x
            w2[name] = gaussian_w2(float(x.mean()), float(x.std()),
                                   mu_a, s_a)
        equal_err = w2["adaptive"] <= w2["em"] + 2 * mc_floor + 0.02
        fewer = float(res_ad.mean_nfe) < float(res_em.mean_nfe)
        emit(
            f"planning/traj_H{H}xD{D}/em{EM_STEPS}", us_em,
            f"mean_nfe={float(res_em.mean_nfe):.0f};w2={w2['em']:.4f}",
        )
        emit(
            f"planning/traj_H{H}xD{D}/adaptive", us_ad,
            f"mean_nfe={float(res_ad.mean_nfe):.0f};"
            f"w2={w2['adaptive']:.4f};"
            f"nfe_ratio={float(res_ad.mean_nfe) / float(res_em.mean_nfe):.3f}x;"
            f"gate_lower_nfe_at_equal_error="
            f"{'pass' if equal_err and fewer else 'FAIL'}",
        )


def bench_planner_occupancy(slots: int = 8, steps: int = 2) -> None:
    sde = VPSDE()
    env = OUEnv(obs_dim=2)
    pcfg = PlannerConfig(horizon=8, obs_dim=env.obs_dim,
                         act_dim=env.act_dim, guidance_scale=1.5)
    fwd = class_gaussian_noise_pred(
        sde, MU + 0.5 * jax.numpy.linspace(-1.0, 1.0, RETURNS_BINS), S0, MU)
    for n_envs in (slots, slots // 2, max(1, slots // 4)):
        rh = RecedingHorizonPlanner(sde, fwd, None, pcfg, env,
                                    slots=slots, sync_horizon=4)
        t0 = time.perf_counter()
        out = rh.rollout(jax.random.PRNGKey(1), n_envs=n_envs,
                         n_steps=steps, returns_label=RETURNS_BINS - 1)
        us = (time.perf_counter() - t0) * 1e6
        n_plans = n_envs * steps
        # quality-proxy gauge (DESIGN.md §15): RMS env-step residual of
        # the delivered plans — how far each plan's next-state rows sit
        # from the OU mean transition; solver regressions push it up
        plans = np.stack([np.asarray(r.result)
                          for r in out["finished"].values()])
        dyn = dynamics_consistency(env, plans, obs_dim=env.obs_dim,
                                   act_dim=env.act_dim)
        emit(
            f"planning/loop_occ{n_envs / slots:.2f}", us / n_plans,
            f"plans={n_plans};mean_nfe={float(out['nfe'].mean()):.0f};"
            f"mean_reward={float(out['rewards'].mean()):.3f};"
            f"wasted_nfe={out['wasted_nfe_fraction']:.3f};"
            f"passenger_nfe={out['passenger_nfe_fraction']:.3f};"
            f"dyn_consistency={dyn:.3f}",
        )


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags (--only ...) never leak
    # into this parser; direct invocation passes sys.argv[1:] below
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args(argv)
    bench_trajectory_shapes(args.batch)
    bench_planner_occupancy()


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
