"""Precision-policy benchmark: fp32 vs bf16 vs bf16_full (DESIGN.md §8).

Two workloads, each run under every preset:

  * **analytic OU conformance** — the exact-Gaussian setting of
    ``tests/test_solver_conformance.py``: x0 ~ N(MU, S0²) under VP, so
    the marginal mean/std at t_eps are known in closed form and the
    marginal-moment error of each preset is measured against an exact
    reference, not against another sampler;
  * **small DiT end-to-end** — a randomly-initialized DiT score net
    sampled with the adaptive solver, timing the full solve so the
    bf16 casts sit exactly where they would in production (the CPU CI
    host has no bf16 matmul units, so wall-clock parity — not speedup —
    is the expectation here; the artifact records the numbers that
    matter everywhere: NFE, iterations, moment drift).

Every row reports mean NFE, wall-clock, and the marginal-moment error;
the gate the conformance suite enforces (bf16 moment error ≤ 2× fp32,
mean NFE ≤ 1.25× fp32) is recomputed here and written to the artifact
``experiments/precision/bench_precision.json``.

CSV: ``precision_<workload>_<preset>,us_per_call,nfe=..|w2=..|...``
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import VPSDE, AdaptiveConfig, sample
from repro.core.analytic import (
    gaussian_marginal_moments, gaussian_score, gaussian_w2,
)
from repro.core.precision import PRESETS, resolve_policy
from repro.models.dit import DiTConfig, init_dit, make_score_fn

from .common import emit, timed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "experiments", "precision")

MU, S0 = 0.3, 0.5
OU_SHAPE = (512, 8)
DIT_SHAPE = (16, 16, 16, 3)


def _moments(x) -> tuple:
    # fp32 upcast first: a bf16 state dtype must not leak reduction
    # error into the measurement
    xf = jnp.asarray(x, jnp.float32)
    return float(jnp.mean(xf)), float(jnp.std(xf))


def bench_ou(preset: str) -> dict:
    sde = VPSDE()
    score = gaussian_score(sde, MU, S0)
    cfg = AdaptiveConfig(eps_rel=0.05, precision=preset)
    fn = jax.jit(lambda k: sample(sde, score, OU_SHAPE, k,
                                  method="adaptive", config=cfg))
    us, res = timed(fn, jax.random.PRNGKey(0), repeats=3)
    mu_a, s_a = gaussian_marginal_moments(sde, MU, S0)
    mu, s = _moments(res.x)
    return {
        "workload": "ou", "preset": preset, "us_per_call": us,
        "mean_nfe": float(res.mean_nfe), "iterations": int(res.iterations),
        "mean_err": abs(mu - mu_a), "std_err": abs(s - s_a),
        "w2": gaussian_w2(mu, s, mu_a, s_a),
    }


def bench_dit(preset: str) -> dict:
    net = DiTConfig(image_size=16, patch=4, d_model=64, num_layers=2,
                    num_heads=4, d_ff=128)
    sde = VPSDE()
    policy = resolve_policy(preset)
    params = init_dit(net, jax.random.PRNGKey(0))
    score = make_score_fn(params, net, sde, policy=policy)
    cfg = AdaptiveConfig(eps_rel=0.05, precision=preset)
    fn = jax.jit(lambda k: sample(sde, score, DIT_SHAPE, k,
                                  method="adaptive", config=cfg))
    us, res = timed(fn, jax.random.PRNGKey(1), repeats=3)
    mu, s = _moments(res.x)
    return {
        "workload": "dit", "preset": preset, "us_per_call": us,
        "mean_nfe": float(res.mean_nfe), "iterations": int(res.iterations),
        "sample_mean": mu, "sample_std": s,
    }


def main() -> None:
    rows = []
    for preset in sorted(PRESETS):
        for bench in (bench_ou, bench_dit):
            r = bench(preset)
            rows.append(r)
            derived = "|".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in r.items()
                if k not in ("workload", "preset", "us_per_call")
            )
            emit(f"precision_{r['workload']}_{preset}", r["us_per_call"], derived)

    by = {(r["workload"], r["preset"]): r for r in rows}
    ref = by[("ou", "fp32")]
    dit_ref = by[("dit", "fp32")]
    gates = {}
    for preset in ("bf16", "bf16_full"):
        r = by[("ou", preset)]
        d = by[("dit", preset)]
        gates[preset] = {
            # the conformance suite's gate, recomputed on the bench run
            "w2_vs_fp32": r["w2"] / max(ref["w2"], 1e-9),
            "moment_error_le_2x_fp32": bool(r["w2"] <= 2.0 * ref["w2"] + 1e-3),
            "nfe_vs_fp32": r["mean_nfe"] / ref["mean_nfe"],
            "nfe_le_1p25x_fp32": bool(r["mean_nfe"] <= 1.25 * ref["mean_nfe"]),
            "dit_moment_drift": abs(d["sample_std"] - dit_ref["sample_std"]),
        }
        emit(
            f"precision_gate_{preset}", 0.0,
            f"w2x={gates[preset]['w2_vs_fp32']:.3f}"
            f"|nfex={gates[preset]['nfe_vs_fp32']:.3f}"
            f"|pass={gates[preset]['moment_error_le_2x_fp32'] and gates[preset]['nfe_le_1p25x_fp32']}",
        )

    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "bench_precision.json"), "w") as f:
        json.dump({"rows": rows, "gates": gates}, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
