"""Score-eval roofline bench (DESIGN.md §13): per-NFE forward cost.

The adaptive solver's wall-clock is NFE × score-eval time — every speed
lever in this repo either cuts NFE (the paper's contribution) or cuts
the per-NFE forward cost (the hot-path kernels). This bench measures
the second factor directly: one jitted score-network forward per row,
so ``us_per_call`` IS the per-NFE wall time at that batch.

Workloads × variants:

  * ``dit_cifar`` — the CIFAR-scale DiT (``configs.diffusion.CIFAR_DIT``,
    64 tokens, d_model 256); baseline = reference attention,
    fast = ``use_flash=True`` through the public attention owner.
  * ``unet_traj16x6`` / ``unet_traj32x8`` — the temporal UNet at the two
    trajectory shapes the serving benches use (horizon 16 × transition 6
    and 32 × 8), with the bottleneck attention block enabled; baseline =
    jnp attention + unfused GroupNorm→SiLU, fast = ``use_flash=True`` +
    ``use_fused_norm=True``.

Both variants of a workload share ONE param tree (the zero-init leaves —
``conv2``/``conv_out``/attention ``wo`` — are perturbed first, otherwise
the parity numbers compare kernels on activations that never reach
them), so the fast-vs-baseline parity in the derived column is a real
numerics check, per precision preset.

FLOPs/bytes per NFE come from the baseline variant's AOT
``compiled.cost_analysis()`` (via ``repro.analysis.hlo.summarize_cost``)
— the model cost, not the kernel implementation's, so "achieved FLOP/s"
is speed-of-light-normalized for both variants. The roofline join
(``repro.analysis.roofline.score_eval_markdown``) turns the artifact
into the compute-vs-memory-bound table CI publishes.

On CPU the Pallas kernels run in interpreter mode: wall-times validate
plumbing only and the speedup column is suppressed (parity is the
payload, per the kernel-bench convention). On an accelerator the same
artifact reports measured fast-vs-baseline speedup and achieved
fraction-of-peak.

CSV: ``score_eval_<workload>_<preset>_<variant>,us_per_call,derived``.
Artifact: ``experiments/score_eval/BENCH_score_eval.json`` (+
``ROOFLINE.md``, the rendered join).
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import summarize_cost
from repro.observability.quality import proxy_fid
from repro.analysis.roofline import score_eval_markdown
from repro.configs.diffusion import CIFAR_DIT
from repro.core.precision import resolve_policy
from repro.models.dit import dit_forward, init_dit
from repro.models.temporal_unet import (
    TemporalUNetConfig, init_temporal_unet, temporal_unet_forward,
)

from .common import emit, timed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "experiments", "score_eval")

PRESETS = ("fp32", "bf16")
#: fast-vs-baseline max|Δ| / max|baseline| bound per preset; fp32 flash
#: and the fused norm are near-exact, bf16 adds one-vs-two rounding of
#: the GroupNorm→SiLU chain plus bf16 attention accumulate differences
PARITY_RTOL = {"fp32": 1e-3, "bf16": 8e-2}

DIT_BATCH = 8
UNET_BATCH = 16

# the two trajectory shapes the serving/planning benches exercise
TRAJ16 = TemporalUNetConfig(horizon=16, transition_dim=6, base=32,
                            mults=(1, 2), t_dim=32, groups=8,
                            attention=True, attn_heads=4)
TRAJ32 = TemporalUNetConfig(horizon=32, transition_dim=8, base=32,
                            mults=(1, 2, 4), t_dim=64, groups=8,
                            attention=True, attn_heads=4)


def _liven_unet(params, key):
    """Perturb the zero-init leaves so every branch carries signal.

    A fresh temporal UNet has zero-init ``conv2``/``conv_out``/attention
    ``wo`` (the bitwise-neutrality guardrails); benchmarking a net whose
    forward is identically zero would make every parity check pass
    vacuously.
    """
    ks = iter(jax.random.split(key, 64))
    bump = lambda w: 0.02 * jax.random.normal(next(ks), w.shape, w.dtype)
    blocks = ([d["res"] for d in params["downs"]]
              + [params["mid1"], params["mid2"]]
              + [u["res"] for u in params["ups"]])
    for blk in blocks:
        blk["conv2"] = bump(blk["conv2"])
    params["conv_out"] = bump(params["conv_out"])
    params["attn"]["wo"] = bump(params["attn"]["wo"])
    return params


def _dit_workload():
    cfg0 = CIFAR_DIT
    cfg1 = dataclasses.replace(cfg0, use_flash=True)
    params = init_dit(cfg0, jax.random.PRNGKey(0))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (DIT_BATCH, cfg0.image_size, cfg0.image_size, cfg0.channels))
    t = jnp.linspace(0.1, 1.0, DIT_BATCH)

    def make(cfg, policy):
        p = policy.cast_params(params)
        return jax.jit(lambda x, t: dit_forward(p, x, t, cfg, policy=policy))

    return "dit_cifar", make, (cfg0, cfg1), (x, t), DIT_BATCH


def _unet_workload(name, cfg1):
    cfg0 = dataclasses.replace(cfg1, use_flash=False, use_fused_norm=False)
    fast = dataclasses.replace(cfg1, use_flash=True, use_fused_norm=True)
    params = _liven_unet(init_temporal_unet(cfg1, jax.random.PRNGKey(0)),
                         jax.random.PRNGKey(2))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (UNET_BATCH, cfg1.horizon, cfg1.transition_dim))
    t = jnp.linspace(0.1, 1.0, UNET_BATCH)

    def make(cfg, policy):
        p = policy.cast_params(params)
        return jax.jit(
            lambda x, t: temporal_unet_forward(p, x, t, cfg, policy=policy))

    return name, make, (cfg0, fast), (x, t), UNET_BATCH


def main() -> None:
    on_cpu = jax.default_backend() == "cpu"
    workloads = [
        _dit_workload(),
        _unet_workload("unet_traj16x6", TRAJ16),
        _unet_workload("unet_traj32x8", TRAJ32),
    ]

    rows = []
    for wname, make, (cfg0, cfg1), args, batch in workloads:
        for preset in PRESETS:
            policy = resolve_policy(preset)
            base = make(cfg0, policy)
            fast = make(cfg1, policy)
            us_b, out_b = timed(base, *args, repeats=2)
            us_f, out_f = timed(fast, *args, repeats=2)

            # model cost per NFE from the baseline path's AOT analysis
            cost = summarize_cost(base.lower(*args).compile().cost_analysis())
            flops = cost.get("flops", 0.0)
            byts = cost.get("bytes_accessed", 0.0)

            a = jnp.asarray(out_b, jnp.float32)
            b = jnp.asarray(out_f, jnp.float32)
            scale = float(jnp.max(jnp.abs(a)))
            diff = float(jnp.max(jnp.abs(a - b)))
            ok = diff <= PARITY_RTOL[preset] * max(scale, 1e-3)
            # quality-proxy gauge (DESIGN.md §15): distributional drift
            # between the two variants' outputs under the fixed
            # random-projection extractor — a max|Δ| parity can stay
            # inside rtol while the output *distribution* shifts; this
            # catches that failure mode. dim=8 keeps the fitted moments
            # sane at these small bench batches.
            pfid = proxy_fid(np.asarray(a), np.asarray(b), dim=8, seed=0)

            common = {
                "workload": wname, "preset": preset, "batch": batch,
                "backend": jax.default_backend(),
                "flops_per_nfe": flops, "bytes_per_nfe": byts,
            }
            rows.append({**common, "variant": "baseline",
                         "us_per_call": us_b})
            fast_row = {**common, "variant": "fast", "us_per_call": us_f,
                        "parity_max_abs": diff, "parity_scale": scale,
                        "parity_pass": bool(ok), "proxy_fid": pfid}
            if not on_cpu:
                fast_row["speedup"] = us_b / us_f
            rows.append(fast_row)

            derived = (f"gflops_nfe={flops / 1e9:.2f}"
                       f"|parity={diff:.2e}|pass={ok}"
                       f"|proxy_fid={pfid:.2e}")
            if not on_cpu:
                derived += f"|speedup={us_b / us_f:.2f}x"
            emit(f"score_eval_{wname}_{preset}_baseline", us_b,
                 f"gflops_nfe={flops / 1e9:.2f}")
            emit(f"score_eval_{wname}_{preset}_fast", us_f, derived)

    artifact = {
        "backend": jax.default_backend(),
        "interpret_mode": on_cpu,
        "note": ("CPU wall-times validate plumbing only (Pallas runs in "
                 "interpreter mode); parity is the payload. Accelerator "
                 "runs add measured speedup + achieved fraction-of-peak."),
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "BENCH_score_eval.json"), "w") as f:
        json.dump(artifact, f, indent=1, sort_keys=True)
    with open(os.path.join(OUT_DIR, "ROOFLINE.md"), "w") as f:
        f.write(score_eval_markdown(artifact) + "\n")


if __name__ == "__main__":
    main()
