"""Serving micro-benchmark: reduced-arch decode throughput per family.

One representative reduced config per architecture family exercises the
full serve path (embed → scanned blocks → KV/SSM state → head → argmax).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import init_decode_state, init_model
from .common import emit, timed

ARCHS = ["olmo-1b", "gemma3-12b", "mamba2-2.7b", "deepseek-moe-16b",
         "jamba-v0.1-52b", "musicgen-medium"]


def main() -> None:
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = get_config(arch).scaled_down()
        params = init_model(cfg, key)
        B = 4
        state = init_decode_state(cfg, B, cache_len=64)
        shape = (B, 1) if cfg.num_codebooks == 1 else (B, 1, cfg.num_codebooks)
        tok = jax.random.randint(key, shape, 0, cfg.vocab_size)
        batch = {"tokens": tok}
        if cfg.vision_dim:
            batch["cross_embeds"] = jax.random.normal(
                key, (B, cfg.num_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
            )
        step = jax.jit(make_serve_step(cfg))
        us, (nt, state) = timed(step, params, batch, state, repeats=10)
        emit(f"serving/{arch}-reduced", us,
             f"tok_per_s={B / (us / 1e6):.0f};batch={B}")


if __name__ == "__main__":
    main()
