"""Sharded-sampling scaling: samples/sec for 1 vs N fake host devices.

Captures the data-parallel scaling axis of ``sample(..., mesh=...)``
(DESIGN.md §3) in the ``name,us_per_call,derived`` CSV the perf
trajectory tracks. Device counts are faked with
``xla_force_host_platform_device_count`` — on a CPU host the shards
share the same cores, so absolute samples/sec is NOT expected to scale;
what this captures is the overhead of the sharded program (partitioned
prior draw, constrained while-loop carry, shard_map'd fused kernel)
relative to the single-device run, and it becomes a true scaling curve
the moment it runs on real accelerators.

Each device count runs in a subprocess (device count locks at jax init).

  PYTHONPATH=src python -m benchmarks.bench_sharded_sampling [--devices 1,4]
"""

from __future__ import annotations

# Child mode must set XLA_FLAGS before jax initializes.
import os  # noqa: E402
import sys  # noqa: E402

if __name__ == "__main__" and "--child" in sys.argv:
    _n = sys.argv[sys.argv.index("--child") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import subprocess

BATCH = 64
DIM = 256
EPS_REL = 0.05


def _child(n_devices: int, use_fused: bool) -> None:
    import jax

    from benchmarks.common import emit, timed
    from repro.core import AdaptiveConfig, VPSDE, sample

    mu, s0 = 0.3, 0.5
    sde = VPSDE()

    def score(x, t):
        m, std = sde.marginal(t)
        m = m.reshape((-1, 1))
        std = std.reshape((-1, 1))
        return -(x - m * mu) / (m * m * s0 * s0 + std * std)

    mesh = jax.make_mesh((n_devices,), ("data",)) if n_devices > 1 else None
    cfg = AdaptiveConfig(eps_rel=EPS_REL, use_fused_kernel=use_fused)
    fn = jax.jit(
        lambda k: sample(sde, score, (BATCH, DIM), k, config=cfg, mesh=mesh)
    )
    us, res = timed(fn, jax.random.PRNGKey(0), repeats=3)
    sps = BATCH / (us / 1e6)
    tag = "fused" if use_fused else "jnp"
    emit(
        f"sharded_sampling/{tag}/dev{n_devices}", us,
        f"samples_per_sec={sps:.1f};batch={BATCH};mean_nfe={float(res.mean_nfe):.0f}",
    )


def main(device_counts=(1, 4)) -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    for n in device_counts:
        for fused in (False, True):
            cmd = [sys.executable, "-m", "benchmarks.bench_sharded_sampling",
                   "--child", str(n)]
            if fused:
                cmd.append("--fused")
            r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                               timeout=560, cwd=root)
            if r.returncode != 0:
                print(f"# sharded_sampling dev{n} fused={fused} FAILED: "
                      f"{r.stderr.strip().splitlines()[-1:]}", file=sys.stderr)
                continue
            for line in r.stdout.strip().splitlines():
                if line.startswith("sharded_sampling/"):
                    print(line)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", type=int, default=None,
                    help="(internal) run one measurement on N fake devices")
    ap.add_argument("--fused", action="store_true")
    ap.add_argument("--devices", default="1,4",
                    help="comma-separated device counts for the sweep")
    args = ap.parse_args()
    if args.child is not None:
        _child(args.child, args.fused)
    else:
        main(tuple(int(x) for x in args.devices.split(",")))
