"""Race the whole solver zoo and write the auto-selection report.

Every registered solver runs its ``repro.analysis.solver_select.ZOO``
configuration on the four conformance workloads — {vp, ve} × {OU
vector, traj16x6 trajectory} — against the analytic Gaussian score, and
the per-workload ranking (best NFE at the W2 gate, DESIGN.md §11) is
written to ``experiments/conformance/selection.{md,json}`` exactly as
the conformance suite writes it, plus wall-clock timings the test suite
does not measure. CI's slow job publishes the report as a step summary
so a solver regression surfaces as a ranking diff.

  PYTHONPATH=src python -m benchmarks.bench_solver_zoo [--batch 512]
"""

from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import emit
from repro.analysis.solver_select import ZOO, select, write_selection
from repro.core import VESDE, VPSDE, available_solvers, sample
from repro.core.analytic import (
    gaussian_marginal_moments, gaussian_score, gaussian_w2,
)

MU, S0 = 0.3, 0.5
TRAJ_H, TRAJ_D = 16, 6  # the conformance suite's trajectory workload


def _workloads(batch):
    return [
        ("vp", VPSDE(), (batch, 8)),
        ("ve", VESDE(sigma_max=10.0), (batch, 8)),
        (f"vp:traj{TRAJ_H}x{TRAJ_D}", VPSDE(), (batch, TRAJ_H, TRAJ_D)),
        (f"ve:traj{TRAJ_H}x{TRAJ_D}", VESDE(sigma_max=10.0),
         (batch, TRAJ_H, TRAJ_D)),
    ]


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags (--only ...) never leak
    # into this parser; direct invocation passes sys.argv[1:] below
    ap = argparse.ArgumentParser()
    # batch matches the conformance suite's 512: the gates were calibrated
    # at that Monte-Carlo floor, and smaller batches can flip a marginal
    # pass (momentum on vp sits ~0.05 of the 0.08 gate at 512)
    ap.add_argument("--batch", type=int, default=512)
    args = ap.parse_args(argv)

    missing = set(available_solvers()) - set(ZOO)
    if missing:
        raise SystemExit(f"solvers missing a ZOO entry: {sorted(missing)}")

    rows = []
    for workload, sde, shape in _workloads(args.batch):
        score = gaussian_score(sde, MU, S0)
        mu_a, s_a = gaussian_marginal_moments(sde, MU, S0)
        for name, spec in ZOO.items():
            if spec.get("vp_only") and not workload.startswith("vp"):
                continue
            fn = jax.jit(
                lambda k, n=name, s=sde, sc=score, sh=shape: sample(
                    s, sc, sh, k, method=n, denoise=False,
                    **ZOO[n]["kwargs"],
                )
            )
            res = fn(jax.random.PRNGKey(0))  # compile + warm
            jax.block_until_ready(res.x)
            t0 = time.perf_counter()
            res = fn(jax.random.PRNGKey(0))
            jax.block_until_ready(res.x)
            us = (time.perf_counter() - t0) * 1e6
            mu, s = float(res.x.mean()), float(res.x.std())
            w2 = gaussian_w2(mu, s, mu_a, s_a)
            nfe = float(res.mean_nfe)
            rows.append({
                "solver": name, "sde": workload, "precision": "fp32",
                "mean_err": abs(mu - mu_a), "std_err": abs(s - s_a),
                "w2": w2, "mean_nfe": nfe, "tol": spec["tol"],
            })
            gate = "pass" if w2 < spec["tol"] else "FAIL"
            emit(
                f"solver_zoo/{workload}/{name}", us,
                f"w2={w2:.4f};mean_nfe={nfe:.0f};gate_{spec['tol']}={gate}",
            )

    report = select(rows)
    md_path, _ = write_selection(report)
    for workload, data in report.items():
        wn, an = data["winner_nfe"], data["adaptive_nfe"]
        ratio = f"{wn / an:.2f}" if (wn and an) else "nan"
        emit(
            f"solver_zoo/select/{workload}", 0.0,
            f"winner={data['winner']};winner_nfe={wn:.0f};"
            f"nfe_vs_adaptive={ratio}x",
        )
    print(f"# selection report: {md_path}")


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
