"""Mixed-traffic tolerance-tier serving (DESIGN.md §14).

Drives one tiered ``DiffusionBatcher`` with a mixed wave of draft /
standard / high_fidelity requests (the paper's Table-1 ε frontier as
serving classes) under EDF-within-priority-band admission, and reports
the per-class economics:

  * ``mean_nfe``   — per-class delivered NFE; the acceptance gate is
    draft ≤ 0.5× high_fidelity *in the same batch* (the paper's 2–10×
    NFE cut, realized per request rather than per deployment);
  * ``w2``         — per-class pooled W2 against the analytic OU
    marginal, each class gated at its own tier tolerance: the draft
    discount must not leak quality loss into the other classes;
  * ``deadline``   — per-class miss counters from the delivery stage;
  * a solo high-fidelity wave as baseline: per-slot tolerances mean the
    premium class pays the *same* NFE whether or not cheap traffic
    shares the batch (exact equality — trajectories are per-slot).

  PYTHONPATH=src python -m benchmarks.bench_tolerance_tiers [--slots 16]
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.diffusion import TOLERANCE_CLASSES
from repro.observability.quality import proxy_fid
from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import (
    gaussian_marginal_moments, gaussian_noise_pred, gaussian_w2,
)
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest
from repro.serving.scheduler import EdfPriorityAdmission

MU, S0 = 0.3, 0.5
DIM = 8
SYNC_HORIZON = 4
TIERS = ("draft", "standard", "high_fidelity")
#: per-class W2 gate: the tier's own ε is the quality knob it sold, so
#: each class must land within O(ε + MC floor) of the analytic marginal
W2_GATE_SCALE = 1.0


def _make_step(sde, cfg):
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # signature holder; forward_fn wins
    return make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))


def _make_batcher(sde, cfg, step, slots):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(DIM,),
                         slots=slots, cfg=cfg, sync_horizon=SYNC_HORIZON,
                         tolerance_classes=True,
                         admission=EdfPriorityAdmission(aging_s=5.0))
    # compile outside the timed region (all-idle carry ⇒ no-op chunk)
    b._carry = b.step_fn(b.params, b._carry)
    return b


def _drain(b, reqs):
    for r in reqs:
        b.submit(r)
    t0 = time.perf_counter()
    done = b.run_to_completion()
    return done, time.perf_counter() - t0


def _class_rows(done, tiers_by_uid):
    rows = {}
    for uid, req in done.items():
        rows.setdefault(tiers_by_uid[uid], []).append(req)
    return rows


def main(argv=()) -> None:
    # default () so benchmarks.run's own flags never leak into this parser
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--per-class", type=int, default=32)
    args = ap.parse_args(argv)

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    step = _make_step(sde, cfg)
    mu_a, s_a = gaussian_marginal_moments(sde, MU, S0)
    mc_floor = 3.0 * s_a / math.sqrt(args.per_class * DIM)

    # mixed wave: tiers interleaved so every sync horizon sees a mix,
    # draft requests on a generous deadline to exercise the counters
    reqs, tiers_by_uid = [], {}
    for i in range(args.per_class):
        for j, tier in enumerate(TIERS):
            uid = i * len(TIERS) + j
            tiers_by_uid[uid] = tier
            reqs.append(ImageRequest(
                uid=uid, seed=uid, tier=tier,
                deadline_ms=(120_000.0 if tier == "draft" else None)))

    b = _make_batcher(sde, cfg, step, args.slots)
    done, dt = _drain(b, reqs)
    assert len(done) == len(reqs)
    rows = _class_rows(done, tiers_by_uid)

    mean_nfe, w2 = {}, {}
    for tier in TIERS:
        rs = rows[tier]
        mean_nfe[tier] = sum(r.nfe for r in rs) / len(rs)
        xs = np.stack([np.asarray(r.result) for r in rs])
        w2[tier] = gaussian_w2(float(xs.mean()), float(xs.std()),
                               mu_a, s_a)
        # quality-proxy gauge (DESIGN.md §15): per-class proxy-FID
        # against reference draws from the analytic t_eps marginal —
        # unlike the pooled-moment W2 it sees the full feature
        # covariance, so a class whose samples collapse or skew while
        # keeping the right pooled mean/std still moves this number
        ref = mu_a + s_a * np.asarray(jax.random.normal(
            jax.random.PRNGKey(777 + TIERS.index(tier)),
            (args.per_class, DIM)))
        pfid = proxy_fid(ref, xs, dim=8, seed=0)
        stats = b.class_stats[tier]
        gate = W2_GATE_SCALE * TOLERANCE_CLASSES[tier].eps_rel + mc_floor
        emit(
            f"tolerance_tiers/mixed/{tier}",
            dt / len(done) * 1e6,
            f"mean_nfe={mean_nfe[tier]:.1f};w2={w2[tier]:.4f};"
            f"w2_gate={gate:.4f};compliant={int(w2[tier] <= gate)};"
            f"proxy_fid={pfid:.4f};"
            f"deadline_misses={stats['deadline_misses']};"
            f"delivered={stats['delivered']};"
            f"mean_wait_s={stats['mean_wait_s']:.3f}",
        )
        assert w2[tier] <= gate, (tier, w2[tier], gate)

    # acceptance gate: the draft discount is real, per batch
    ratio = mean_nfe["draft"] / mean_nfe["high_fidelity"]
    emit("tolerance_tiers/mixed/gate", 0.0,
         f"draft_over_hf_nfe={ratio:.3f};gate=0.5;"
         f"passed={int(ratio <= 0.5)}")
    assert ratio <= 0.5, (mean_nfe["draft"], mean_nfe["high_fidelity"])

    # solo high-fidelity baseline: premium NFE is invariant to the cheap
    # traffic sharing the batch (per-slot tolerance ⇒ exact equality)
    b_solo = _make_batcher(sde, cfg, step, args.slots)
    # reuse the mixed wave's seeds for the high_fidelity class so the
    # per-request comparison is exact, not statistical
    hf_uids = sorted(u for u, t in tiers_by_uid.items()
                     if t == "high_fidelity")
    solo_reqs = [ImageRequest(uid=i, seed=u, tier="high_fidelity")
                 for i, u in enumerate(hf_uids)]
    done_solo, _ = _drain(b_solo, solo_reqs)
    solo_nfe = {r.seed: r.nfe for r in done_solo.values()}
    mixed_nfe = {done[u].seed: done[u].nfe for u in hf_uids}
    exact = int(solo_nfe == mixed_nfe)
    emit("tolerance_tiers/solo_hf_baseline", 0.0,
         f"mean_nfe={sum(solo_nfe.values()) / len(solo_nfe):.1f};"
         f"mixed_equals_solo_per_request={exact}")
    assert exact, "high_fidelity NFE changed under mixed traffic"


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
