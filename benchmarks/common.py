"""Shared benchmark harness: trained score nets + quality metrics.

The paper scores solvers by FID against CIFAR/LSUN/FFHQ using 50k/5k
samples through Inception-v3. Offline substitutes (DESIGN.md §6):

  * quality metric — Fréchet distance computed on the *known* mean and
    covariance of the synthetic data distribution (the same statistic
    FID computes on Inception features, but with an exact reference);
    for the 2-D mixture we also report a sliced-Wasserstein distance.
  * score networks — small DiT/MLP nets trained here (cached across
    benchmark tables), plus analytic scores where exactness matters.

Every benchmark prints ``name,us_per_call,derived`` CSV rows, where
``derived`` packs the table's payload (NFE / quality / etc).
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VESDE, VPSDE, dsm_loss
from repro.data.images import GMM2D
from repro.models.score_unet import (
    MLPScoreConfig, init_mlp_score, mlp_score_forward,
)
from repro.optim import AdamW, ema_init, ema_params, ema_update

Array = jax.Array

GMM = GMM2D()  # 4-mode mixture, the benchmark data distribution


def frechet_gaussian(x: Array, y: Array) -> float:
    """Fréchet distance between Gaussian fits of two sample sets (the FID
    formula, on raw features): |μ1−μ2|² + tr(C1 + C2 − 2(C1 C2)^½)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    mu1, mu2 = x.mean(0), y.mean(0)
    c1 = np.cov(x, rowvar=False) + 1e-8 * np.eye(x.shape[1])
    c2 = np.cov(y, rowvar=False) + 1e-8 * np.eye(y.shape[1])
    # matrix sqrt of c1 c2 via eigendecomposition of the symmetrized product
    s1 = _sqrtm_psd(c1)
    inner = _sqrtm_psd(s1 @ c2 @ s1)
    return float(((mu1 - mu2) ** 2).sum() + np.trace(c1 + c2 - 2 * inner))


def _sqrtm_psd(a: np.ndarray) -> np.ndarray:
    w, v = np.linalg.eigh((a + a.T) / 2)
    w = np.clip(w, 0, None)
    return (v * np.sqrt(w)) @ v.T


def sliced_wasserstein(x: Array, y: Array, n_proj: int = 64, seed: int = 0) -> float:
    """Sliced W2 between two sample sets (exact in each 1-D projection)."""
    key = jax.random.PRNGKey(seed)
    d = x.shape[1]
    dirs = jax.random.normal(key, (n_proj, d))
    dirs = dirs / jnp.linalg.norm(dirs, axis=1, keepdims=True)
    n = min(x.shape[0], y.shape[0])
    px = jnp.sort(x[:n] @ dirs.T, axis=0)
    py = jnp.sort(y[:n] @ dirs.T, axis=0)
    return float(jnp.sqrt(jnp.mean((px - py) ** 2)))


@functools.lru_cache(maxsize=4)
def trained_mlp_score(process: str, steps: int = 600, seed: int = 0):
    """Train (and cache) an MLP score net on the 4-mode GMM for VE or VP."""
    sde = VPSDE() if process == "vp" else VESDE(sigma_max=12.0)
    cfg = MLPScoreConfig(dim=2, hidden=128, depth=3)
    key = jax.random.PRNGKey(seed)
    params = init_mlp_score(cfg, key)
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    ema = ema_init(params)

    def apply_fn(p, x, t):
        _, std = sde.marginal(t)
        return mlp_score_forward(p, x, t, cfg) / std[:, None]

    @jax.jit
    def step(params, opt_state, ema, key):
        key, kd, kl = jax.random.split(key, 3)
        x0 = GMM.sample(kd, 512)
        loss, grads = jax.value_and_grad(
            lambda p: dsm_loss(sde, apply_fn, p, x0, kl)
        )(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, ema_update(ema, params, 0.995), key, loss

    for _ in range(steps):
        params, opt_state, ema, key, _ = step(params, opt_state, ema, key)
    final = ema_params(ema, params)

    def score_fn(x, t):
        return apply_fn(final, x, t)

    return sde, score_fn


def timed(fn: Callable, *args, repeats: int = 1) -> Tuple[float, object]:
    """us/call of a jitted callable (first call excluded = compile)."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
