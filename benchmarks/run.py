"""Benchmark entry point. One function per paper table + framework
benches. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only table1,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    bench_compaction,
    bench_device_serving,
    bench_dimensionality,
    bench_guidance,
    bench_kernels,
    bench_planning,
    bench_precision,
    bench_score_eval,
    bench_serving,
    bench_sharded_sampling,
    bench_solver_zoo,
    bench_tolerance_tiers,
    table1_solver_grid,
    table2_highdim,
    table3_offtheshelf,
    table45_ablations,
)

SUITES = {
    "table1": table1_solver_grid.main,     # paper Table 1 (+IS table analog)
    "table2": table2_highdim.main,         # paper Table 2
    "table3": table3_offtheshelf.main,     # paper Table 3 / App. A
    "table45": table45_ablations.main,     # paper Tables 4-5 / App. B
    "dimensionality": bench_dimensionality.main,  # beyond-paper
    "kernels": bench_kernels.main,
    "serving": bench_serving.main,
    "sharded_sampling": bench_sharded_sampling.main,  # 1-vs-N device scaling
    "compaction": bench_compaction.main,   # slot compaction vs monolithic
    "device_serving": bench_device_serving.main,  # host-sync traffic A/B
    "precision": bench_precision.main,     # fp32/bf16/bf16_full policies
    "guidance": bench_guidance.main,       # conditioning NFE overhead
    "planning": bench_planning.main,       # trajectory workload + planner loop
    "solver_zoo": bench_solver_zoo.main,   # zoo race + auto-selection report
    "score_eval": bench_score_eval.main,   # per-NFE hot-path roofline
    "tolerance_tiers": bench_tolerance_tiers.main,  # per-class NFE economics
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name}; have {list(SUITES)}", file=sys.stderr)
            raise SystemExit(2)
        t0 = time.time()
        SUITES[name]()
        print(f"# suite {name} done in {time.time() - t0:.0f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
