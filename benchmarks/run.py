"""Benchmark entry point. One function per paper table + framework
benches. Prints ``name,us_per_call,derived`` CSV and writes one
``BENCH_<suite>.json`` artifact per suite at the repo root (DESIGN.md
§15): a stable schema — suite name, config, wall time, the parsed CSV
rows, and any pass/fail gate tokens found in the derived columns — so
CI and regression tooling diff machine-readable results instead of
scraping stdout. ``--no-artifacts`` restores print-only behaviour.

  PYTHONPATH=src python -m benchmarks.run [--only table1,...]
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import pathlib
import re
import sys
import time

import jax

from . import (
    bench_compaction,
    bench_device_serving,
    bench_dimensionality,
    bench_guidance,
    bench_kernels,
    bench_planning,
    bench_precision,
    bench_score_eval,
    bench_serving,
    bench_sharded_sampling,
    bench_solver_zoo,
    bench_tolerance_tiers,
    table1_solver_grid,
    table2_highdim,
    table3_offtheshelf,
    table45_ablations,
)

SUITES = {
    "table1": table1_solver_grid.main,     # paper Table 1 (+IS table analog)
    "table2": table2_highdim.main,         # paper Table 2
    "table3": table3_offtheshelf.main,     # paper Table 3 / App. A
    "table45": table45_ablations.main,     # paper Tables 4-5 / App. B
    "dimensionality": bench_dimensionality.main,  # beyond-paper
    "kernels": bench_kernels.main,
    "serving": bench_serving.main,
    "sharded_sampling": bench_sharded_sampling.main,  # 1-vs-N device scaling
    "compaction": bench_compaction.main,   # slot compaction vs monolithic
    "device_serving": bench_device_serving.main,  # host-sync traffic A/B
    "precision": bench_precision.main,     # fp32/bf16/bf16_full policies
    "guidance": bench_guidance.main,       # conditioning NFE overhead
    "planning": bench_planning.main,       # trajectory workload + planner loop
    "solver_zoo": bench_solver_zoo.main,   # zoo race + auto-selection report
    "score_eval": bench_score_eval.main,   # per-NFE hot-path roofline
    "tolerance_tiers": bench_tolerance_tiers.main,  # per-class NFE economics
}

#: artifacts land at the repo root, next to README.md — the stable,
#: diffable location CI uploads from
ROOT = pathlib.Path(__file__).resolve().parents[1]

# ``emit()`` rows: name,us_per_call,derived (derived may hold commas
# inside no row we produce, so a 2-split is exact)
_ROW_RE = re.compile(r"^([A-Za-z0-9_.\[\]/=:+-]+),([0-9.eE+-]+|),(.*)$")

#: derived-column tokens that read as benchmark gates — ``k=v`` where k
#: is a pass/fail flag (exact or ``*_pass``/``*_passed`` suffix)
_GATE_KEYS = {"pass", "passed", "compliant", "ok"}


def _parse_gates(derived: str):
    """Pull boolean gate tokens out of a derived column: ``k=v`` pieces
    (split on ``;`` / ``|``) whose key names a pass/fail check. Values
    parse as bool-ish (true/false/1/0/yes/no); anything else is skipped
    rather than guessed."""
    gates = {}
    for piece in re.split(r"[;|]", derived):
        piece = piece.strip()
        if "=" not in piece:
            continue
        k, v = piece.split("=", 1)
        k, v = k.strip(), v.strip().lower()
        if k in _GATE_KEYS or k.endswith("_pass") or k.endswith("_passed"):
            if v in ("true", "1", "yes"):
                gates[k] = True
            elif v in ("false", "0", "no"):
                gates[k] = False
    return gates


def parse_rows(text: str):
    """Parse a suite's captured stdout into structured rows: every
    ``name,us,derived`` CSV line becomes {name, us_per_call, derived,
    gates}; non-CSV lines (section banners, reports) are kept verbatim
    under ``notes`` so nothing a suite prints is dropped."""
    rows, notes = [], []
    for line in text.splitlines():
        m = _ROW_RE.match(line.strip())
        if m and not line.startswith("name,"):
            name, us, derived = m.groups()
            rows.append({
                "name": name,
                "us_per_call": float(us) if us else None,
                "derived": derived,
                "gates": _parse_gates(derived),
            })
        elif line.strip():
            notes.append(line.rstrip())
    return rows, notes


def artifact_path(name: str, out_dir: pathlib.Path = ROOT) -> pathlib.Path:
    """Where a suite's artifact lands: ``BENCH_<suite>.json`` at the
    repo root — the contract the artifact-coverage guard test pins."""
    return out_dir / f"BENCH_{name}.json"


def write_artifact(name: str, rows, notes, wall_time_s: float,
                   out_dir: pathlib.Path = ROOT) -> pathlib.Path:
    """One suite's machine-readable result (schema_version 1): name,
    config (argv + backend), wall time, parsed rows with their gate
    bits, and an aggregate ``gates`` rollup (all_pass over every gate
    token found)."""
    all_gates = {}
    for r in rows:
        for k, v in r["gates"].items():
            all_gates[f"{r['name']}:{k}"] = v
    doc = {
        "name": name,
        "schema_version": 1,
        "config": {
            "argv": sys.argv,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
        },
        "wall_time_s": round(wall_time_s, 3),
        "rows": rows,
        "notes": notes,
        "gates": {
            "tokens": all_gates,
            "all_pass": all(all_gates.values()) if all_gates else None,
        },
    }
    path = artifact_path(name, out_dir)
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return path


class _Tee(io.TextIOBase):
    """Mirror suite stdout to the real stream while capturing it for
    the artifact — the console output stays byte-identical."""

    def __init__(self, stream):
        self._stream = stream
        self._buf = io.StringIO()

    def write(self, s):
        self._stream.write(s)
        return self._buf.write(s)

    def flush(self):
        self._stream.flush()

    def getvalue(self) -> str:
        return self._buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="print-only: skip the BENCH_<suite>.json files")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name}; have {list(SUITES)}", file=sys.stderr)
            raise SystemExit(2)
        t0 = time.time()
        tee = _Tee(sys.stdout)
        with contextlib.redirect_stdout(tee):
            SUITES[name]()
        wall = time.time() - t0
        if not args.no_artifacts:
            rows, notes = parse_rows(tee.getvalue())
            path = write_artifact(name, rows, notes, wall)
            print(f"# artifact {path.relative_to(ROOT)}", file=sys.stderr)
        print(f"# suite {name} done in {wall:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
