"""Paper Table 1 analog: NFE / quality for every solver on VE and VP.

Grid: {reverse-diffusion+Langevin, EM-1000, adaptive at ε_rel ∈
{0.01, 0.02, 0.05, 0.10, 0.50}, EM at matched NFE, DDIM (VP only),
probability-flow ODE} × {VP, VE} on the 4-mode GMM with trained score
nets. Quality = Fréchet distance on raw features (exact reference
moments) + sliced-W2; speed = mean per-sample NFE.

Reproduces the paper's qualitative table: adaptive ≈ baseline quality at
a fraction of the NFE; EM at the adaptive solver's NFE degrades sharply
at loose tolerances; DDIM degrades more gracefully than EM.
"""

from __future__ import annotations

import jax

from repro.core import sample
from .common import GMM, emit, frechet_gaussian, sliced_wasserstein, timed

N_SAMPLES = 4096
EPS_GRID = (0.01, 0.02, 0.05, 0.10, 0.50)


def _quality(x, key):
    data = GMM.sample(key, N_SAMPLES)
    return frechet_gaussian(x, data), sliced_wasserstein(x, data)


def run(process: str) -> None:
    from .common import trained_mlp_score

    sde, score_fn = trained_mlp_score(process)
    key = jax.random.PRNGKey(42)
    kq = jax.random.PRNGKey(7)

    def bench(name, method, **kw):
        fn = jax.jit(
            lambda k: sample(sde, score_fn, (N_SAMPLES, 2), k,
                             method=method, **kw)
        )
        us, res = timed(fn, key)
        fd, sw2 = _quality(res.x, kq)
        nfe = float(res.mean_nfe)
        emit(f"table1/{process}/{name}", us,
             f"nfe={nfe:.0f};frechet={fd:.4f};sw2={sw2:.4f}")
        return nfe

    # baselines (paper's solver settings)
    bench("reverse-langevin", "pc", n_steps=1000)
    bench("em-1000", "em", n_steps=1000)
    if process == "vp":
        bench("ddim-100", "ddim", n_steps=100)
    bench("prob-flow-ode", "ode", rtol=1e-5, atol=1e-5)

    # ours at each tolerance + EM/DDIM at matched budget
    for eps in EPS_GRID:
        nfe = bench(f"ours-eps{eps}", "adaptive", eps_rel=eps)
        matched = max(int(nfe), 2)
        bench(f"em-match-eps{eps}", "em", n_steps=matched)
        if process == "vp":
            bench(f"ddim-match-eps{eps}", "ddim", n_steps=matched)


def main() -> None:
    for process in ("vp", "ve"):
        run(process)


if __name__ == "__main__":
    main()
