"""Paper Table 2 analog: high-dimensional generation (the 256×256 case).

At 196k dims the paper found EM cannot converge at moderate NFE while
the adaptive solver can. We reproduce the mechanism at d=3072 (CIFAR
dimensionality) with an exact anisotropic-Gaussian score — exactness
matters here because the effect being measured is *solver* error, and an
analytic score removes network error from the comparison. VE process
(the paper's high-res models are VE).

Metric: Fréchet distance on the leading 8 principal dims + full-dim
mean/var error.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import VESDE, sample
from .common import emit, frechet_gaussian, timed

D = 3072
N = 256


def _setup():
    key = jax.random.PRNGKey(0)
    mu = 0.5 * jax.random.normal(key, (D,))
    # anisotropic diagonal covariance spanning 2 decades
    s = 0.05 + 0.45 * jax.random.uniform(jax.random.fold_in(key, 1), (D,)) ** 2
    sde = VESDE(sigma_max=30.0)

    def score(x, t):
        m, std = sde.marginal(t)
        var = (m[:, None] * s[None, :]) ** 2 + std[:, None] ** 2
        return -(x - m[:, None] * mu[None, :]) / var

    def reference(key, n):
        return mu + s * jax.random.normal(key, (n, D))

    return sde, score, reference


def main() -> None:
    sde, score, reference = _setup()
    key = jax.random.PRNGKey(3)
    data = reference(jax.random.PRNGKey(11), N)

    def bench(name, method, **kw):
        fn = jax.jit(
            lambda k: sample(sde, score, (N, D), k, method=method, **kw)
        )
        us, res = timed(fn, key)
        fd = frechet_gaussian(res.x[:, :8], data[:, :8])
        mean_err = float(jnp.abs(res.x.mean(0) - data.mean(0)).mean())
        std_err = float(jnp.abs(res.x.std(0) - data.std(0)).mean())
        emit(
            f"table2/ve-d{D}/{name}", us,
            f"nfe={float(res.mean_nfe):.0f};frechet8={fd:.4f};"
            f"mean_err={mean_err:.4f};std_err={std_err:.4f}",
        )
        return float(res.mean_nfe)

    bench("reverse-langevin", "pc", n_steps=1000)
    bench("em-2000", "em", n_steps=2000)
    bench("prob-flow-ode", "ode", rtol=1e-5, atol=1e-5)
    for eps in (0.01, 0.02, 0.05, 0.10):
        nfe = bench(f"ours-eps{eps}", "adaptive", eps_rel=eps)
        bench(f"em-match-eps{eps}", "em", n_steps=max(int(nfe), 2))


if __name__ == "__main__":
    main()
