"""Paper Table 3 / Appendix A analog: why off-the-shelf adaptive SDE
solvers fail on score-based RDPs.

The paper ran DifferentialEquations.jl solvers (SOSRA/SRA3/Lamba/...)
and found divergence or big slowdowns. We reproduce the *mechanisms*
with in-framework variants on the VP GMM benchmark:

  * lamba-style    — adaptive pair WITHOUT extrapolation, ℓ∞ error,
                     r = 0.5, δ(x') (Lamba 2003's choices);
  * linf-only      — ours but with the ℓ∞ norm (the 'single pixel stalls
                     everyone' failure: NFE explodes);
  * tight-tol      — ours at ODE-solver-default tolerances
                     (atol = rtol = 1e-6: the 6–8× slowdown the paper saw
                     with high-order Julia solvers chasing needless
                     precision);
  * ours           — the paper's algorithm.

Each row: NFE + quality; the derived field shows the failure class.
"""

from __future__ import annotations

import jax

from repro.core import AdaptiveConfig, sample
from .common import GMM, emit, frechet_gaussian, timed

N = 2048


def main() -> None:
    from .common import trained_mlp_score

    sde, score_fn = trained_mlp_score("vp")
    key = jax.random.PRNGKey(5)
    data = GMM.sample(jax.random.PRNGKey(13), N)

    variants = {
        "ours": AdaptiveConfig(eps_rel=0.05),
        "lamba-style": AdaptiveConfig(
            eps_rel=0.05, extrapolate=False, error_norm="linf",
            r_exponent=0.5, prev_tolerance=False,
        ),
        "linf-only": AdaptiveConfig(eps_rel=0.05, error_norm="linf"),
        "tight-tol": AdaptiveConfig(eps_rel=1e-4, eps_abs=1e-6),
    }
    rows = {}
    for name, cfg in variants.items():
        fn = jax.jit(
            lambda k, c=cfg: sample(sde, score_fn, (N, 2), k,
                                    method="adaptive", config=c)
        )
        us, res = timed(fn, key)
        fd = frechet_gaussian(res.x, data)
        nfe = float(res.mean_nfe)
        rows[name] = nfe
        emit(f"table3/vp/{name}", us, f"nfe={nfe:.0f};frechet={fd:.4f}")

    # derived comparison rows mirroring the paper's "× slower" column
    base = rows["ours"]
    for name, nfe in rows.items():
        if name != "ours":
            emit(f"table3/vp/{name}-vs-ours", 0.0,
                 f"slowdown={nfe / base:.2f}x")


if __name__ == "__main__":
    main()
