"""Paper Tables 4–5 analog: ablations of Algorithm 1 on VP and VE.

Rows (paper App. B): no change; δ(x') instead of δ(x', x'_prev); no
extrapolation; q = ∞; r ∈ {0.5, 0.8, 1.0}; Lamba-variant combinations.
Reported: NFE + Fréchet quality per (process, variant).
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core import AdaptiveConfig, sample
from .common import GMM, emit, frechet_gaussian, timed

N = 2048

BASE = AdaptiveConfig(eps_rel=0.05)

VARIANTS = {
    "no-change": {},
    "delta-no-prev": dict(prev_tolerance=False),
    "no-extrapolation": dict(extrapolate=False),
    "q-inf": dict(error_norm="linf"),
    "r0.5": dict(r_exponent=0.5),
    "r0.8": dict(r_exponent=0.8),
    "r1.0": dict(r_exponent=1.0),
    "lamba-r0.5": dict(extrapolate=False, r_exponent=0.5,
                       prev_tolerance=False),
    "lamba-linf-theta0.8": dict(extrapolate=False, r_exponent=0.5,
                                error_norm="linf", safety=0.8),
}


def main() -> None:
    from .common import trained_mlp_score

    for process in ("vp", "ve"):
        sde, score_fn = trained_mlp_score(process)
        key = jax.random.PRNGKey(21)
        data = GMM.sample(jax.random.PRNGKey(17), N)
        for name, mods in VARIANTS.items():
            cfg = dataclasses.replace(BASE, **mods)
            fn = jax.jit(
                lambda k, c=cfg: sample(sde, score_fn, (N, 2), k,
                                        method="adaptive", config=c)
            )
            us, res = timed(fn, key)
            fd = frechet_gaussian(res.x, data)
            emit(
                f"table45/{process}/{name}", us,
                f"nfe={float(res.mean_nfe):.0f};frechet={fd:.4f};"
                f"rej={float(res.rejected.sum()) / max(float((res.accepted + res.rejected).sum()), 1):.3f}",
            )


if __name__ == "__main__":
    main()
