"""Diffusion language modeling with a zoo backbone + the paper's solver.

Trains a reduced qwen-family backbone as a score network over token
embeddings on a synthetic patterned language, then generates token
sequences with the adaptive solver vs. EM — the paper's technique
driving *text* generation through the same model zoo the AR serving
path uses.

Scope note: at this CPU-demo scale (1-layer backbone, random frozen
embedding geometry, minutes of training) the sampler produces valid
tokens but not yet the data's joint structure — embedding-space
diffusion LMs need orders of magnitude more capacity/steps for that
(Li et al. 2022 trained ~10⁵ steps). What this demo *does* show, and
tests/test_diffusion_lm.py verifies: DSM loss convergence, exact
embedding round-tripping, and the adaptive solver running the reverse
diffusion over sequences at a fraction of EM's NFE.

  PYTHONPATH=src python examples/diffusion_lm_demo.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import VPSDE
from repro.models.diffusion_lm import (
    DiffusionLMConfig, diffusion_lm_loss, generate, init_diffusion_lm,
)
from repro.optim import AdamW


def main():
    bb = get_config("qwen1.5-0.5b").scaled_down().replace(vocab_size=32)
    cfg = DiffusionLMConfig(backbone=bb, embed_dim=32)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    params = init_diffusion_lm(cfg, key)
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    def data(key, B=16, S=16):
        # "language": ascending runs starting at a random even token
        start = jax.random.randint(key, (B, 1), 0, 8) * 2
        return (start + jnp.arange(S)[None, :]) % 32

    @jax.jit
    def step(params, opt_state, key):
        key, kd, kl = jax.random.split(key, 3)
        loss, grads = jax.value_and_grad(
            lambda p: diffusion_lm_loss(p, cfg, sde, data(kd), kl))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, key, loss

    print("training diffusion-LM (reduced qwen backbone) ...")
    t0 = time.time()
    for i in range(400):
        params, opt_state, key, loss = step(params, opt_state, key)
        if i % 100 == 0:
            print(f"  step {i:4d}  loss {float(loss):8.3f}")
    print(f"trained in {time.time() - t0:.0f}s")

    def run_correct(toks):
        """Fraction of adjacent pairs following the +1 (mod 32) rule."""
        t = np.asarray(toks)
        return float(np.mean((t[:, 1:] - t[:, :-1]) % 32 == 1))

    for method, kw in [("adaptive", dict(eps_rel=0.05)),
                       ("adaptive", dict(eps_rel=0.2)),
                       ("em", dict(n_steps=200))]:
        toks, res = generate(params, cfg, sde, batch=32, seq=16, key=key,
                             method=method, **kw)
        print(f"{method}{kw}: NFE {float(res.mean_nfe):5.0f}  "
              f"pattern-consistency {run_correct(toks):.2f} "
              f"(0.03 = chance; structure needs production-scale training)")
    print("sample:", np.asarray(toks)[0].tolist())


if __name__ == "__main__":
    main()
