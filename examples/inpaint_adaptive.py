"""Checkerboard-mask inpainting with the adaptive solver — no
checkpoint needed (DESIGN.md §9), mirroring examples/sample_adaptive.py.

An exactly solvable per-pixel Gaussian process stands in for a trained
score net, so every claim is checkable: observed pixels are projected
(re-noised to each sample's own t) after every accepted step and pinned
exactly at delivery, the free region still lands on the true
distribution, and the NFE overhead vs the unconditional solve stays
small. The same flags run on the DiT demo (`python -m
repro.launch.sample --inpaint`) and per-request in the server
(`python -m repro.launch.serve --diffusion --inpaint`).

  PYTHONPATH=src python examples/inpaint_adaptive.py
"""

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, VESDE, inpaint, sample

H = W = 16  # 16×16×3 images
C = 3
BATCH = 64


def main():
    key = jax.random.PRNGKey(0)
    sde = VESDE(sigma_max=30.0)

    # per-pixel Gaussian data: mu (H,W,C), per-pixel std s — exact score
    mu = 0.5 + 0.1 * jax.random.normal(key, (H, W, C))
    s = 0.05 + 0.2 * jax.random.uniform(jax.random.fold_in(key, 1),
                                        (H, W, C))

    def score(x, t):
        m, std = sde.marginal(t)
        m = m.reshape(-1, 1, 1, 1)
        std = std.reshape(-1, 1, 1, 1)
        return -(x - m * mu) / ((m * s) ** 2 + std**2)

    # a "photo" to damage: one draw from the data distribution
    truth = mu + s * jax.random.normal(jax.random.fold_in(key, 2),
                                       (BATCH, H, W, C))
    yy, xx = jnp.mgrid[:H, :W]
    checker = (((yy // 4 + xx // 4) % 2) == 0)[None, :, :, None]
    mask = jnp.broadcast_to(checker, truth.shape).astype(jnp.float32)

    shape = (BATCH, H, W, C)
    res_u = jax.jit(lambda k: sample(
        sde, score, shape, k, method="adaptive", eps_rel=0.02))(key)

    conditioner, cond = inpaint(mask, truth)
    res = jax.jit(lambda k: sample(
        sde, score, shape, k, method="adaptive",
        config=AdaptiveConfig(eps_rel=0.02, conditioner=conditioner),
        cond=cond))(key)

    obs_resid = float(jnp.abs((res.x - truth) * mask).max())
    free = res.x * (1 - mask)
    n_free = float((1 - mask).sum())
    free_mean_err = float(jnp.abs(
        (free.sum(0) / BATCH - mu * (1 - mask[0])).sum() / n_free * BATCH))
    ratio = float(res.mean_nfe) / float(res_u.mean_nfe)

    print(f"{'':24s}{'NFE':>8s}{'iters':>8s}")
    print(f"{'unconditional':24s}{float(res_u.mean_nfe):8.0f}"
          f"{int(res_u.iterations):8d}")
    print(f"{'checkerboard inpaint':24s}{float(res.mean_nfe):8.0f}"
          f"{int(res.iterations):8d}")
    print(f"\nobserved-pixel residual (exact pin at delivery): "
          f"{obs_resid:.2e}")
    print(f"free-region mean error vs true per-pixel mean:   "
          f"{free_mean_err:.4f}")
    print(f"NFE ratio inpaint/unconditional: {ratio:.2f}x "
          f"(conformance gate: <= 1.10x at the OU gate shape; "
          f"projection costs no score evaluations)")


if __name__ == "__main__":
    main()
