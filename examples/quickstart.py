"""Quickstart: the paper's algorithm in ~60 lines.

Trains a small score network on a 2-D Gaussian mixture and generates
samples with the adaptive solver vs. Euler–Maruyama, printing NFE and
quality for both — the paper's headline comparison, runnable in ~2 min
on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import VPSDE, dsm_loss, sample
from repro.data.images import GMM2D
from repro.models.score_unet import (
    MLPScoreConfig, init_mlp_score, mlp_score_forward,
)
from repro.optim import AdamW, ema_init, ema_params, ema_update


def main():
    sde = VPSDE()
    gmm = GMM2D()
    net = MLPScoreConfig(dim=2, hidden=128, depth=3)
    key = jax.random.PRNGKey(0)
    params = init_mlp_score(net, key)
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    opt_state, ema = opt.init(params), ema_init(params)

    def apply_fn(p, x, t):
        _, std = sde.marginal(t)  # noise-prediction parameterization
        return mlp_score_forward(p, x, t, net) / std[:, None]

    @jax.jit
    def train_step(params, opt_state, ema, key):
        key, kd, kl = jax.random.split(key, 3)
        x0 = gmm.sample(kd, 512)
        loss, grads = jax.value_and_grad(
            lambda p: dsm_loss(sde, apply_fn, p, x0, kl))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, ema_update(ema, params, 0.995), key, loss

    print("training score network on 4-mode GMM ...")
    for step in range(600):
        params, opt_state, ema, key, loss = train_step(
            params, opt_state, ema, key)
        if step % 150 == 0:
            print(f"  step {step:4d}  dsm loss {float(loss):.3f}")

    score_params = ema_params(ema, params)
    score_fn = lambda x, t: apply_fn(score_params, x, t)

    print("\nsampling 2048 points:")
    for method, kw in [("em", dict(n_steps=1000)),
                       ("adaptive", dict(eps_rel=0.01)),
                       ("adaptive", dict(eps_rel=0.05))]:
        res = jax.jit(lambda k: sample(sde, score_fn, (2048, 2), k,
                                       method=method, **kw))(key)
        data = gmm.sample(jax.random.PRNGKey(9), 2048)
        err = float(jnp.abs(jnp.sort(res.x[:, 0]) - jnp.sort(data[:, 0])).mean())
        tag = f"{method}({kw})"
        print(f"  {tag:35s} NFE {float(res.mean_nfe):6.0f}   W1(x-axis) {err:.4f}")
    print("\nadaptive reaches EM-1000 quality at a fraction of the NFE — "
          "the paper's Figure 1.")


if __name__ == "__main__":
    main()
