"""Tolerance sweep on an exactly solvable high-dimensional process —
reproduces the paper's Figure 1 speed/quality trade-off curve, and shows
per-sample adaptive stepping (each image finishes at its own NFE).

  PYTHONPATH=src python examples/sample_adaptive.py
"""

import jax
import jax.numpy as jnp

from repro.core import VESDE, sample

D = 3072  # CIFAR dimensionality


def main():
    key = jax.random.PRNGKey(0)
    mu = 0.5 * jax.random.normal(key, (D,))
    s = 0.05 + 0.45 * jax.random.uniform(jax.random.fold_in(key, 1), (D,))
    sde = VESDE(sigma_max=30.0)

    def score(x, t):
        m, std = sde.marginal(t)
        var = (m[:, None] * s[None, :]) ** 2 + std[:, None] ** 2
        return -(x - m[:, None] * mu[None, :]) / var

    print(f"{'method':28s}{'NFE':>8s}{'iters':>8s}{'rej%':>7s}"
          f"{'mean err':>10s}{'std err':>9s}")
    for name, method, kw in [
        ("em-2000 (baseline)", "em", dict(n_steps=2000)),
        ("ours eps_rel=0.01", "adaptive", dict(eps_rel=0.01)),
        ("ours eps_rel=0.02", "adaptive", dict(eps_rel=0.02)),
        ("ours eps_rel=0.05", "adaptive", dict(eps_rel=0.05)),
        ("ours eps_rel=0.10", "adaptive", dict(eps_rel=0.10)),
        ("prob-flow ODE", "ode", {}),
    ]:
        res = jax.jit(lambda k: sample(sde, score, (64, D), k,
                                       method=method, **kw))(key)
        me = float(jnp.abs(res.x.mean(0) - mu).mean())
        se = float(jnp.abs(res.x.std(0) - s).mean())
        tot = float((res.accepted + res.rejected).sum())
        rej = 100 * float(res.rejected.sum()) / max(tot, 1)
        print(f"{name:28s}{float(res.mean_nfe):8.0f}{int(res.iterations):8d}"
              f"{rej:7.1f}{me:10.4f}{se:9.4f}")

    # per-sample adaptivity: distribution of per-sample NFE in one batch
    res = jax.jit(lambda k: sample(sde, score, (64, D), k,
                                   method="adaptive", eps_rel=0.02))(key)
    nfe = jax.device_get(res.nfe)
    print(f"\nper-sample NFE within one batch: min {nfe.min()} / "
          f"median {int(jnp.median(jnp.asarray(nfe)))} / max {nfe.max()} "
          f"(paper Sec. 3.1.5: every sample steps at its own pace)")


if __name__ == "__main__":
    main()
