"""Serve a reduced LM from the assigned-architecture zoo with batched
requests through the production serve path (KV/SSM caches, greedy
decode), and a DiT diffusion "server" that answers image requests with
the adaptive solver — both generation paradigms of the framework.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import VPSDE, sample
from repro.launch.serve import serve_batch
from repro.models import init_model
from repro.models.dit import DiTConfig, init_dit, make_score_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    # --- 1. autoregressive serving ---------------------------------------
    cfg = get_config(args.arch).scaled_down()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape += (cfg.num_codebooks,)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)
    cross = (
        jax.random.normal(key, (args.batch, cfg.num_patches, cfg.vision_dim),
                          jnp.dtype(cfg.dtype))
        if cfg.vision_dim else None
    )
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, gen_len=args.gen_len,
                       cross_embeds=cross)
    dt = time.time() - t0
    print(f"[AR] {args.arch} (reduced): generated {toks.shape} "
          f"in {dt:.1f}s ({toks.shape[0] * toks.shape[1] / dt:.0f} tok/s)")

    # --- 2. diffusion serving (the paper's technique) ---------------------
    net = DiTConfig(image_size=16, patch=4, d_model=96, num_layers=2,
                    num_heads=4, d_ff=256)
    sde = VPSDE()
    dit = init_dit(net, key)
    score = make_score_fn(dit, net, sde)
    t0 = time.time()
    res = jax.jit(lambda k: sample(sde, score, (args.batch, 16, 16, 3), k,
                                   method="adaptive", eps_rel=0.05))(key)
    dt = time.time() - t0
    print(f"[diffusion] served {args.batch} image requests in {dt:.1f}s "
          f"(mean NFE {float(res.mean_nfe):.0f}, adaptive solver)")


if __name__ == "__main__":
    main()
