"""End-to-end driver: train a DiT score network on synthetic images,
then sample with the full solver suite (deliverable b).

Default preset trains a small DiT on 16×16 Gaussian-mixture images for a
few hundred steps (CPU-feasible); ``--preset 100m`` selects the ~100M-
parameter DiT of configs/diffusion.py (the production-mesh target — the
same model the dry-run lowers at 32×32/patch-2).

  PYTHONPATH=src python examples/train_diffusion.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs.diffusion import CIFAR_DIT, DIT_100M
from repro.core import VPSDE, dsm_loss, sample
from repro.data.images import GMMImageConfig, sample_images
from repro.models.dit import DiTConfig, dit_forward, init_dit
from repro.optim import AdamW, ema_init, ema_params, ema_update, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["small", "cifar", "100m"],
                    default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--sample-batch", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    net = {
        "small": DiTConfig(image_size=16, patch=4, d_model=128, num_layers=4,
                           num_heads=4, d_ff=512),
        "cifar": CIFAR_DIT,
        "100m": DIT_100M,
    }[args.preset]
    data_cfg = GMMImageConfig(image_size=net.image_size,
                              channels=net.channels)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    params = init_dit(net, key)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"DiT preset={args.preset}: {n_params / 1e6:.1f}M params")

    opt = AdamW(lr=warmup_cosine(3e-4, args.steps // 10 + 1, args.steps),
                weight_decay=0.0)
    opt_state, ema = opt.init(params), ema_init(params)

    def apply_fn(p, x, t):
        _, std = sde.marginal(t)
        return dit_forward(p, x, t, net) / std.reshape(-1, 1, 1, 1)

    @jax.jit
    def train_step(params, opt_state, ema, key):
        key, kd, kl = jax.random.split(key, 3)
        x0 = sample_images(data_cfg, kd, args.batch)
        loss, grads = jax.value_and_grad(
            lambda p: dsm_loss(sde, apply_fn, p, x0, kl))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, ema_update(ema, params, 0.999), key, loss

    t0 = time.time()
    for step in range(args.steps):
        params, opt_state, ema, key, loss = train_step(
            params, opt_state, ema, key)
        if step % 50 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(loss):10.2f}  "
                  f"{(time.time() - t0) / (step + 1):.2f}s/step")

    score_params = ema_params(ema, params)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps,
                        {"params": score_params},
                        metadata={"preset": args.preset})
        print(f"checkpoint written to {args.ckpt_dir}")

    score_fn = lambda x, t: apply_fn(score_params, x, t)
    shape = (args.sample_batch, net.image_size, net.image_size, net.channels)
    data = sample_images(data_cfg, jax.random.PRNGKey(7), args.sample_batch)

    print("\nsolver comparison on the trained model:")
    for method, kw in [("em", dict(n_steps=500)),
                       ("adaptive", dict(eps_rel=0.01)),
                       ("adaptive", dict(eps_rel=0.05)),
                       ("ode", {})]:
        res = jax.jit(lambda k: sample(sde, score_fn, shape, k,
                                       method=method, **kw))(key)
        mean_err = float(jnp.abs(res.x.mean((0, 1, 2)) - data.mean((0, 1, 2))).mean())
        std_err = float(jnp.abs(res.x.std() - data.std()))
        print(f"  {method:10s}{str(kw):22s} NFE {float(res.mean_nfe):6.0f}  "
              f"chan-mean err {mean_err:.3f}  std err {std_err:.3f}")


if __name__ == "__main__":
    main()
