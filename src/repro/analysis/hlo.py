"""HLO text parsing: collective operand bytes + cost-analysis summary.

``compiled.cost_analysis()`` has FLOPs and memory traffic but not
collective volume; we parse the post-SPMD HLO and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Sizes are per-participating-device (the HLO is the
per-device program after partitioning).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %ag = bf16[16,4096,128]{2,1,0} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_text(hlo: str) -> Dict:
    """Sum output bytes of every collective op in (post-SPMD) HLO text."""
    per_kind: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo):
        tuple_body, dtype, dims, kind, phase = m.groups()
        if phase == "-done":
            continue  # counted at -start
        if tuple_body is not None:
            size = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_body)
            )
        else:
            size = _shape_bytes(dtype, dims)
        per_kind[kind] += size
        counts[kind] += 1
    return {
        "total_bytes": int(sum(per_kind.values())),
        "bytes_by_kind": dict(per_kind),
        "counts": dict(counts),
    }


def summarize_cost(cost) -> Dict:
    """Normalize compiled.cost_analysis() (dict of floats) to the keys
    the roofline uses. Values are per-device."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    # keep the per-memory-space byte counts too
    for k, v in cost.items():
        if k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out
