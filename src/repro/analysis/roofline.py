"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch × shape × mesh):
    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

All numerators are per-device (the dry-run records per-device HLO costs),
so the formulas divide by per-chip peaks only. Hardware: TPU v5e.

Also derives MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat and
redundant compute).

Usage:
  PYTHONPATH=src python -m repro.analysis.roofline [--mesh 1pod] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Dict, Optional

from repro.configs import get_config, get_shape
from repro.configs.shapes import apply_shape_policy

# TPU v5e per-chip peaks
PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")
SCORE_EVAL_ARTIFACT = os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "experiments", "score_eval", "BENCH_score_eval.json")


def _param_counts(cfg) -> Dict[str, float]:
    """(total, active) parameter counts excluding the embedding table
    (embeddings do lookup, not matmul; the LM head IS a matmul and is
    counted)."""
    import jax

    from repro.launch.specs import abstract_params

    shapes = abstract_params(cfg)
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = math.prod(leaf.shape)
        if name == "embed":
            continue
        total += n
        if cfg.moe and "/mlp/w_" in name and "shared" not in name:
            # routed experts: only top_k of num_experts active per token
            active += n * cfg.moe.top_k / cfg.moe.num_experts
        else:
            active += n
    return {"total": total, "active": active}


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> Dict:
    """6·N_active·D for train, 2·N_active·D forward-only shapes."""
    cfg = apply_shape_policy(get_config(arch), get_shape(shape_name))
    shape = get_shape(shape_name)
    counts = _param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: ONE token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return {
        "model_flops_total": factor * counts["active"] * tokens,
        "model_flops_per_device": factor * counts["active"] * tokens / devices,
        "params_total": counts["total"],
        "params_active": counts["active"],
    }


def analyze_record(rec: dict) -> dict:
    flops = rec["cost"].get("flops", 0.0)
    bytes_acc = rec["cost"].get(
        "bytes_accessed", rec["cost"].get("est_hbm_traffic_bytes", 0.0)
    )
    coll = rec["collectives"]["total_bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    ratio = mf["model_flops_per_device"] / flops if flops else float("nan")
    bound_time = max(terms.values())
    mfu_bound = (
        mf["model_flops_per_device"] / PEAK_FLOPS / bound_time
        if bound_time else float("nan")
    )
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_per_device": mf["model_flops_per_device"],
        "useful_ratio": ratio,
        "mfu_upper_bound": mfu_bound,
        "peak_gib": (rec["memory"]["peak_bytes"] or 0) / 2**30,
    }


def load_all(mesh: str = "1pod") -> Dict[str, dict]:
    from repro.configs import ARCH_IDS

    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        rec = json.load(open(path))
        if rec["arch"] not in ARCH_IDS:
            continue  # extras (e.g. the dit-sampler dry-run) have their own report
        out[f"{rec['arch']}:{rec['shape']}"] = rec
    return out


def score_eval_markdown(artifact: Optional[dict] = None) -> str:
    """Roofline join for the score-eval bench (DESIGN.md §13).

    Each row of ``experiments/score_eval/BENCH_score_eval.json`` carries
    the per-NFE model FLOPs/bytes (baseline-path AOT cost analysis) and
    the measured per-NFE wall time; this join divides by the TPU v5e
    peaks to classify each score eval as compute- or memory-bound and —
    when the record came from an accelerator — reports achieved FLOP/s
    as a fraction of peak. CPU records keep the bound classification
    (it depends only on the model cost) but their ``achieved`` column
    reflects interpreter-mode wall time, flagged in the footer.
    """
    if artifact is None:
        with open(SCORE_EVAL_ARTIFACT) as f:
            artifact = json.load(f)
    header = ("workload", "preset", "variant", "us/NFE", "GFLOP/NFE",
              "t_compute_s", "t_memory_s", "bound", "achieved_GFLOP/s",
              "frac_peak")
    lines = ["| " + " | ".join(header) + " |", "|" + "---|" * len(header)]
    for r in artifact["rows"]:
        flops = float(r.get("flops_per_nfe") or 0.0)
        byts = float(r.get("bytes_per_nfe") or 0.0)
        t_c = flops / PEAK_FLOPS
        t_m = byts / HBM_BW
        bound = "compute" if t_c >= t_m else "memory"
        us = float(r["us_per_call"])
        achieved = flops / (us * 1e-6) if us else 0.0
        lines.append("| " + " | ".join((
            r["workload"], r["preset"], r["variant"], f"{us:.1f}",
            f"{flops / 1e9:.2f}", f"{t_c:.3e}", f"{t_m:.3e}", bound,
            f"{achieved / 1e9:.2f}", f"{achieved / PEAK_FLOPS:.2e}",
        )) + " |")
    backend = artifact.get("backend", "?")
    lines.append("")
    lines.append(
        f"_backend: {backend}; peaks: TPU v5e "
        f"{PEAK_FLOPS / 1e12:.0f} TFLOP/s bf16, {HBM_BW / 1e9:.0f} GB/s HBM._"
        + (" _CPU interpreter-mode wall times — achieved/frac_peak are "
           "plumbing-validation numbers, not hardware measurements._"
           if backend == "cpu" else ""))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="1pod", choices=["1pod", "2pod"])
    ap.add_argument("--md", action="store_true", help="markdown table")
    ap.add_argument("--score-eval", action="store_true",
                    help="print the score-eval per-NFE roofline join "
                         "(reads experiments/score_eval/)")
    args = ap.parse_args()

    if args.score_eval:
        print(score_eval_markdown())
        return

    recs = load_all(args.mesh)
    if not recs:
        raise SystemExit(f"no dry-run records for mesh {args.mesh}")

    header = ("arch", "shape", "compute_s", "memory_s", "coll_s",
              "dominant", "useful", "mfu_ub", "peak_GiB")
    if args.md:
        print("| " + " | ".join(header) + " |")
        print("|" + "---|" * len(header))
    else:
        print(",".join(header))
    for key, rec in sorted(recs.items()):
        a = analyze_record(rec)
        row = (
            rec["arch"], rec["shape"],
            f"{a['t_compute_s']:.3e}", f"{a['t_memory_s']:.3e}",
            f"{a['t_collective_s']:.3e}", a["dominant"],
            f"{a['useful_ratio']:.2f}", f"{a['mfu_upper_bound']:.2f}",
            f"{a['peak_gib']:.1f}",
        )
        if args.md:
            print("| " + " | ".join(row) + " |")
        else:
            print(",".join(row))


if __name__ == "__main__":
    main()
