"""Per-workload solver auto-selection: best NFE at a fixed W2 gate.

The solver zoo's capstone (DESIGN.md §11): given conformance rows —
one per (solver, workload) from the analytic suite or the zoo
benchmark — pick, per workload, the cheapest solver (lowest mean NFE)
among those that pass their W2 gate. The report is written to
``experiments/conformance/selection.{md,json}`` and published as a CI
step summary, so a solver regression surfaces as a *ranking diff*, not
a silent gate pass.

``ZOO`` is the single spec of the raced configurations: registered
solver name → conformance kwargs + W2 gate. It is shared by
``tests/test_solver_conformance.py`` (which derives its case table from
it, so registry completeness stays a structural property) and
``benchmarks/bench_solver_zoo.py`` (which races the zoo end to end with
wall-clock timings).

Gates are per-solver, not global: PC-family samplers are
variance-biased on coarse grids (the paper calls PC "only heuristically
motivated") and carry a loose 0.25 gate; passing a loose gate does not
hand them the win unless they also spend the fewest NFE.
"""

from __future__ import annotations

import json
import os
from typing import Optional

#: registered solver → {kwargs, tol[, vp_only]}. Tolerances mirror the
#: conformance suite's history: 0.08 for solvers expected at EM-200
#: error, 0.10 for DDIM-50, 0.25 for the PC family.
ZOO = {
    "em": dict(kwargs=dict(n_steps=200), tol=0.08),
    "adaptive": dict(kwargs=dict(eps_rel=0.05), tol=0.08),
    "momentum": dict(kwargs=dict(eps_rel=0.05), tol=0.08),
    "heun": dict(kwargs=dict(eps_rel=0.05), tol=0.08),
    "ode": dict(kwargs={}, tol=0.08),
    "pc": dict(kwargs=dict(n_steps=100), tol=0.25),
    "pc_hmc": dict(kwargs=dict(n_steps=100), tol=0.25),
    "ddim": dict(kwargs=dict(n_steps=50), tol=0.10, vp_only=True),
}


def zoo_cases() -> dict:
    """(kwargs, tol) per solver — the conformance suite's case table."""
    return {name: (dict(spec["kwargs"]), spec["tol"])
            for name, spec in ZOO.items()}


def select(rows) -> dict:
    """Per-workload ranking + winner from conformance rows.

    ``rows`` are summary rows (dicts with at least solver / sde / w2 /
    mean_nfe / tol). Only fp32, unconditioned rows of zoo solvers are
    ranked — precision presets and conditioner overheads are gated by
    their own suites, not raced here. The workload key is the row's
    ``sde`` column (``vp``, ``ve``, ``vp:traj16x6``, ...).

    Returns {workload: {ranking, winner, winner_nfe, adaptive_nfe}} with
    the ranking sorted by mean NFE ascending and the winner the cheapest
    entry that passes its gate.
    """
    by_workload: dict = {}
    for r in rows:
        if r.get("solver") not in ZOO:
            continue
        if r.get("precision", "fp32") != "fp32":
            continue
        if r.get("conditioner", "none") not in (None, "none"):
            continue
        by_workload.setdefault(r["sde"], []).append(r)

    report = {}
    for workload, wrows in sorted(by_workload.items()):
        ranking = [
            {
                "solver": r["solver"],
                "w2": float(r["w2"]),
                "tol": float(r["tol"]),
                "mean_nfe": float(r["mean_nfe"]),
                "passes": float(r["w2"]) < float(r["tol"]),
            }
            for r in sorted(wrows, key=lambda r: float(r["mean_nfe"]))
        ]
        winner = next((e for e in ranking if e["passes"]), None)
        adaptive_entry = next(
            (e for e in ranking if e["solver"] == "adaptive"), None)
        report[workload] = {
            "ranking": ranking,
            "winner": winner["solver"] if winner else None,
            "winner_nfe": winner["mean_nfe"] if winner else None,
            "adaptive_nfe": (
                adaptive_entry["mean_nfe"] if adaptive_entry else None),
        }
    return report


def render_markdown(report: dict) -> str:
    """The selection report as the CI-step-summary markdown."""
    lines = [
        "### Solver auto-selection (lowest NFE passing the W2 gate)",
        "",
        "| workload | winner | winner NFE | adaptive NFE | NFE vs adaptive |",
        "|---|---|---|---|---|",
    ]
    for workload, data in report.items():
        win, wn, an = data["winner"], data["winner_nfe"], data["adaptive_nfe"]
        ratio = f"{wn / an:.2f}x" if (wn and an) else "n/a"
        lines.append(
            f"| {workload} | {win or 'NONE PASSED'} "
            f"| {wn:.0f} | {an:.0f} | {ratio} |"
            if wn is not None and an is not None
            else f"| {workload} | {win or 'NONE PASSED'} | - | - | {ratio} |"
        )
    for workload, data in report.items():
        lines += [
            "",
            f"#### `{workload}`",
            "",
            "| rank | solver | W2 | gate | mean NFE | passes |",
            "|---|---|---|---|---|---|",
        ]
        for i, e in enumerate(data["ranking"], 1):
            mark = "yes" if e["passes"] else "no"
            star = " (winner)" if e["solver"] == data["winner"] else ""
            lines.append(
                f"| {i} | {e['solver']}{star} | {e['w2']:.4f} "
                f"| {e['tol']:.2f} | {e['mean_nfe']:.0f} | {mark} |"
            )
    return "\n".join(lines) + "\n"


def write_selection(report: dict, out_dir: Optional[str] = None):
    """Write selection.{md,json}; returns (md_path, json_path)."""
    if out_dir is None:
        root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))))
        out_dir = os.path.join(root, "experiments", "conformance")
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, "selection.json")
    md_path = os.path.join(out_dir, "selection.md")
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    with open(md_path, "w") as f:
        f.write(render_markdown(report))
    return md_path, json_path
