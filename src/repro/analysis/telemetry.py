"""Telemetry report generator (DESIGN.md §15): render a serve-loop
``trace_record()`` JSON — as written by ``repro.launch.serve
--trace-out`` or ``DiffusionBatcher.trace_record()`` directly — into a
markdown report:

  * per-stage latency table from the tracer's histograms (admission /
    solve / delivery / planner rounds);
  * per-request NFE CDF from the delivered-request books;
  * step-size-vs-t and accept-rate-vs-t curves binned from the
    step-telemetry ring (the paper's Fig. 2-style adaptivity picture:
    h grows over the reverse solve, rejections cluster near t = T).

Idle-slot ring records (t ≤ t_eps) are filtered out host-side here —
the device writes unconditionally to keep the off path's loop body
identical, so the filter is a read-time concern (DESIGN.md §15).

Usage:
  PYTHONPATH=src python -m repro.analysis.telemetry --trace trace.json \
      [--out TELEMETRY.md]
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Sequence

import numpy as np


def active_records(telemetry: Dict) -> Dict[str, np.ndarray]:
    """Flatten a trace record's ``telemetry`` block to 1-D arrays over
    *active* records only (t > t_eps): idle slots ride the device loop
    with t pinned at/below the floor and never accept, so they carry no
    solver information."""
    t = np.asarray(telemetry["t"], np.float64).ravel()
    h = np.asarray(telemetry["h"], np.float64).ravel()
    err = np.asarray(telemetry["err"], np.float64).ravel()
    acc = np.asarray(telemetry["accept"]).astype(bool).ravel()
    t_eps = float(telemetry.get("t_eps", 0.0))
    # replicate the device's fp32 activity test exactly: the ring holds
    # fp32 t, and idle slots sit at fp32(t_eps) — a float64 threshold
    # would misread them as live (fp32(1e-3) > 1e-3 in float64)
    live = t > float(np.float32(t_eps + 1e-12))
    return {"t": t[live], "h": h[live], "err": err[live], "accept": acc[live]}


def step_size_vs_t(telemetry: Dict, bins: int = 12) -> List[Dict]:
    """Bin the active ring records by solver time t: per bin the mean
    step size h, the accept rate, and the mean scaled error norm — the
    adaptivity curves the paper's step-size analysis plots."""
    rec = active_records(telemetry)
    if rec["t"].size == 0:
        return []
    lo, hi = float(rec["t"].min()), float(rec["t"].max())
    if hi <= lo:
        hi = lo + 1e-9
    edges = np.linspace(lo, hi, bins + 1)
    idx = np.clip(np.digitize(rec["t"], edges) - 1, 0, bins - 1)
    rows = []
    for b in range(bins):
        m = idx == b
        if not m.any():
            continue
        rows.append({
            "t_lo": float(edges[b]),
            "t_hi": float(edges[b + 1]),
            "records": int(m.sum()),
            "mean_h": float(rec["h"][m].mean()),
            "accept_rate": float(rec["accept"][m].mean()),
            "mean_err": float(rec["err"][m].mean()),
        })
    return rows


def nfe_percentiles(requests: Sequence[Dict],
                    qs: Sequence[float] = (0, 10, 25, 50, 75, 90, 100),
                    ) -> List[Dict]:
    """Per-request NFE CDF points (the spread slot refill exploits)."""
    nfes = np.asarray([r["nfe"] for r in requests], np.float64)
    if nfes.size == 0:
        return []
    return [{"pct": float(q), "nfe": float(np.percentile(nfes, q))}
            for q in qs]


def _md_table(headers: Sequence[str], rows: Sequence[Sequence]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def telemetry_markdown(trace: Dict) -> str:
    """The full markdown report for one trace record."""
    lines = ["# Serve-loop telemetry report", ""]

    reqs = trace.get("requests", [])
    if reqs:
        total_nfe = sum(r["nfe"] for r in reqs)
        acc = sum(r.get("accepted", 0) for r in reqs)
        rej = sum(r.get("rejected", 0) for r in reqs)
        misses = sum(bool(r.get("deadline_missed")) for r in reqs)
        lines += [
            f"Delivered **{len(reqs)}** requests, total NFE {total_nfe}, "
            f"accepted/rejected steps {acc}/{rej}, "
            f"deadline misses {misses}.",
            "",
        ]

    hist = trace.get("trace", {}).get("stage_histograms", {})
    if hist:
        lines += ["## Per-stage latency", ""]
        rows = [
            (name,
             s["count"],
             f"{s['mean_s'] * 1e3:.2f}",
             f"{s['max_s'] * 1e3:.2f}",
             f"{s['total_s'] * 1e3:.1f}")
            for name, s in sorted(hist.items())
        ]
        lines += [_md_table(
            ("stage", "spans", "mean ms", "max ms", "total ms"), rows), ""]

    if reqs:
        lines += ["## Per-request NFE CDF", ""]
        rows = [(f"p{p['pct']:.0f}", f"{p['nfe']:.0f}")
                for p in nfe_percentiles(reqs)]
        lines += [_md_table(("percentile", "NFE"), rows), ""]

    tel = trace.get("telemetry")
    if tel:
        lines += [
            "## Step size and accept rate vs t",
            "",
            f"{tel['records']} ring records over "
            f"{tel['iterations']} device iterations "
            f"(active records only; idle slots filtered at t_eps).",
            "",
        ]
        rows = [
            (f"[{r['t_lo']:.3f}, {r['t_hi']:.3f})",
             r["records"],
             f"{r['mean_h']:.4f}",
             f"{r['accept_rate']:.2f}",
             f"{r['mean_err']:.3f}")
            for r in step_size_vs_t(tel)
        ]
        if rows:
            lines += [_md_table(
                ("t bin", "records", "mean h", "accept rate", "mean err"),
                rows), ""]

    stats = trace.get("class_stats") or {}
    if stats:
        lines += ["## Per-tier delivery", ""]
        rows = [
            (name,
             s["delivered"],
             f"{s['mean_nfe']:.0f}",
             s["deadline_misses"],
             f"{s['mean_wait_s'] * 1e3:.0f}")
            for name, s in sorted(stats.items())
        ]
        lines += [_md_table(
            ("tier", "delivered", "mean NFE", "deadline misses",
             "mean wait ms"), rows), ""]

    return "\n".join(lines).rstrip() + "\n"


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", required=True,
                    help="trace_record() JSON (launch/serve --trace-out)")
    ap.add_argument("--out", default=None,
                    help="write the markdown here (default: stdout)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    md = telemetry_markdown(trace)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"report -> {args.out}")
    else:
        print(md)


if __name__ == "__main__":
    main()
