"""Pytree checkpointing: flatten to path-keyed npz + json metadata.

No orbax offline; this covers the framework need (save/restore params,
optimizer state, EMA, step) with atomic writes and structure validation
on restore.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_"):-len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        restored.append(jax.numpy.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], restored), step
