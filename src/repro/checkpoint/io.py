"""Pytree checkpointing: flatten to path-keyed npz + json metadata.

No orbax offline; this covers the framework need (save/restore params,
optimizer state, EMA, step) with atomic writes and structure validation
on restore.

Extension dtypes (DESIGN.md §8): numpy's npz format only serializes its
builtin dtypes — an ml_dtypes leaf (bfloat16 param trees under the
``bf16_full`` precision preset) would silently degrade to a raw void
array and fail to restore. Such leaves are stored as same-width
unsigned-int views with the true dtype names recorded *inside the npz*
(the ``__encoded_dtypes__`` entry — the marker is load-bearing, so it
travels with the arrays rather than in a separable sidecar; the json
metadata carries a human-readable copy), and viewed back on restore —
bit-exact round trips for every param dtype
(``tests/test_checkpoint_roundtrip.py``).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


#: same-width unsigned view used to serialize extension dtypes
_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}

#: in-npz entry carrying {key: dtype name} for encoded leaves
_ENCODED_KEY = "__encoded_dtypes__"


def _encode(flat: Dict[str, np.ndarray]):
    """npz-safe (arrays, encoded_dtypes): extension-dtype leaves (numpy
    kind 'V' — ml_dtypes bfloat16 etc.) become same-width uint views,
    with the true dtype name recorded per key."""
    out, encoded = {}, {}
    for key, arr in flat.items():
        if arr.dtype.kind == "V":
            out[key] = arr.view(_UINT_FOR_SIZE[arr.dtype.itemsize])
            encoded[key] = arr.dtype.name
        else:
            out[key] = arr
    return out, encoded


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat, encoded = _encode(_flatten(tree))
    if encoded:
        # the decode marker rides inside the archive: a checkpoint
        # copied without its json sidecar must still restore bit-exactly
        # rather than silently value-cast raw uint patterns
        flat[_ENCODED_KEY] = np.asarray(json.dumps(encoded))
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    meta = {"step": step, **(metadata or {})}
    if encoded:
        meta["encoded_dtypes"] = encoded
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_"):-len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    encoded = {}
    if _ENCODED_KEY in data.files:
        encoded = json.loads(str(data[_ENCODED_KEY]))
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    extra = set(data.files) - set(flat_like) - {_ENCODED_KEY}
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        if key in encoded:
            # view the uint payload back as its true extension dtype —
            # bit-exact, no rounding through an intermediate float
            arr = arr.view(np.dtype(encoded[key]))
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        restored.append(jax.numpy.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], restored), step
