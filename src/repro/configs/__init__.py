"""Config registry: ``get_config(arch_id)`` for every assigned arch."""

from __future__ import annotations

from repro.models.config import ModelConfig

from . import (
    deepseek_moe_16b,
    gemma3_12b,
    granite_moe_3b_a800m,
    jamba_v0_1_52b,
    llama3_2_vision_90b,
    mamba2_2_7b,
    musicgen_medium,
    olmo_1b,
    qwen1_5_0_5b,
    qwen3_14b,
)
from .shapes import (
    LONG_CONTEXT_SWA_WINDOW,
    SHAPES,
    InputShape,
    apply_shape_policy,
    get_shape,
    needs_swa_override,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        olmo_1b,
        qwen1_5_0_5b,
        qwen3_14b,
        jamba_v0_1_52b,
        llama3_2_vision_90b,
        granite_moe_3b_a800m,
        gemma3_12b,
        mamba2_2_7b,
        deepseek_moe_16b,
        musicgen_medium,
    )
}

ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown arch '{name}'; have {list(ARCH_IDS)}") from None


__all__ = [
    "ARCH_IDS",
    "InputShape",
    "LONG_CONTEXT_SWA_WINDOW",
    "SHAPES",
    "apply_shape_policy",
    "get_config",
    "get_shape",
    "needs_swa_override",
]
