"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066].

Shared experts are fused into one 2·1408-wide always-on MLP. (The HF
checkpoint's first layer is a dense 10944-wide MLP; we keep the uniform
MoE pattern for the scanned stack — noted in DESIGN.md §6.)
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    mixer_pattern=("A",),
    mlp_pattern=("E",),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_ffn=1408,
        num_shared_experts=2,
        shared_ffn=2816,
    ),
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    source="arXiv:2401.06066 (DeepSeekMoE 16B)",
)
