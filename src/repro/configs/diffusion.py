"""Score-network configs for the paper's own experiments (VE/VP models).

``cifar_dit`` mirrors the paper's CIFAR-10 32×32 setting at a trainable
scale; ``highres_dit`` stands in for the LSUN/FFHQ 256×256 setting (used
by the table-2 benchmark at reduced resolution on CPU, full resolution
under the dry-run). ``toy_mlp`` is the exactly-solvable 2-D setting used
for solver validation. ``traj_unet`` is the trajectory workload's
temporal score network (DESIGN.md §10) at a locomotion-style shape.
"""

from repro.models.dit import DiTConfig
from repro.models.score_unet import MLPScoreConfig, UNetConfig
from repro.models.temporal_unet import TemporalUNetConfig

# Paper Table 1 analog (CIFAR-scale, 32×32×3)
CIFAR_DIT = DiTConfig(
    image_size=32, channels=3, patch=4, d_model=256, num_layers=6,
    num_heads=8, d_ff=1024,
)
CIFAR_UNET = UNetConfig(image_size=32, channels=3, base=32, mults=(1, 2, 2))

# Paper Table 2 analog (high-res, 256×256×3) — dry-run / lowering scale
HIGHRES_DIT = DiTConfig(
    image_size=256, channels=3, patch=16, d_model=768, num_layers=12,
    num_heads=12, d_ff=3072,
)

# ~100M-param DiT for the end-to-end example's full preset
DIT_100M = DiTConfig(
    image_size=32, channels=3, patch=2, d_model=768, num_layers=12,
    num_heads=12, d_ff=3072,
)

TOY_MLP = MLPScoreConfig(dim=2, hidden=128, depth=3)

# Trajectory-diffusion planning workload (DESIGN.md §10): horizon-32
# plans over a locomotion-style transition (obs 17 + act 6 = 23), with
# returns-to-go CFG bins (decision-diffuser setting)
TRAJ_UNET = TemporalUNetConfig(
    horizon=32, transition_dim=23, base=32, mults=(1, 2, 4), t_dim=64,
    returns_bins=10,
)
