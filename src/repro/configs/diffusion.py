"""Score-network configs for the paper's own experiments (VE/VP models),
plus the serving tier's tolerance-class presets (DESIGN.md §14).

``cifar_dit`` mirrors the paper's CIFAR-10 32×32 setting at a trainable
scale; ``highres_dit`` stands in for the LSUN/FFHQ 256×256 setting (used
by the table-2 benchmark at reduced resolution on CPU, full resolution
under the dry-run). ``toy_mlp`` is the exactly-solvable 2-D setting used
for solver validation. ``traj_unet`` is the trajectory workload's
temporal score network (DESIGN.md §10) at a locomotion-style shape.
"""

import dataclasses
from typing import Optional

from repro.models.dit import DiTConfig
from repro.models.score_unet import MLPScoreConfig, UNetConfig
from repro.models.temporal_unet import TemporalUNetConfig


@dataclasses.dataclass(frozen=True)
class ToleranceClass:
    """A per-request quality tier (DESIGN.md §14): the adaptive solver's
    error tolerance as a *runtime* admission knob, not a config rebuild.

    The paper's Table 1 sweeps ε from 0.01 (best FID) to 0.5 (2–10×
    fewer NFE); a tier names a point on that frontier. ``eps_abs=None``
    defers to ``sde.abs_tolerance`` (the image-calibrated default, same
    resolution rule as ``AdaptiveConfig.eps_abs``); ``h_init=None``
    defers to the serving config's ``h_init``. ``deadline_ms`` is the
    tier's default latency budget (None = no deadline) and ``priority``
    its default admission band (lower = more urgent) — both are
    per-request overridable.
    """

    name: str
    eps_rel: float
    eps_abs: Optional[float] = None
    h_init: Optional[float] = None
    deadline_ms: Optional[float] = None
    priority: int = 0


#: paper-Table-1 frontier presets: draft trades W2 for the 2–10× NFE
#: cut (ε=0.5, the paper's cheapest setting), standard is the repo's
#: serving default (ε=0.05), high_fidelity the paper's best-FID ε=0.01.
DRAFT = ToleranceClass("draft", eps_rel=0.5, priority=1)
STANDARD = ToleranceClass("standard", eps_rel=0.05, priority=1)
HIGH_FIDELITY = ToleranceClass("high_fidelity", eps_rel=0.01, priority=0)

TOLERANCE_CLASSES = {c.name: c for c in (DRAFT, STANDARD, HIGH_FIDELITY)}


def resolve_tier(tier) -> ToleranceClass:
    """Preset name or ToleranceClass instance → ToleranceClass."""
    if isinstance(tier, ToleranceClass):
        return tier
    if tier in TOLERANCE_CLASSES:
        return TOLERANCE_CLASSES[tier]
    raise KeyError(
        f"unknown tolerance class {tier!r}; presets: "
        f"{sorted(TOLERANCE_CLASSES)} (or pass a ToleranceClass)"
    )

# Paper Table 1 analog (CIFAR-scale, 32×32×3)
CIFAR_DIT = DiTConfig(
    image_size=32, channels=3, patch=4, d_model=256, num_layers=6,
    num_heads=8, d_ff=1024,
)
CIFAR_UNET = UNetConfig(image_size=32, channels=3, base=32, mults=(1, 2, 2))

# Paper Table 2 analog (high-res, 256×256×3) — dry-run / lowering scale
HIGHRES_DIT = DiTConfig(
    image_size=256, channels=3, patch=16, d_model=768, num_layers=12,
    num_heads=12, d_ff=3072,
)

# ~100M-param DiT for the end-to-end example's full preset
DIT_100M = DiTConfig(
    image_size=32, channels=3, patch=2, d_model=768, num_layers=12,
    num_heads=12, d_ff=3072,
)

TOY_MLP = MLPScoreConfig(dim=2, hidden=128, depth=3)

# Trajectory-diffusion planning workload (DESIGN.md §10): horizon-32
# plans over a locomotion-style transition (obs 17 + act 6 = 23), with
# returns-to-go CFG bins (decision-diffuser setting)
TRAJ_UNET = TemporalUNetConfig(
    horizon=32, transition_dim=23, base=32, mults=(1, 2, 4), t_dim=64,
    returns_bins=10,
)
