"""gemma3-12b — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family, 12B-scale per assignment].

head_dim 256 is decoupled from d_model/num_heads (Gemma convention).
Local layers use a 1024-token sliding window; every 6th layer is global
— this native sub-quadratic pattern is why gemma3 runs long_500k
without the SWA override (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    mixer_pattern=("L", "L", "L", "L", "L", "A"),
    mlp_pattern=("D",) * 6,
    sliding_window=1024,
    qk_norm=True,
    norm_type="rmsnorm",
    act="gelu",
    glu=True,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt (12B-scale per assignment)",
)
