"""granite-moe-3b-a800m — MoE, 40 routed experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

The assignment's config line says 40 experts top-8 while its note says
32; we follow the explicit config numbers (40) — recorded in DESIGN.md §4.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mixer_pattern=("A",),
    mlp_pattern=("E",),
    moe=MoEConfig(num_experts=40, top_k=8, expert_ffn=512),
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (3b-a800m per assignment)",
)
