"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2 every other
layer [arXiv:2403.19887].

Period-8 super-block: attention at index 4, Mamba elsewhere; MoE MLP on
odd indices. Adaptation note (DESIGN.md §6): Jamba v0.1 uses Mamba-1
(d_state 16); we realize the SSM layers with the Mamba2/SSD formulation
(same d_state) because SSD is the TPU-native (MXU-friendly) form of the
selective scan.
"""

from repro.models.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    mixer_pattern=("M", "M", "M", "M", "A", "M", "M", "M"),
    mlp_pattern=("D", "E", "D", "E", "D", "E", "D", "E"),
    moe=MoEConfig(num_experts=16, top_k=2, expert_ffn=14336),
    mamba=MambaConfig(d_state=16, head_dim=64, expand=2, n_groups=1),
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    source="arXiv:2403.19887 (Jamba v0.1)",
)
