"""llama-3.2-vision-90b — VLM: cross-attention image layers every 5th
layer [hf:meta-llama/Llama-3.2-11B-Vision, 90B-scale per assignment].

The ViT vision tower is the allowed stub: ``input_specs()`` supplies
precomputed patch embeddings (B, num_patches, vision_dim); the decoder's
cross-attention layers (k/v projected from vision_dim) ARE implemented.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    mixer_pattern=("A", "A", "A", "A", "X"),
    mlp_pattern=("D", "D", "D", "D", "D"),
    vision_dim=7680,
    num_patches=1601,
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B-scale per assignment)",
)
