"""mamba2-2.7b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

d_inner = 2·2560 = 5120 → 80 heads of dim 64, d_state 128. No MLP
(mlp_pattern "N") — the Mamba2 block is the whole layer. num_heads /
num_kv_heads below are placeholders (no attention layers exist).
"""

from repro.models.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    mixer_pattern=("M",),
    mlp_pattern=("N",),
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    norm_type="rmsnorm",
    source="arXiv:2405.21060 (Mamba2 2.7B)",
)
