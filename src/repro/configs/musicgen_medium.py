"""musicgen-medium — decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284].

The EnCodec frontend is the allowed stub: inputs are the 4 parallel
codebook token streams (B, S, 4); embedding = Σ_k embed_k(token_k),
output = 4 parallel vocab-2048 heads (the delay-pattern bookkeeping is a
data-pipeline concern, handled in repro.data.tokens).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    mixer_pattern=("A",),
    mlp_pattern=("D",),
    norm_type="layernorm",
    act="gelu",
    glu=False,  # MusicGen uses a plain (non-gated) transformer MLP
    source="arXiv:2306.05284 (MusicGen medium)",
)
