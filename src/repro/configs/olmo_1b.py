"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    mixer_pattern=("A",),
    mlp_pattern=("D",),
    norm_type="layernorm_np",  # OLMo's non-parametric LN
    act="silu",
    glu=True,
    rope_theta=10_000.0,
    source="arXiv:2402.00838 (OLMo 1B)",
)
