"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    mixer_pattern=("A",),
    mlp_pattern=("D",),
    qkv_bias=True,  # Qwen1.5's attention biases
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
