"""qwen3-14b — dense, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B family]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    mixer_pattern=("A",),
    mlp_pattern=("D",),
    qk_norm=True,  # Qwen3's per-head RMS q/k norms
    norm_type="rmsnorm",
    act="silu",
    glu=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B (14B-scale variant per assignment)",
)
