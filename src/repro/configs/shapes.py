"""The four assigned input shapes and the per-(arch × shape) policy.

``decode_32k`` / ``long_500k`` lower ``serve_step`` (ONE token with a KV
cache of ``seq_len``), not ``train_step``. long_500k requires
sub-quadratic attention state: SSM/hybrid/local-attention archs run
natively; pure full-attention archs run via the sliding-window variant
(``swa_override``), per the assignment rules (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Window used when a pure full-attention arch runs long_500k.
LONG_CONTEXT_SWA_WINDOW = 8_192


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise ValueError(f"unknown shape '{name}'; have {sorted(SHAPES)}") from None


def needs_swa_override(cfg, shape: InputShape) -> bool:
    """True when the arch needs the sliding-window variant for this shape:
    *pure* full-attention stacks (every mixer "A"/"X") on the 500k decode
    shape. Archs with native sub-quadratic structure — SSM ("M") or
    local-attention ("L") layers (mamba2, jamba, gemma3) — run long_500k
    natively: their occasional global layers decode in O(S) against a
    sharded KV cache (DESIGN.md §4)."""
    return shape.name == "long_500k" and all(
        m in ("A", "X") for m in cfg.mixer_pattern
    )


def apply_shape_policy(cfg, shape: InputShape):
    """Return the (possibly SWA-overridden) config used for this shape."""
    if needs_swa_override(cfg, shape):
        pattern = tuple("L" if m == "A" else m for m in cfg.mixer_pattern)
        return cfg.replace(
            mixer_pattern=pattern, sliding_window=LONG_CONTEXT_SWA_WINDOW
        )
    return cfg
