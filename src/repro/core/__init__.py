"""Core of the reproduction: SDEs + the paper's adaptive solver + baselines.

The paper's primary contribution (Algorithm 1/2 — adaptive-step-size
extrapolated stochastic Improved Euler) lives in
``repro.core.solvers.adaptive``; everything else here is the substrate
it needs (processes, tolerances, losses, sampling driver).
"""

from repro.core.guidance import (
    ClassifierFree,
    Colorize,
    Conditioner,
    Inpaint,
    class_conditional,
    classifier_free,
    colorize,
    inpaint,
)
from repro.core.precision import PrecisionPolicy, resolve_policy
from repro.core.sde import SDE, VESDE, VPSDE, SubVPSDE, get_sde
from repro.core.solvers import (
    AdaptiveConfig,
    ForwardAdaptiveConfig,
    SolveResult,
    SolverCarry,
    adaptive,
    adaptive_forward,
    available_solvers,
    ddim,
    euler_maruyama,
    finalize,
    get_solver,
    heun,
    init_carry,
    momentum,
    predictor_corrector,
    predictor_corrector_hmc,
    probability_flow_rk45,
    resolve_config,
    solve_chunk,
)
from repro.core.likelihood import bits_per_dim, log_likelihood
from repro.core.losses import dsm_loss, make_loss_fn
from repro.core.sampling import sample, sample_chunked, solve_in_chunks

__all__ = [
    "SDE", "VESDE", "VPSDE", "SubVPSDE", "get_sde",
    "PrecisionPolicy", "resolve_policy",
    "Conditioner", "ClassifierFree", "Inpaint", "Colorize",
    "class_conditional", "classifier_free", "inpaint", "colorize",
    "AdaptiveConfig", "ForwardAdaptiveConfig", "SolveResult", "SolverCarry",
    "adaptive", "adaptive_forward", "available_solvers", "ddim",
    "euler_maruyama", "finalize", "get_solver", "heun", "init_carry",
    "momentum", "predictor_corrector", "predictor_corrector_hmc",
    "probability_flow_rk45", "resolve_config", "solve_chunk",
    "dsm_loss", "make_loss_fn",
    "bits_per_dim", "log_likelihood",
    "sample", "sample_chunked", "solve_in_chunks",
]
