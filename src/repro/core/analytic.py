"""Closed-form scores for Gaussian data — the test/bench workhorse.

For data x0 ~ N(mu, s0² I) under any linear-drift SDE with transition
kernel N(m(t)·x0, std(t)² I), the time-t marginal is Gaussian in closed
form:

    x_t ~ N(m(t)·mu, m(t)²·s0² + std(t)²)

so the exact score is available without a network. Every conformance
test, serving test, self-test, and benchmark that needs an exact score
uses these two factories instead of re-deriving the formula inline.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.sde import SDE

Array = jax.Array


def gaussian_score(sde: SDE, mu: float = 0.3, s0: float = 0.5):
    """Exact score ∇log p_t for x0 ~ N(mu, s0² I); t is a (B,) vector."""

    def score(x: Array, t: Array) -> Array:
        m, std = sde.marginal(t)
        m = m.reshape((-1,) + (1,) * (x.ndim - 1))
        std = std.reshape((-1,) + (1,) * (x.ndim - 1))
        return -(x - m * mu) / (m * m * s0 * s0 + std * std)

    return score


def gaussian_marginal_moments(
    sde: SDE, mu: float = 0.3, s0: float = 0.5, t: float | None = None
):
    """Exact (mean, std) of x_t for x0 ~ N(mu, s0² I); t defaults to
    ``sde.t_eps`` — the reference the conformance suite and the
    precision benchmark both measure against."""
    tt = sde.t_eps if t is None else t
    m, s = sde.marginal(jnp.asarray(tt, jnp.float32))
    return float(m) * mu, math.sqrt(float(m) ** 2 * s0**2 + float(s) ** 2)


def gaussian_w2(mu1: float, s1: float, mu2: float, s2: float) -> float:
    """Exact 2-Wasserstein distance between 1-D Gaussians."""
    return math.sqrt((mu1 - mu2) ** 2 + (s1 - s2) ** 2)


def class_gaussian_score(sde: SDE, mus, s0: float = 0.5,
                         null_mu: float = 0.3):
    """Label-aware exact score (DESIGN.md §9 test workhorse): class ``y``
    has data x0 ~ N(mus[y], s0² I); a negative (null) label — and
    ``y=None`` — selects ``null_mu``, computing *exactly* the same
    arithmetic as ``gaussian_score(sde, null_mu, s0)`` so the
    classifier-free ``scale=0`` path can be asserted bit-identical to
    the unconditional solve."""
    mus = jnp.asarray(mus, jnp.float32)

    def score(x: Array, t: Array, y: Array | None = None) -> Array:
        m, std = sde.marginal(t)
        m = m.reshape((-1,) + (1,) * (x.ndim - 1))
        std = std.reshape((-1,) + (1,) * (x.ndim - 1))
        if y is None:
            mu_y = jnp.full((x.shape[0],), null_mu, jnp.float32)
        else:
            mu_y = jnp.where(y < 0, jnp.float32(null_mu),
                             mus[jnp.clip(y, 0, mus.shape[0] - 1)])
        mu_y = mu_y.reshape((-1,) + (1,) * (x.ndim - 1))
        return -(x - m * mu_y) / (m * m * s0 * s0 + std * std)

    return score


def gaussian_noise_pred(sde: SDE, mu: float = 0.3, s0: float = 0.5):
    """The same exact score as a ``forward_fn(params, x, t)`` in
    ``make_sample_step``'s noise-prediction convention (score = -out/std).
    ``params`` is ignored — the score is analytic."""
    score = gaussian_score(sde, mu, s0)

    def forward_fn(params, x: Array, t: Array) -> Array:
        _, std = sde.marginal(t)
        return -score(x, t) * std.reshape((-1,) + (1,) * (x.ndim - 1))

    return forward_fn


def class_gaussian_noise_pred(sde: SDE, mus, s0: float = 0.5,
                              null_mu: float = 0.3):
    """Label-aware :func:`class_gaussian_score` in ``make_sample_step``'s
    noise-prediction ``forward_fn(params, x, t, y=None)`` convention —
    the analytic stand-in for a returns-conditioned score net in the
    planner's serving loop (DESIGN.md §10). The null branch computes
    exactly ``gaussian_noise_pred(sde, null_mu, s0)``'s arithmetic."""
    score = class_gaussian_score(sde, mus, s0, null_mu)

    def forward_fn(params, x: Array, t: Array, y: Array | None = None) -> Array:
        _, std = sde.marginal(t)
        return -score(x, t, y) * std.reshape((-1,) + (1,) * (x.ndim - 1))

    return forward_fn
