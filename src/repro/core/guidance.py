"""Controlled generation: composable score-field transforms (DESIGN.md §9).

The adaptive solver integrates *whatever score field it is handed* —
its tolerance-driven step control (two score evaluations, Eq. 4/5 error
estimate) never inspects where the score came from. Song et al. (2021,
App. I) show that the classic controllable-generation scenarios all
reduce to sampling a modified score field:

  * **classifier-free guidance** — replace s(x,t) with
    s_u + w·(s_c − s_u), a pure score-field transform;
  * **inpainting** — sample the unconditional field but *project* the
    observed coordinates onto the forward marginal of the observation
    after every accepted step;
  * **colorization** — inpainting in a rotated channel basis where the
    observed coordinate is the gray component.

This module is the seam that makes those scenarios (and every future
one: super-resolution, editing, restoration) first-class in the
sampling/serving stack. A conditioner splits into two halves:

  * the **static half** — a :class:`Conditioner` instance: hashable,
    array-free, registered as a static pytree. It lives in
    ``AdaptiveConfig.conditioner`` and rides through jit closures
    without tracing, exactly like a ``PrecisionPolicy`` (DESIGN.md §8).
  * the **per-sample payload** (``cond``) — a pytree of arrays whose
    leaves all carry a leading batch dim (labels ``(B,)``, masks
    ``(B, …)``). It lives in ``SolverCarry.cond``, travels through
    ``solve_chunk`` untouched, and is compacted/admitted per-slot by
    the serving loop alongside x and the per-slot PRNG keys
    (DESIGN.md §7/§9: condition leaves move with their samples,
    shard-locally, like keys).

Guardrails (DESIGN.md §9): ``conditioner=None`` (the default
everywhere) leaves every code path bit-identical to the unconditional
stack — no extra noise draws, no extra casts; ``classifier_free`` with
``scale=0`` degenerates to the unconditional score; ``inpaint`` with
``mask=None`` returns no conditioner at all. Projection math always
runs in fp32, under every precision preset — condition payloads are
control-path data, never stored at a reduced state dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: sentinel class id meaning "unconditional" in a classifier-free payload
NULL_LABEL = -1


def _expand(v: Array, x: Array) -> Array:
    """(B,) → (B, 1, 1, ...) to broadcast against x."""
    return v.reshape(v.shape + (1,) * (x.ndim - v.ndim))


def _f32(*arrays):
    return tuple(a.astype(jnp.float32) for a in arrays)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Conditioner:
    """Protocol for score-field conditioning (DESIGN.md §9).

    Subclasses override some of the four hooks below; the base class is
    the identity conditioner (every hook a no-op), so a subclass only
    pays for what it uses. Instances must stay array-free — per-sample
    arrays belong in the ``cond`` payload pytree, which every hook
    receives alongside the state. The class is registered static, so a
    conditioner inside ``AdaptiveConfig`` hashes/compares by value and
    never becomes a traced input.

    Hooks:
      * :meth:`wrap_score` — transform the score field given the batch
        payload; called inside the solver body with ``carry.cond``.
      * :meth:`project` — post-accept state projection at the slot's
        *new* time t (DESIGN.md §9 explains why projection must come
        after acceptance, never inside the proposal).
      * :meth:`finalize_project` — exact (noise-free) constraint
        replacement applied by ``finalize`` after the Tweedie denoise.
      * :meth:`cond_struct` — the payload's abstract structure, used by
        the serving loop (neutral payload for idle slots) and the
        sharding layer (batch-axis specs per leaf).
    """

    #: set by subclasses whose :meth:`project` does real work; the
    #: solver draws projection noise (an extra per-iteration PRNG draw)
    #: only when this is True, keeping unconditional noise streams
    #: untouched.
    has_projection = False

    def wrap_score(
        self, score_fn: Callable, cond: Any
    ) -> Callable[[Array, Array], Array]:
        """Return the transformed score field for payload ``cond``.

        The default is the identity — projection-only conditioners
        leave the score field alone.
        """
        return score_fn

    def project(self, sde, x: Array, t: Array, cond: Any, z: Array) -> Array:
        """Project state ``x`` at per-sample times ``t`` onto the
        constraint manifold, re-noising observed data to time t with the
        fp32 standard-normal draw ``z``. Returns fp32; the solver casts
        back to the state dtype. Identity by default."""
        return x

    def finalize_project(self, x: Array, cond: Any) -> Array:
        """Exact constraint replacement on the delivered sample (no
        re-noising) — applied after the Tweedie denoise. Identity by
        default."""
        return x

    def cond_struct(self, batch: int, sample_shape) -> Any:
        """Abstract payload pytree (``jax.ShapeDtypeStruct`` leaves,
        leading dim ``batch``), or None when the conditioner carries no
        payload."""
        return None

    def neutral_cond(self, batch: int, sample_shape) -> Any:
        """A concrete payload that makes the conditioner a no-op — the
        serving loop's idle-slot filler and its fallback for requests
        submitted without a payload. The base default is all-zeros
        (zero mask ⇒ projection is the exact identity); subclasses
        whose zeros are *not* neutral must override (``ClassifierFree``
        uses the null label)."""
        struct = self.cond_struct(batch, sample_shape)
        if struct is None:
            return None
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), struct
        )


# ---------------------------------------------------------------------------
# classifier-free guidance
# ---------------------------------------------------------------------------


def classifier_free(
    cond_score: Callable[[Array, Array], Array],
    uncond_score: Callable[[Array, Array], Array],
    scale: float,
) -> Callable[[Array, Array], Array]:
    """Functional classifier-free transform: s_u + w·(s_c − s_u).

    The composable score-field form (DESIGN.md §9): both inputs and the
    output have the plain ``s(x, t)`` signature, so the result drops
    into ``sample()`` / any solver / another transform unchanged. The
    combination runs in fp32 and is cast back to the unconditional
    score's dtype.

    ``scale == 0`` returns ``uncond_score`` itself — the same callable,
    hence bit-identical to the unconditional path by construction.

    When both fields come from one label-aware network, use
    :class:`ClassifierFree` (the payload/conditioner form) instead: it
    evaluates the pair as a single stacked forward.
    """
    if scale == 0.0:
        return uncond_score

    def guided(x: Array, t: Array) -> Array:
        s_u = uncond_score(x, t)
        s_c = cond_score(x, t)
        u32, c32 = _f32(s_u, s_c)
        return (u32 + scale * (c32 - u32)).astype(s_u.dtype)

    return guided


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class ClassifierFree(Conditioner):
    """Classifier-free guidance over a label-aware score field
    (DESIGN.md §9).

    The base score function must accept a trailing label vector:
    ``score_fn(x, t, y)`` with ``y`` int32 ``(B,)`` and ``y ==
    null_label`` meaning unconditional (``repro.models.dit.make_score_fn``
    produces this signature when ``DiTConfig.num_classes > 0``). The
    payload is ``{"label": (B,) int32}`` — one class id per slot, moved
    with its sample by the serving loop's compaction.

    The guided field is evaluated as **one batched forward** in an
    in-kernel-friendly layout: the batch is doubled to ``[x; x]`` with
    labels ``[y; null]``, the network runs once over 2B contiguous
    rows (no interleaving — each half keeps the original row order, so
    a batch-sharded forward splits without resharding), and the two
    halves combine as s_u + w·(s_c − s_u) in fp32.

    ``scale == 0`` skips the doubling entirely and evaluates the single
    null-labeled forward — the unconditional mode of the network, at
    unconditional cost.
    """

    scale: float = 1.0
    null_label: int = NULL_LABEL

    def wrap_score(self, score_fn: Callable, cond: Any) -> Callable:
        y = cond["label"]
        null = jnp.full_like(y, self.null_label)
        if self.scale == 0.0:
            return lambda x, t: score_fn(x, t, null)

        def guided(x: Array, t: Array) -> Array:
            b = x.shape[0]
            x2 = jnp.concatenate([x, x], axis=0)
            t2 = jnp.concatenate([t, t], axis=0)
            y2 = jnp.concatenate([y, null], axis=0)
            s2 = score_fn(x2, t2, y2)  # one forward over 2B rows
            c32, u32 = _f32(s2[:b], s2[b:])
            return (u32 + self.scale * (c32 - u32)).astype(s2.dtype)

        return guided

    def cond_struct(self, batch: int, sample_shape) -> Any:
        return {"label": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    def neutral_cond(self, batch: int, sample_shape) -> Any:
        """Neutral means *unconditional*: the null label, not class 0 —
        an all-zeros payload would guide toward a real class."""
        return {"label": jnp.full((batch,), self.null_label, jnp.int32)}


def class_conditional(
    labels, scale: float, *, null_label: int = NULL_LABEL
) -> Tuple[ClassifierFree, Any]:
    """Build the (conditioner, payload) pair for class-conditional
    sampling: ``sample(..., conditioner=c, cond=payload)`` (DESIGN.md
    §9). ``labels`` is an int ``(B,)`` vector of class ids."""
    return (
        ClassifierFree(scale=float(scale), null_label=null_label),
        {"label": jnp.asarray(labels, jnp.int32)},
    )


# ---------------------------------------------------------------------------
# inpainting
# ---------------------------------------------------------------------------


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Inpaint(Conditioner):
    """Inpainting as post-accept projection (Song et al. 2021 App. I;
    DESIGN.md §9).

    Payload: ``{"mask": (B, …), "observed": (B, …)}``, both fp32 and
    shaped like the sample; ``mask == 1`` marks observed coordinates.
    After every *accepted* step — never inside the proposal, which
    would corrupt the Eq. 4/5 error estimate — the observed
    coordinates are replaced by a fresh draw from the forward marginal
    at the slot's **own new time t** (per-sample step sizes mean every
    slot sits at a different t, so the re-noising uses the per-slot t
    vector and stays valid under compaction):

        x ← mask · (m(t)·observed + std(t)·z) + (1 − mask) · x

    in fp32 under every precision preset. ``finalize_project`` then
    pins the observed coordinates to ``observed`` exactly (noise-free)
    on the delivered, denoised sample. A zero mask makes both maps the
    exact identity.
    """

    has_projection = True

    def project(self, sde, x: Array, t: Array, cond: Any, z: Array) -> Array:
        m, s = sde.marginal(t)
        x32, mask, obs, z32, m32, s32 = _f32(
            x, cond["mask"], cond["observed"], z, m, s
        )
        obs_t = _expand(m32, x32) * obs + _expand(s32, x32) * z32
        return mask * obs_t + (1.0 - mask) * x32

    def finalize_project(self, x: Array, cond: Any) -> Array:
        mask, obs = _f32(cond["mask"], cond["observed"])
        return (mask * obs + (1.0 - mask) * x.astype(jnp.float32)).astype(
            x.dtype
        )

    def cond_struct(self, batch: int, sample_shape) -> Any:
        shp = (batch,) + tuple(sample_shape)
        sds = jax.ShapeDtypeStruct(shp, jnp.float32)
        return {"mask": sds, "observed": sds}


def inpaint(mask, observed) -> Tuple[Optional[Inpaint], Any]:
    """Build the (conditioner, payload) pair for inpainting:
    ``sample(..., conditioner=c, cond=payload)`` (DESIGN.md §9).

    ``mask`` and ``observed`` are batched ``(B, …)`` arrays shaped like
    the samples (mask 1 = keep observed). ``mask=None`` returns
    ``(None, None)`` — no conditioner object at all, so the call site
    degrades to the bit-identical unconditional path.
    """
    if mask is None:
        return None, None
    return Inpaint(), {
        "mask": jnp.asarray(mask, jnp.float32),
        "observed": jnp.asarray(observed, jnp.float32),
    }


# ---------------------------------------------------------------------------
# colorization — inpainting in a rotated channel basis
# ---------------------------------------------------------------------------


def gray_basis(channels: int) -> Array:
    """Orthonormal channel basis whose first row is the gray direction
    1/√C — the decoupling transform of Song et al. 2021 App. I.2
    (DESIGN.md §9). Deterministic (Householder reflection mapping
    e₀ → 1/√C), fp32, constant-folded under jit."""
    import numpy as np

    c = int(channels)
    g = np.full((c,), 1.0 / np.sqrt(c))
    v = g - np.eye(c)[0]
    n2 = float(v @ v)
    m = np.eye(c) if n2 < 1e-12 else np.eye(c) - 2.0 * np.outer(v, v) / n2
    # rows: m @ e0 = g ⇒ use m as the basis with row 0 = gray direction
    return jnp.asarray(m.T, jnp.float32)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Colorize(Conditioner):
    """Colorization as a channel-space mask instance of inpainting
    (DESIGN.md §9).

    Rotate the trailing channel axis by the orthonormal
    :func:`gray_basis`; in that basis the observed coordinate is a
    single channel — the gray component u₀ = ⟨x, 1⟩/√C — and the
    projection is exactly :class:`Inpaint`'s, applied to u₀: after every
    accepted step, u₀ ← m(t)·gray + std(t)·z at the slot's own t (fp32),
    then rotate back. Payload: ``{"gray": (B, …, 1) fp32}`` — the known
    gray image, one channel. ``finalize_project`` pins u₀ = gray
    exactly on the delivered sample.
    """

    has_projection = True
    channels: int = 3

    def project(self, sde, x: Array, t: Array, cond: Any, z: Array) -> Array:
        basis = gray_basis(self.channels)
        m, s = sde.marginal(t)
        x32, gray, z32, m32, s32 = _f32(x, cond["gray"], z, m, s)
        u = jnp.einsum("...c,dc->...d", x32, basis)
        gray_t = _expand(m32, gray) * gray + _expand(s32, gray) * z32[..., :1]
        u = jnp.concatenate([gray_t, u[..., 1:]], axis=-1)
        return jnp.einsum("...d,dc->...c", u, basis)

    def finalize_project(self, x: Array, cond: Any) -> Array:
        basis = gray_basis(self.channels)
        x32, gray = _f32(x, cond["gray"])
        u = jnp.einsum("...c,dc->...d", x32, basis)
        u = jnp.concatenate([gray, u[..., 1:]], axis=-1)
        return jnp.einsum("...d,dc->...c", u, basis).astype(x.dtype)

    def cond_struct(self, batch: int, sample_shape) -> Any:
        shp = (batch,) + tuple(sample_shape[:-1]) + (1,)
        return {"gray": jax.ShapeDtypeStruct(shp, jnp.float32)}


def colorize(gray, channels: int = 3) -> Tuple[Optional[Colorize], Any]:
    """Build the (conditioner, payload) pair for colorization:
    ``gray`` is the known gray component ⟨x, 1⟩/√C, batched ``(B, …, 1)``
    (a trailing singleton channel; ``(B, …)`` is auto-expanded). Use
    :func:`to_gray` to compute it from a reference image (DESIGN.md §9).
    ``gray=None`` returns ``(None, None)``."""
    if gray is None:
        return None, None
    g = jnp.asarray(gray, jnp.float32)
    if g.shape[-1] != 1:
        g = g[..., None]
    return Colorize(channels=channels), {"gray": g}


def to_gray(x, channels: int = 3) -> Array:
    """Gray component of a color image in the :func:`gray_basis`
    convention (DESIGN.md §9): ⟨x, 1⟩/√C over the trailing channel
    axis, keepdims."""
    basis = gray_basis(channels)
    return jnp.einsum("...c,c->...", x.astype(jnp.float32),
                      basis[0])[..., None]


# ---------------------------------------------------------------------------
# payload plumbing shared by solver / sharding / serving
# ---------------------------------------------------------------------------


def cond_batch(cond: Any) -> Optional[int]:
    """Leading (batch) dim shared by every payload leaf, or None for an
    empty payload. Raises if leaves disagree — a payload whose leaves
    straddle batches cannot be compacted per-slot (DESIGN.md §9)."""
    leaves = jax.tree_util.tree_leaves(cond)
    if not leaves:
        return None
    sizes = {int(l.shape[0]) for l in leaves}
    if len(sizes) != 1:
        raise ValueError(
            f"condition payload leaves disagree on the batch dim: {sizes}"
        )
    return sizes.pop()
