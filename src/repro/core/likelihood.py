"""Exact log-likelihood via the probability-flow ODE (Song et al. 2020a
App. D.2) — the capability that makes score-based models *normalizing
flows* when solved as ODEs.

d/dt log p(x(t)) = −∇·f̃(x, t) along dx/dt = f̃ = f − ½g²s, so

  log p₀(x₀) = log p_T(x_T) + ∫₀^T ∇·f̃(x(t), t) dt.

The divergence uses either the exact jacobian trace (jacfwd — O(d)
evaluations, fine for small d and for tests) or the Hutchinson
estimator (Rademacher probes — O(probes), production path for images).
Integration reuses the adaptive RK45 machinery (fixed-step RK4 here for
carry simplicity; the step count is a knob).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE

Array = jax.Array


def _divergence_exact(fn, x: Array, t: Array) -> Array:
    """∇·fn per sample via the exact jacobian trace. x (B, d)."""

    def single(xi, ti):
        jac = jax.jacfwd(lambda v: fn(v[None, :], ti[None])[0])(xi)
        return jnp.trace(jac)

    return jax.vmap(single)(x, t)


def _divergence_hutchinson(fn, x: Array, t: Array, key: Array,
                           probes: int = 8) -> Array:
    """Unbiased ∇·fn via Rademacher probes: E[εᵀ (∂fn/∂x) ε]."""

    def one_probe(k):
        eps = jax.random.rademacher(k, x.shape, x.dtype)
        _, jvp = jax.jvp(lambda v: fn(v, t), (x,), (eps,))
        return jnp.sum(jvp * eps, axis=tuple(range(1, x.ndim)))

    keys = jax.random.split(key, probes)
    return jnp.mean(jax.vmap(one_probe)(keys), axis=0)


def log_likelihood(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x0: Array,
    *,
    n_steps: int = 200,
    method: str = "exact",  # "exact" (small d) | "hutchinson"
    key: Array | None = None,
    probes: int = 8,
) -> Array:
    """log p₀(x₀) per sample (nats). x0 (B, d...) flattened internally."""
    B = x0.shape[0]
    orig_shape = x0.shape
    d = int(jnp.prod(jnp.asarray(x0.shape[1:])))
    x0f = x0.reshape(B, d)

    def ode_fn(x: Array, t: Array) -> Array:
        # batch-size-polymorphic: the exact-divergence path calls this
        # with single samples (B=1) inside vmap.
        xs = x.reshape((-1,) + orig_shape[1:])
        drift = sde.ode_drift(xs, t, score_fn(xs, t))
        return drift.reshape(x.shape[0], d)

    if method == "exact":
        div = lambda x, t, k: _divergence_exact(ode_fn, x, t)
    elif method == "hutchinson":
        assert key is not None, "hutchinson needs a PRNG key"
        div = lambda x, t, k: _divergence_hutchinson(ode_fn, x, t, k, probes)
    else:
        raise ValueError(method)

    h = (sde.T - sde.t_eps) / n_steps
    base_key = key if key is not None else jax.random.PRNGKey(0)

    def rk4(x, t, k):
        tb = jnp.full((B,), t)
        k1 = ode_fn(x, tb)
        k2 = ode_fn(x + 0.5 * h * k1, tb + 0.5 * h)
        k3 = ode_fn(x + 0.5 * h * k2, tb + 0.5 * h)
        k4 = ode_fn(x + h * k3, tb + h)
        x_new = x + (h / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
        # divergence accumulated at the midpoint (2nd-order quadrature)
        dv = div(x + 0.5 * h * k1, tb + 0.5 * h, k)
        return x_new, dv

    def body(carry, i):
        x, acc, k = carry
        k, sub = jax.random.split(k)
        t = sde.t_eps + i * h
        x, dv = rk4(x, t, sub)
        return (x, acc + h * dv, k), None

    (xT, int_div, _), _ = jax.lax.scan(
        body, (x0f, jnp.zeros((B,)), base_key), jnp.arange(n_steps)
    )

    # prior log-density at t = T: N(0, prior_std² I)
    ps = sde.prior_std()
    logp_T = -0.5 * (
        jnp.sum((xT / ps) ** 2, axis=1) + d * jnp.log(2 * jnp.pi * ps * ps)
    )
    return logp_T + int_div


def bits_per_dim(sde: SDE, score_fn, x0: Array, **kw) -> Array:
    """BPD for 8-bit data living in sde.value_range: the discrete
    likelihood of a bin of width Δ = (hi−lo)/256 is ≈ p(x)·Δ, so
    bpd = −(log p + d·log Δ) / (d·log 2)."""
    d = int(jnp.prod(jnp.asarray(x0.shape[1:])))
    ll = log_likelihood(sde, score_fn, x0, **kw)
    lo, hi = sde.value_range
    delta = (hi - lo) / 256.0
    return -(ll / d + jnp.log(delta)) / jnp.log(2.0)
