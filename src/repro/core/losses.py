"""Continuous-time denoising score matching (paper Eq. 3).

L(θ) = E_{t ~ U[t_eps, T], x0 ~ data, xt ~ p(xt|x0)}
         [ λ(t)/2 · ‖s_θ(xt, t) − ∇_{xt} log p(xt|x0)‖² ]

with λ(t) = 1 / E‖∇ log p(xt|x0)‖² = std(t)², which reduces the inner
term to ‖std·s_θ + z‖² — the numerically stable "noise prediction" form
we use below.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE

Array = jax.Array
ScoreApply = Callable[..., Array]  # (params, x, t) -> score


def dsm_loss(
    sde: SDE,
    apply_fn: ScoreApply,
    params,
    x0: Array,
    key: Array,
) -> Array:
    """Scalar DSM loss over a batch of clean samples ``x0`` (B, ...)."""
    batch = x0.shape[0]
    kt, kz = jax.random.split(key)
    t = jax.random.uniform(kt, (batch,), minval=sde.t_eps, maxval=sde.T)
    z = jax.random.normal(kz, x0.shape, x0.dtype)
    xt = sde.perturb(x0, t, z)
    score = apply_fn(params, xt, t)
    _, std = sde.marginal(t)
    std = std.reshape((-1,) + (1,) * (x0.ndim - 1))
    # λ(t)=std² ⇒ λ/2‖s − (−z/std)‖² = ½‖std·s + z‖².
    per_sample = 0.5 * jnp.sum(
        (std * score + z) ** 2, axis=tuple(range(1, x0.ndim))
    )
    return jnp.mean(per_sample)


def make_loss_fn(sde: SDE, apply_fn: ScoreApply):
    def loss_fn(params, batch: Array, key: Array) -> Array:
        return dsm_loss(sde, apply_fn, params, batch, key)

    return loss_fn
