"""Precision policy: bf16 score evaluation with fp32 error control.

The paper's entire cost model is score-network evaluations — Algorithm 1
spends 2 NFE per step and everything else is cheap elementwise math — so
running the network in bf16 recovers ~2× matmul throughput and ~2× HBM
bandwidth on the ROADMAP's target hardware. The adaptive solver is
uniquely suited to absorb the resulting low-precision score noise: its
mixed tolerance is calibrated to δ ≥ ε_abs = (range)/256 ≈ 4e-3 (paper
Sec. 3.1.3), orders of magnitude above bf16 rounding error at unit
scale, and the step controller rejects any step whose error estimate
trips — the same robustness argument Song et al. 2020a make for inexact
scores. The *control path* (t, h, δ, the scaled-ℓ2 error, the accept
decision, the step-size update) is therefore never downcast: integrator
bookkeeping stays fp32 while only the expensive tensor math runs
reduced (DESIGN.md §8).

``PrecisionPolicy`` names one dtype per seam:

  * ``compute_dtype`` — network activations (and the weight copies the
    matmuls consume);
  * ``param_dtype``   — stored ("master") weights;
  * ``state_dtype``   — the solver carry's x / x_prev tensors;
  * ``control_dtype`` — t / h / δ / error / accept arithmetic, pinned
    to fp32 (constructor-enforced; there is no knob to lower it).

Presets:

  ========== ============= =========== ===========
  preset     compute_dtype param_dtype state_dtype
  ========== ============= =========== ===========
  fp32       float32       float32     float32
  bf16       bfloat16      float32     float32
  bf16_full  bfloat16      bfloat16    bfloat16
  ========== ============= =========== ===========

The class is registered as a *static* pytree (no array leaves), so a
policy rides through ``jax.jit`` closures, dataclass configs, and
``functools.partial`` without tracing. All casts are ``astype``; under
the ``fp32`` preset every cast is a same-dtype no-op, which is what
makes the default bit-identical to the pre-policy code path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

#: preset → (compute_dtype, param_dtype, state_dtype)
PRESETS: Dict[str, tuple] = {
    "fp32": ("float32", "float32", "float32"),
    "bf16": ("bfloat16", "float32", "float32"),
    "bf16_full": ("bfloat16", "bfloat16", "bfloat16"),
}

_CONTROL = "float32"


def _canon(name) -> str:
    return str(jnp.dtype(name).name)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True, init=False)
class PrecisionPolicy:
    """Which dtype lives at which seam of the sampling stack.

    Construct from a preset name (``PrecisionPolicy("bf16")``) with
    optional per-seam overrides (``PrecisionPolicy("bf16",
    state_dtype="bfloat16")``). ``control_dtype`` cannot be overridden:
    the tolerance/step-size/accept arithmetic is always fp32.
    """

    compute_dtype: str
    param_dtype: str
    state_dtype: str
    control_dtype: str

    def __init__(
        self,
        preset: str = "fp32",
        *,
        compute_dtype=None,
        param_dtype=None,
        state_dtype=None,
    ):
        if preset not in PRESETS:
            raise ValueError(
                f"unknown precision preset {preset!r}; have {sorted(PRESETS)}"
            )
        c, p, s = PRESETS[preset]
        object.__setattr__(self, "compute_dtype", _canon(compute_dtype or c))
        object.__setattr__(self, "param_dtype", _canon(param_dtype or p))
        object.__setattr__(self, "state_dtype", _canon(state_dtype or s))
        object.__setattr__(self, "control_dtype", _CONTROL)

    # --- jnp dtypes per seam ------------------------------------------
    @property
    def compute(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def param(self):
        return jnp.dtype(self.param_dtype)

    @property
    def state(self):
        return jnp.dtype(self.state_dtype)

    @property
    def control(self):
        return jnp.dtype(self.control_dtype)

    @property
    def name(self) -> str:
        """Preset name when the dtypes match one, else 'custom'."""
        mine = (self.compute_dtype, self.param_dtype, self.state_dtype)
        for preset, dts in PRESETS.items():
            if mine == tuple(_canon(d) for d in dts):
                return preset
        return "custom"

    @property
    def is_fp32(self) -> bool:
        return self.name == "fp32"

    # --- casts ---------------------------------------------------------
    def to_compute(self, x: Array) -> Array:
        return x.astype(self.compute)

    def to_state(self, x: Array) -> Array:
        return x.astype(self.state)

    def to_control(self, x: Array) -> Array:
        return x.astype(self.control)

    def _cast_tree(self, tree, dtype):
        def leaf(a):
            return a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a

        return jax.tree_util.tree_map(leaf, tree)

    def cast_params(self, params):
        """Floating leaves → ``param_dtype`` (storage / master weights).

        Integer leaves (token tables, counters) pass through untouched.
        Works on concrete arrays and on ``ShapeDtypeStruct`` trees under
        ``jax.eval_shape``.
        """
        return self._cast_tree(params, self.param)

    def params_for_compute(self, params):
        """Floating leaves → ``compute_dtype`` — the copy the matmuls
        consume. XLA fuses the cast into the first use, so the master
        copy is unchanged and no second resident copy persists."""
        return self._cast_tree(params, self.compute)

    # --- the score-fn seam ---------------------------------------------
    def wrap_score_fn(
        self, score_fn: Callable[[Array, Array], Array]
    ) -> Callable[[Array, Array], Array]:
        """Cast x → ``compute_dtype`` on entry, output → ``state_dtype``
        on exit. t passes through untouched (control path, fp32). Under
        the fp32 preset both casts are no-ops, so wrapping is free."""

        def wrapped(x: Array, t: Array) -> Array:
            return score_fn(self.to_compute(x), t).astype(self.state)

        return wrapped

    # --- reporting ------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        """JSON-friendly record for dry-run / benchmark artifacts."""
        return {
            "policy": self.name,
            "compute_dtype": self.compute_dtype,
            "param_dtype": self.param_dtype,
            "state_dtype": self.state_dtype,
            "control_dtype": self.control_dtype,
            "compute_itemsize": int(self.compute.itemsize),
            "param_itemsize": int(self.param.itemsize),
            "state_itemsize": int(self.state.itemsize),
        }


def resolve_policy(policy: Optional[Any]) -> PrecisionPolicy:
    """None | preset name | PrecisionPolicy → PrecisionPolicy."""
    if policy is None:
        return PrecisionPolicy("fp32")
    if isinstance(policy, PrecisionPolicy):
        return policy
    if isinstance(policy, str):
        return PrecisionPolicy(policy)
    raise TypeError(
        f"precision must be a preset name or PrecisionPolicy, got {policy!r}"
    )
