"""High-level sampling API: prior draw → solver → denoised samples.

``sample()`` is the single entry point the examples, benchmarks, and the
serving path use. It is jit-friendly (everything inside is lax control
flow) and mesh-aware (DESIGN.md §3): pass ``mesh=`` to shard the batch
axis of the prior draw, the solver's while-loop carry, and every score-
network forward pass over the mesh's data axes — batched reverse-SDE
sampling is embarrassingly data-parallel, so this is pure throughput.
Samples are bit-identical sharded vs unsharded for a fixed key.

``solve_in_chunks()`` is the resumable form (DESIGN.md §7): the same
adaptive solve, but executed as a chain of ``solve_chunk`` calls of at
most ``max_sync_iters`` device iterations each, with control returning
to the host between chunks. Bit-identical to ``sample(method=
'adaptive')`` for a fixed key — the serving loop uses exactly this
yield structure to retire converged slots mid-flight.
"""

from __future__ import annotations

import functools
import inspect
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sde import SDE
from repro.core.solvers import SolveResult, get_solver
from repro.core.solvers.adaptive import (
    AdaptiveConfig, finalize, init_carry, resolve_config, solve_chunk,
)

Array = jax.Array


@functools.lru_cache(maxsize=None)
def _accepts_sharding(solver: Callable) -> bool:
    """Cached ``inspect.signature`` probe — solvers are module-level
    functions, so the registry's handful of entries is cached forever
    instead of re-inspected on every ``sample()`` call."""
    return "sharding" in inspect.signature(solver).parameters


@functools.lru_cache(maxsize=8)
def _finalize_jit(sde, score_fn):
    """Jitted ``finalize`` for an (sde, score_fn) pair. Repeated calls
    with the same pair — the serving loop's pattern — reuse one trace
    instead of retracing a fresh lambda per call; the small LRU bound
    keeps one-shot closures (and the params they capture) from being
    retained for the process lifetime the way a global jit with static
    args would. ``conditioner`` is static like ``precision``: both are
    hashable, array-free policy objects (DESIGN.md §8/§9)."""
    return jax.jit(
        functools.partial(finalize, sde, score_fn),
        static_argnames=("denoise", "precision", "conditioner"),
    )


@functools.lru_cache(maxsize=8)
def _chunk_jit(sde, score_fn, cfg, max_sync_iters, sharding):
    """Jitted ``solve_chunk`` closure for one solve configuration.

    ``solve_in_chunks`` used to build ``jax.jit(lambda c: ...)`` fresh on
    every call — a new Python callable each time, so jax's trace cache
    never hit and every call paid a full retrace+compile even with
    identical (config, carry structure, mesh). Keying the closure on the
    hashable configuration tuple instead makes repeat calls — the
    benchmark/serving pattern — reuse one compiled chunk; the carry's
    shape struct is then deduplicated by jax's own cache under this
    single stable callable. Bounded like ``_finalize_jit`` so one-shot
    configurations don't pin their captures forever.
    """
    return jax.jit(
        functools.partial(
            solve_chunk, sde, score_fn,
            max_sync_iters=max_sync_iters, config=cfg, sharding=sharding,
        )
    )


def sample(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    shape,
    key: Array,
    *,
    method: str = "adaptive",
    denoise: bool = True,
    mesh=None,
    cond=None,
    **solver_kwargs,
) -> SolveResult:
    """Generate ``shape[0]`` samples of shape ``shape[1:]`` — the single
    entry point tying the paper↔code map together (DESIGN.md §1, §3).

    Args:
      sde: forward process whose reverse we solve.
      score_fn: s(x, t) with t a (B,) vector (with a ``ClassifierFree``
        conditioner: s(x, t, y) — DESIGN.md §9).
      shape: full batch shape, e.g. (64, 32, 32, 3).
      method: 'adaptive' | 'em' | 'pc' | 'ode' | 'ddim'.
      mesh: optional ``jax.sharding.Mesh``; shards the batch axis of the
        prior draw and (for solvers that accept a ``sharding`` kwarg) the
        whole solver loop over the mesh's data axes. Falls back to
        replication when ``shape[0]`` does not divide the data axes.
      cond: optional per-sample condition payload (DESIGN.md §9),
        consumed by the ``conditioner`` in ``AdaptiveConfig`` (pass
        ``config=AdaptiveConfig(conditioner=...)`` or the
        ``conditioner=...`` kwarg override). Adaptive-solver-only; for
        the fixed-grid baselines use the functional
        ``repro.core.guidance.classifier_free`` transform, which needs
        no solver support.
    """
    k_prior, k_solve = jax.random.split(key)
    x_init = sde.prior_sample(k_prior, shape)
    solver = get_solver(method)
    if cond is not None:
        solver_kwargs["cond"] = cond
    if mesh is not None:
        from repro.parallel.sharding import sample_state_shardings

        arr_s, _, _ = sample_state_shardings(mesh, shape[0], len(shape))
        x_init = jax.lax.with_sharding_constraint(x_init, arr_s)
        if _accepts_sharding(solver):
            solver_kwargs.setdefault("sharding", arr_s)
    return solver(sde, score_fn, x_init, k_solve, denoise=denoise, **solver_kwargs)


def solve_in_chunks(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    shape,
    key: Array,
    *,
    max_sync_iters: int,
    config: AdaptiveConfig | None = None,
    denoise: bool = True,
    mesh=None,
    cond=None,
    on_sync: Callable | None = None,
    chunk_fn: Callable | None = None,
    **overrides,
) -> SolveResult:
    """Adaptive solve as a host-driven chain of bounded device chunks
    (DESIGN.md §7).

    Each chunk runs at most ``max_sync_iters`` Algorithm-1 iterations
    device-side, then yields the ``SolverCarry`` to the host;
    ``on_sync(carry)`` (if given) observes every intermediate carry —
    the hook the serving loop replaces with slot compaction. The final
    result is bit-identical to the monolithic ``sample(method=
    'adaptive')`` for the same key.

    The default chunk closure is cached per (sde, score_fn, config,
    max_sync_iters, sharding) — repeat calls with the same configuration
    reuse one compiled chunk instead of retracing (``_chunk_jit``).
    Callers needing a custom step (e.g. the serving loop's
    ``make_sample_step``, which folds in network params) pass
    ``chunk_fn`` — a prebuilt jitted ``carry -> carry`` chunk.

    ``cond`` is the optional per-sample condition payload for
    ``cfg.conditioner`` (DESIGN.md §9); it rides in the carry through
    every chunk, exactly as the serving loop's compaction expects.
    """
    cfg = resolve_config(config, overrides)
    k_prior, k_solve = jax.random.split(key)
    x_init = sde.prior_sample(k_prior, shape)
    sharding = None
    if mesh is not None:
        from repro.parallel.sharding import sample_state_shardings

        sharding, _, _ = sample_state_shardings(mesh, shape[0], len(shape))
        x_init = jax.lax.with_sharding_constraint(x_init, sharding)
    carry = init_carry(sde, x_init, k_solve, config=cfg, sharding=sharding,
                       cond=cond)
    chunk = chunk_fn or _chunk_jit(sde, score_fn, cfg, max_sync_iters,
                                   sharding)
    # loop on the carry's own (already device-reduced) done mask — one
    # scalar crosses to the host per chunk, never the full (B,) t vector
    while not bool(carry.done.all()) and int(carry.iterations) < cfg.max_iters:
        carry = chunk(carry)
        if on_sync is not None:
            on_sync(carry)
    return _finalize_jit(sde, score_fn)(carry, denoise=denoise,
                                        precision=cfg.precision,
                                        conditioner=cfg.conditioner)


def sample_chunked(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    n_samples: int,
    sample_shape,
    key: Array,
    *,
    chunk: int = 64,
    method: str = "adaptive",
    mesh=None,
    **solver_kwargs,
):
    """Generate many samples in fixed-size chunks (host loop, jit inner).

    Returns (samples (N, ...), mean NFE) as host numpy — used by the
    FID-style benchmarks (DESIGN.md §6) that need tens of thousands of
    samples. ``mesh`` shards each chunk's batch axis, as in ``sample``.

    Two throughput details matter at that scale: the chunks are already
    host arrays, so they are joined with ``np.concatenate`` (the old
    ``jnp.concatenate`` round-tripped every chunk *back* to the device
    and materialized the full (N, ...) result there — at FID scale that
    re-upload both doubled transfer volume and could OOM device memory);
    and each chunk's ``device_get`` is issued only after the *next*
    chunk has been dispatched, so the d2h copy of chunk i overlaps the
    device compute of chunk i+1 instead of serializing with it.
    """
    fn = jax.jit(
        lambda k: sample(
            sde, score_fn, (chunk,) + tuple(sample_shape), k,
            method=method, mesh=mesh, **solver_kwargs,
        )
    )
    outs, nfes = [], []
    pending = None  # previous chunk's (x, nfe), still device-resident
    n_chunks = (n_samples + chunk - 1) // chunk
    for i in range(n_chunks):
        key, sub = jax.random.split(key)
        res = fn(sub)  # async dispatch: device starts chunk i now
        if pending is not None:  # ...while chunk i-1 copies out
            outs.append(jax.device_get(pending[0]))
            nfes.append(jax.device_get(pending[1]))
        pending = (res.x, res.nfe)
    outs.append(jax.device_get(pending[0]))
    nfes.append(jax.device_get(pending[1]))
    x = np.concatenate(outs)[:n_samples]
    nfe = np.concatenate(nfes)[:n_samples]
    return x, float(nfe.mean())
