"""High-level sampling API: prior draw → solver → denoised samples.

``sample()`` is the single entry point the examples, benchmarks, and the
serving path use. It is jit-friendly (everything inside is lax control
flow) and mesh-aware (DESIGN.md §3): pass ``mesh=`` to shard the batch
axis of the prior draw, the solver's while-loop carry, and every score-
network forward pass over the mesh's data axes — batched reverse-SDE
sampling is embarrassingly data-parallel, so this is pure throughput.
Samples are bit-identical sharded vs unsharded for a fixed key.
"""

from __future__ import annotations

import inspect
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE
from repro.core.solvers import SolveResult, get_solver

Array = jax.Array


def sample(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    shape,
    key: Array,
    *,
    method: str = "adaptive",
    denoise: bool = True,
    mesh=None,
    **solver_kwargs,
) -> SolveResult:
    """Generate ``shape[0]`` samples of shape ``shape[1:]``.

    Args:
      sde: forward process whose reverse we solve.
      score_fn: s(x, t) with t a (B,) vector.
      shape: full batch shape, e.g. (64, 32, 32, 3).
      method: 'adaptive' | 'em' | 'pc' | 'ode' | 'ddim'.
      mesh: optional ``jax.sharding.Mesh``; shards the batch axis of the
        prior draw and (for solvers that accept a ``sharding`` kwarg) the
        whole solver loop over the mesh's data axes. Falls back to
        replication when ``shape[0]`` does not divide the data axes.
    """
    k_prior, k_solve = jax.random.split(key)
    x_init = sde.prior_sample(k_prior, shape)
    solver = get_solver(method)
    if mesh is not None:
        from repro.parallel.sharding import sample_state_shardings

        arr_s, _, _ = sample_state_shardings(mesh, shape[0], len(shape))
        x_init = jax.lax.with_sharding_constraint(x_init, arr_s)
        if "sharding" in inspect.signature(solver).parameters:
            solver_kwargs.setdefault("sharding", arr_s)
    return solver(sde, score_fn, x_init, k_solve, denoise=denoise, **solver_kwargs)


def sample_chunked(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    n_samples: int,
    sample_shape,
    key: Array,
    *,
    chunk: int = 64,
    method: str = "adaptive",
    mesh=None,
    **solver_kwargs,
):
    """Generate many samples in fixed-size chunks (host loop, jit inner).

    Returns (samples (N, ...), mean NFE) — used by the FID-style
    benchmarks that need tens of thousands of samples. ``mesh`` shards
    each chunk's batch axis, as in ``sample``.
    """
    fn = jax.jit(
        lambda k: sample(
            sde, score_fn, (chunk,) + tuple(sample_shape), k,
            method=method, mesh=mesh, **solver_kwargs,
        )
    )
    outs, nfes = [], []
    n_chunks = (n_samples + chunk - 1) // chunk
    for i in range(n_chunks):
        key, sub = jax.random.split(key)
        res = fn(sub)
        outs.append(jax.device_get(res.x))
        nfes.append(jax.device_get(res.nfe))
    x = jnp.concatenate([jnp.asarray(o) for o in outs])[:n_samples]
    nfe = jnp.concatenate([jnp.asarray(v) for v in nfes])[:n_samples]
    return x, float(jnp.mean(nfe))
