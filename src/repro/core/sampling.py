"""High-level sampling API: prior draw → solver → denoised samples.

``sample()`` is the single entry point the examples, benchmarks, and the
serving path use. It is jit-friendly (everything inside is lax control
flow) and pjit-friendly: shard the batch axis of the returned samples by
passing ``out_shardings`` to an outer ``jax.jit``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE
from repro.core.solvers import SolveResult, get_solver

Array = jax.Array


def sample(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    shape,
    key: Array,
    *,
    method: str = "adaptive",
    denoise: bool = True,
    **solver_kwargs,
) -> SolveResult:
    """Generate ``shape[0]`` samples of shape ``shape[1:]``.

    Args:
      sde: forward process whose reverse we solve.
      score_fn: s(x, t) with t a (B,) vector.
      shape: full batch shape, e.g. (64, 32, 32, 3).
      method: 'adaptive' | 'em' | 'pc' | 'ode' | 'ddim'.
    """
    k_prior, k_solve = jax.random.split(key)
    x_init = sde.prior_sample(k_prior, shape)
    solver = get_solver(method)
    return solver(sde, score_fn, x_init, k_solve, denoise=denoise, **solver_kwargs)


def sample_chunked(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    n_samples: int,
    sample_shape,
    key: Array,
    *,
    chunk: int = 64,
    method: str = "adaptive",
    **solver_kwargs,
):
    """Generate many samples in fixed-size chunks (host loop, jit inner).

    Returns (samples (N, ...), mean NFE) — used by the FID-style
    benchmarks that need tens of thousands of samples.
    """
    fn = jax.jit(
        lambda k: sample(
            sde, score_fn, (chunk,) + tuple(sample_shape), k,
            method=method, **solver_kwargs,
        )
    )
    outs, nfes = [], []
    n_chunks = (n_samples + chunk - 1) // chunk
    for i in range(n_chunks):
        key, sub = jax.random.split(key)
        res = fn(sub)
        outs.append(jax.device_get(res.x))
        nfes.append(jax.device_get(res.nfe))
    x = jnp.concatenate([jnp.asarray(o) for o in outs])[:n_samples]
    nfe = jnp.concatenate([jnp.asarray(v) for v in nfes])[:n_samples]
    return x, float(jnp.mean(nfe))
