"""Forward diffusion processes (SDEs) for score-based generative models.

Implements the two processes used by the paper (Song et al. 2020a
conventions):

  VE :  dx = sqrt(d[sigma^2(t)]/dt) dw,   sigma(t) = smin (smax/smin)^t
  VP :  dx = -1/2 beta(t) x dt + sqrt(beta(t)) dw,
        beta(t) = bmin + t (bmax - bmin)

plus sub-VP (Song et al. 2020a eq. 29) as an extra, and the shared
machinery every solver needs: reverse-SDE drift, probability-flow ODE
drift, Gaussian transition kernels (for single-step forward corruption
and the DSM training target), priors, and Tweedie denoising variance.

All methods are shape-polymorphic: ``t`` may be a scalar or a batch
vector ``(B,)`` broadcast against state ``x`` of shape ``(B, ...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
ScoreFn = Callable[[Array, Array], Array]  # (x, t) -> score, t shape (B,) or ()


def _bcast(t: Array, x: Array) -> Array:
    """Broadcast a per-sample scalar ``t`` against state ``x``."""
    t = jnp.asarray(t)
    if t.ndim == 0:
        return t
    return t.reshape(t.shape + (1,) * (x.ndim - t.ndim))


@dataclasses.dataclass(frozen=True)
class SDE:
    """Abstract forward diffusion dx = f(x,t) dt + g(t) dw on t in [0, 1]."""

    #: final time of the forward process
    T: float = 1.0
    #: numerical epsilon at which reverse integration stops (paper App. D)
    t_eps: float = 1e-3

    # --- forward process ------------------------------------------------
    def drift(self, x: Array, t: Array) -> Array:
        raise NotImplementedError

    def diffusion(self, t: Array) -> Array:
        raise NotImplementedError

    def drift_coeff(self, t: Array) -> Array:
        """a(t) such that f(x, t) = a(t) * x (all our drifts are linear).

        Used by the fused Pallas solver-step kernel, which wants the
        step expressed with per-sample scalar coefficients.
        """
        raise NotImplementedError

    # --- transition kernel p(x_t | x_0) = N(mean_scale*x0, std^2 I) ------
    def marginal(self, t: Array) -> Tuple[Array, Array]:
        """Return (mean_scale(t), std(t)) of the transition kernel."""
        raise NotImplementedError

    def perturb(self, x0: Array, t: Array, z: Array) -> Array:
        """Single-step forward corruption x_t = m(t) x0 + s(t) z."""
        m, s = self.marginal(t)
        return _bcast(m, x0) * x0 + _bcast(s, x0) * z

    def kernel_score(self, xt: Array, x0: Array, t: Array) -> Array:
        """∇_{x_t} log p(x_t | x_0) — the DSM regression target."""
        m, s = self.marginal(t)
        return -(xt - _bcast(m, x0) * x0) / _bcast(s, x0) ** 2

    # --- prior at t = T ---------------------------------------------------
    def prior_std(self) -> float:
        raise NotImplementedError

    def prior_sample(self, key: Array, shape) -> Array:
        return jax.random.normal(key, shape) * self.prior_std()

    # --- reverse-time processes ------------------------------------------
    def reverse_drift(self, x: Array, t: Array, score: Array) -> Array:
        """Drift of the reverse SDE: f(x,t) - g(t)^2 score."""
        g = _bcast(self.diffusion(t), x)
        return self.drift(x, t) - g * g * score

    def ode_drift(self, x: Array, t: Array, score: Array) -> Array:
        """Drift of the probability-flow ODE: f(x,t) - 1/2 g(t)^2 score."""
        g = _bcast(self.diffusion(t), x)
        return self.drift(x, t) - 0.5 * g * g * score

    # --- training ----------------------------------------------------------
    def loss_weight(self, t: Array) -> Array:
        """λ(t) ∝ 1 / E‖∇ log p(x_t|x_0)‖² = std(t)^2 (paper Sec. 2.1)."""
        _, s = self.marginal(t)
        return s**2

    # --- Tweedie denoising (paper App. D) ----------------------------------
    def tweedie_denoise(self, x: Array, score: Array) -> Array:
        """Exact Tweedie posterior mean at t = t_eps.

        E[x0 | x_t] = (x_t + std(t)² · ∇log p_t(x_t)) / m(t).

        Note an erratum vs. the paper's Appendix D, which states
        Var[x(t)|x(0)] = 1 for VP: that constant is the t=1 variance, and
        plugging it in at t = t_eps diverges under an exact score (we
        verified: it triples the sample std on an analytic Gaussian).
        The paper's pretrained nets are inexact near t=0, which masked
        this; we use the exact formula. For VE (m=1, std≈σ_min) the two
        agree with the paper's σ_min² = 1e-4 value.
        """
        m, s = self.marginal(jnp.asarray(self.t_eps, jnp.float32))
        return (x + (s * s) * score) / m

    # --- solver calibration --------------------------------------------------
    @property
    def value_range(self) -> Tuple[float, float]:
        """(y_min, y_max) of data as trained; sets ε_abs = (ymax-ymin)/256."""
        raise NotImplementedError

    @property
    def abs_tolerance(self) -> float:
        lo, hi = self.value_range
        return (hi - lo) / 256.0


@dataclasses.dataclass(frozen=True)
class VESDE(SDE):
    """Variance-exploding process. Data range [0, 1] by convention."""

    sigma_min: float = 0.01
    sigma_max: float = 50.0
    t_eps: float = 1e-5

    def sigma(self, t: Array) -> Array:
        return self.sigma_min * (self.sigma_max / self.sigma_min) ** t

    def drift(self, x: Array, t: Array) -> Array:
        return jnp.zeros_like(x)

    def drift_coeff(self, t: Array) -> Array:
        return jnp.zeros_like(jnp.asarray(t, jnp.float32))

    def diffusion(self, t: Array) -> Array:
        # g(t) = sigma(t) * sqrt(2 log(smax/smin))
        return self.sigma(t) * jnp.sqrt(
            2.0 * jnp.log(self.sigma_max / self.sigma_min)
        )

    def marginal(self, t: Array) -> Tuple[Array, Array]:
        return jnp.ones_like(jnp.asarray(t, jnp.float32)), self.sigma(t)

    def prior_std(self) -> float:
        return self.sigma_max

    @property
    def value_range(self) -> Tuple[float, float]:
        return (0.0, 1.0)


@dataclasses.dataclass(frozen=True)
class VPSDE(SDE):
    """Variance-preserving process. Data range [-1, 1] by convention."""

    beta_min: float = 0.1
    beta_max: float = 20.0
    t_eps: float = 1e-3

    def beta(self, t: Array) -> Array:
        return self.beta_min + jnp.asarray(t) * (self.beta_max - self.beta_min)

    def _int_beta(self, t: Array) -> Array:
        t = jnp.asarray(t)
        return self.beta_min * t + 0.5 * t**2 * (self.beta_max - self.beta_min)

    def drift(self, x: Array, t: Array) -> Array:
        return -0.5 * _bcast(self.beta(t), x) * x

    def drift_coeff(self, t: Array) -> Array:
        return -0.5 * self.beta(t)

    def diffusion(self, t: Array) -> Array:
        return jnp.sqrt(self.beta(t))

    def marginal(self, t: Array) -> Tuple[Array, Array]:
        ib = self._int_beta(t)
        mean_scale = jnp.exp(-0.5 * ib)
        std = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(-ib), 1e-12))
        return mean_scale, std

    def prior_std(self) -> float:
        return 1.0

    @property
    def value_range(self) -> Tuple[float, float]:
        return (-1.0, 1.0)


@dataclasses.dataclass(frozen=True)
class SubVPSDE(VPSDE):
    """sub-VP process of Song et al. 2020a (extra beyond the paper)."""

    def diffusion(self, t: Array) -> Array:
        ib = self._int_beta(t)
        return jnp.sqrt(self.beta(t) * (1.0 - jnp.exp(-2.0 * ib)))

    def marginal(self, t: Array) -> Tuple[Array, Array]:
        ib = self._int_beta(t)
        mean_scale = jnp.exp(-0.5 * ib)
        std = jnp.maximum(1.0 - jnp.exp(-ib), 1e-12)
        return mean_scale, std


def get_sde(name: str, **kw) -> SDE:
    name = name.lower()
    if name == "ve":
        return VESDE(**kw)
    if name == "vp":
        return VPSDE(**kw)
    if name in ("subvp", "sub-vp"):
        return SubVPSDE(**kw)
    raise ValueError(f"unknown SDE '{name}' (want 've'|'vp'|'subvp')")
