"""Solver suite: the paper's adaptive solver plus every baseline it compares to."""

from .base import (
    SolveResult,
    available_solvers,
    get_solver,
    register_solver,
    solver_nfe_per_iteration,
)
from .euler_maruyama import euler_maruyama
from .adaptive import (
    AdaptiveConfig,
    ForwardAdaptiveConfig,
    SolverCarry,
    adaptive,
    adaptive_forward,
    events_pending,
    finalize,
    init_carry,
    resolve_config,
    solve_chunk,
    solve_horizons,
)
from .momentum import DEFAULT_BETA, momentum
from .heun import heun
from .predictor_corrector import predictor_corrector, predictor_corrector_hmc
from .probability_flow import probability_flow_rk45
from .ddim import ddim

__all__ = [
    "SolveResult",
    "SolverCarry",
    "available_solvers",
    "get_solver",
    "register_solver",
    "solver_nfe_per_iteration",
    "events_pending",
    "solve_horizons",
    "euler_maruyama",
    "AdaptiveConfig",
    "ForwardAdaptiveConfig",
    "adaptive",
    "adaptive_forward",
    "finalize",
    "init_carry",
    "resolve_config",
    "solve_chunk",
    "momentum",
    "DEFAULT_BETA",
    "heun",
    "predictor_corrector",
    "predictor_corrector_hmc",
    "probability_flow_rk45",
    "ddim",
]
