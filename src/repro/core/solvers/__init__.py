"""Solver suite: the paper's adaptive solver plus every baseline it compares to."""

from .base import SolveResult, available_solvers, get_solver, register_solver
from .euler_maruyama import euler_maruyama
from .adaptive import (
    AdaptiveConfig,
    ForwardAdaptiveConfig,
    SolverCarry,
    adaptive,
    adaptive_forward,
    finalize,
    init_carry,
    resolve_config,
    solve_chunk,
)
from .predictor_corrector import predictor_corrector
from .probability_flow import probability_flow_rk45
from .ddim import ddim

__all__ = [
    "SolveResult",
    "SolverCarry",
    "available_solvers",
    "get_solver",
    "register_solver",
    "euler_maruyama",
    "AdaptiveConfig",
    "ForwardAdaptiveConfig",
    "adaptive",
    "adaptive_forward",
    "finalize",
    "init_carry",
    "resolve_config",
    "solve_chunk",
    "predictor_corrector",
    "probability_flow_rk45",
    "ddim",
]
