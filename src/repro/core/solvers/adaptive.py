"""The paper's contribution: dynamic-step-size extrapolated SDE solver.

Algorithm 1 (reverse diffusion, per-sample adaptive step sizes) and
Algorithm 2 (general forward-time diffusion with the Itô s=±1 trick).

TPU adaptation (DESIGN.md §3): the whole adaptive loop is a device-side
``jax.lax.while_loop`` whose carry holds per-sample (t, h, x, x'_prev,
nfe, accept/reject counters). The score network receives a *vector* of
per-sample times, so samples at different t share one batched forward
pass; finished samples ride along with masked (frozen) state, exactly
the "wait for all images to converge" semantics of paper Sec. 3.1.5 but
without host round-trips.

Resumable solve (DESIGN.md §7): the loop state is the public
``SolverCarry`` pytree and the loop itself is exposed as
``solve_chunk(carry, max_sync_iters)`` — up to ``max_sync_iters`` body
iterations device-side, then control returns to the host with the carry
intact. Chaining chunks is bit-identical to the monolithic solve
(``adaptive()`` is itself one maximal chunk), which is what lets the
serving loop retire converged slots and admit fresh requests at every
sync horizon instead of keeping stragglers' seatmates frozen.

The post-score elementwise arithmetic of one step (two Euler forms,
extrapolated average, mixed tolerance, scaled ℓ2 error) is available in
two numerically identical implementations:

  * pure jnp (default; what XLA fuses on its own), and
  * the fused Pallas kernel ``repro.kernels.solver_step`` (one HBM pass,
    in-VMEM error reduction) selected with ``use_fused_kernel=True``.

Conditioning seam (DESIGN.md §9): ``AdaptiveConfig.conditioner`` plus
the carry's per-slot ``cond`` payload turn the same loop into guided /
inpainting / class-conditional sampling — the conditioner transforms
the score field inside the loop body and projects observed data after
every accepted step; ``conditioner=None`` is bit-identical to the
unconditional solver.

Precision policy (DESIGN.md §8): ``AdaptiveConfig.precision`` selects a
``repro.core.precision.PrecisionPolicy``. The carry's x / x_prev live in
``state_dtype`` and the score network runs in ``compute_dtype``, while
the *control path* — t, h, the mixed tolerance, the scaled-ℓ2 error,
the accept decision, and the step-size update — always computes in
fp32: the step controller is what absorbs low-precision score noise, so
it is never itself downcast. The default ``"fp32"`` policy makes every
cast a same-dtype no-op and is bit-identical to the unpoliced solver.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.guidance import Conditioner, cond_batch
from repro.core.precision import PrecisionPolicy, resolve_policy
from repro.core.sde import SDE
from repro.core.tolerance import (
    mixed_tolerance,
    next_step_size,
    scaled_error_l2,
    scaled_error_linf,
)
from repro.observability.telemetry import (
    StepTelemetry, init_telemetry, record_step,
)
from .base import SolveResult, register_solver

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Hyper-parameters of Algorithm 1 (defaults = paper defaults)."""

    eps_rel: float = 0.01
    eps_abs: Optional[float] = None  # None → sde.abs_tolerance (image-calibrated)
    h_init: float = 0.01
    safety: float = 0.9  # θ
    r_exponent: float = 0.9  # r
    error_norm: str = "l2"  # "l2" (paper) | "linf" (ablation)
    prev_tolerance: bool = True  # δ(x', x'_prev) (Eq.5) vs δ(x') (Eq.4)
    extrapolate: bool = True  # accept x'' (paper) vs x' (ablation → EM-like)
    max_iters: int = 100_000
    use_fused_kernel: bool = False
    #: precision preset name or PrecisionPolicy (DESIGN.md §8); "fp32"
    #: (the default) is bit-identical to the policy-free solver
    precision: "str | PrecisionPolicy" = "fp32"
    #: score-field conditioner (DESIGN.md §9) — the *static* half of a
    #: controlled-generation scenario (guidance scale, projection rule);
    #: the per-sample payload rides in ``SolverCarry.cond``. None (the
    #: default) is bit-identical to the unconditional solver.
    conditioner: Optional[Conditioner] = None
    #: heavy-ball coefficient β of the ``momentum`` solver family
    #: (DESIGN.md §11): both proposals gain β·(x − x_prev), the last
    #: *accepted* displacement, and x_prev switches from "last accepted
    #: low-order proposal" to "last accepted state" so that displacement
    #: is well-defined. β rides outside the embedded error estimate (a
    #: transport term shared by x' and x̃) — the W2 conformance gate is
    #: what adjudicates it. 0.0 (the default) is bit-identical to the
    #: plain Algorithm-1 solver.
    momentum: float = 0.0
    #: integrate the probability-flow ODE instead of the reverse SDE
    #: (the ``heun`` solver family, DESIGN.md §11): the score
    #: coefficients halve (½g² drift), the diffusion noise vanishes and
    #: the main noise draw is skipped entirely (the PRNG stream is not
    #: advanced), which turns the paper's extrapolated pair (x', x'')
    #: into Heun's trapezoidal method with an embedded Euler error
    #: estimate — an adaptive 2nd-order ODE solver with *per-sample*
    #: step sizes (unlike the batch-global RK45 baseline). False (the
    #: default) is bit-identical to the SDE solver.
    probability_flow: bool = False
    #: step-telemetry ring capacity (DESIGN.md §15): > 0 makes
    #: ``init_carry`` attach a ``StepTelemetry`` ring of that many
    #: records per slot, and the loop body then writes each iteration's
    #: (t, h, err, accept) snapshot into it device-side. 0 (the
    #: default) keeps the carry's pre-telemetry treedef — the
    #: telemetry-off program is bitwise identical to the untelemetered
    #: solver on every path.
    telemetry_capacity: int = 0


def _expand(v: Array, x: Array) -> Array:
    """(B,) → (B, 1, 1, ...) to broadcast against x."""
    return v.reshape(v.shape + (1,) * (x.ndim - 1))


def _step_math_jnp(x, x_prime, score2, z, x_prev, e0, d1, d2, cfg,
                   eps_abs, eps_rel):
    """x̃, x'' and the scaled error — reference path (see kernels/solver_step).

    e0 = h·a(t−h); d1 = h·g(t−h)²; d2 = √h·g(t−h); all shape (B,).
    x̃  = x − e0·x' + d1·score2 + d2·z   (drift evaluated at x', Alg. 1)
    x'' = ½ (x' + x̃)

    Tensor operands arrive in the policy's state dtype; the math runs in
    fp32 (error control is fp32 by design, and under the fp32 policy the
    upcasts are no-ops). Returns (x'' fp32, err fp32); the caller casts
    the accepted proposal back to the state dtype.

    ``eps_abs``/``eps_rel`` are either Python floats (the static-config
    path) or (B,) fp32 arrays (per-slot tolerance, DESIGN.md §14) —
    expanded to broadcast against the state. With every slot at the same
    value the broadcast arithmetic is bitwise identical to the float
    path (same fp32 elementwise ops).
    """
    x, x_prime, score2, z, x_prev = (
        a.astype(jnp.float32) for a in (x, x_prime, score2, z, x_prev)
    )
    if isinstance(eps_abs, jax.Array):
        eps_abs = _expand(eps_abs, x)
    if isinstance(eps_rel, jax.Array):
        eps_rel = _expand(eps_rel, x)
    x_tilde = x - _expand(e0, x) * x_prime + _expand(d1, x) * score2 + _expand(d2, x) * z
    x_high = 0.5 * (x_prime + x_tilde)
    delta = mixed_tolerance(
        x_prime, x_prev if cfg.prev_tolerance else None, eps_abs, eps_rel
    )
    if cfg.error_norm == "l2":
        err = scaled_error_l2(x_prime, x_high, delta)
    elif cfg.error_norm == "linf":
        err = scaled_error_linf(x_prime, x_high, delta)
    else:
        raise ValueError(f"unknown error_norm {cfg.error_norm!r}")
    return x_high, err


def _step_math_fused(x, x_prime, score2, z, x_prev, e0, d1, d2, cfg,
                     eps_abs, eps_rel):
    """Fused Pallas path. Operands stay in the state dtype (bf16 under
    ``bf16_full`` — that is the HBM-bandwidth win); the kernel upcasts
    each VMEM tile to fp32, accumulates the scaled-ℓ2 residual in fp32,
    and emits x'' in the operand dtype with e2 always fp32. Per-slot
    (B,) tolerances dispatch to the vector-ε kernel variant."""
    from repro.kernels.solver_step import ops as fused

    if cfg.error_norm != "l2":
        raise ValueError("fused kernel implements the paper's ℓ2 norm only")
    return fused.error_step(
        x, x_prime, score2, z, x_prev, e0, d1, d2,
        eps_abs=eps_abs,
        eps_rel=eps_rel,
        use_prev=cfg.prev_tolerance,
    )


def _step_math_fused_sharded(
    x, x_prime, score2, z, x_prev, e0, d1, d2, cfg, eps_abs, eps_rel,
    *, sharding
):
    """Fused path under a batch-sharded mesh: shard_map'd Pallas kernel
    with per-shard in-VMEM error reduction (DESIGN.md §3)."""
    from repro.kernels.solver_step import ops as fused

    if cfg.error_norm != "l2":
        raise ValueError("fused kernel implements the paper's ℓ2 norm only")
    axes = sharding.spec[0]
    return fused.sharded_error_step(
        x, x_prime, score2, z, x_prev, e0, d1, d2,
        eps_abs=eps_abs,
        eps_rel=eps_rel,
        use_prev=cfg.prev_tolerance,
        mesh=sharding.mesh,
        batch_axes=(axes,) if isinstance(axes, str) else tuple(axes),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SolverCarry:
    """Resumable state of an Algorithm-1 solve (one pytree, jit-safe).

    Attributes:
      x: current state, shape (B, ...), in the policy's ``state_dtype``
         (fp32 unless a bf16_full precision policy is active).
      x_prev: last accepted low-order proposal x' (mixed tolerance, Eq.5);
         same dtype as x. All control fields below (t, h, counters) are
         fp32/int32 regardless of policy — the control path never
         downcasts (DESIGN.md §8).
      t: per-sample current time, shape (B,). t <= t_eps means converged;
         t == 0.0 doubles as "idle slot" in the serving loop.
      h: per-sample current step size, shape (B,).
      key: PRNG state — either one shared key of shape (2,) (whole-batch
         noise draw; what ``adaptive()`` uses, bit-identical to the
         monolithic loop) or per-slot keys of shape (B, 2) (each sample
         owns its noise stream, so the serving loop can move a sample
         between slots or admit a new one without perturbing anyone
         else's trajectory).
      nfe / accepted / rejected: per-sample counters, shape (B,) int32.
      done: per-sample convergence mask as of the last executed
         iteration, shape (B,) bool.
      iterations: total body iterations executed so far, scalar int32.
      cond: optional per-slot condition payload (DESIGN.md §9) — a
         pytree whose leaves all have leading dim B (labels (B,), masks
         (B, ...)), consumed by ``AdaptiveConfig.conditioner``. It
         rides through ``solve_chunk`` untouched and the serving loop
         compacts/admits its leaves per-slot alongside x and the
         per-slot keys, so a sample's conditioning travels with it.
         None (the default) for unconditional solves.
      atol / rtol: optional per-slot error tolerances, shape (B,) fp32
         (DESIGN.md §14). When present the loop body reads ε_abs/ε_rel
         from *these leaves* instead of the static config, so each slot
         solves at its own quality tier and the values travel through
         chunking, compaction permutations, sharding, and the
         device-resident event program exactly like ``cond`` — tier
         changes are data, never a retrace. Both-or-neither: None (the
         default) is the static-config path, bitwise identical to the
         pre-tolerance-class solver.
      telemetry: optional ``StepTelemetry`` ring (DESIGN.md §15): (B,
         cap) buffers of each iteration's per-slot (t, h, err, accept)
         snapshot plus a monotone head cursor, written by the loop body
         at ``head % cap`` each iteration. Like ``cond``/``atol``, its
         None-ness is treedef structure — the None default keeps the
         exact pre-telemetry pytree and the telemetry-off trace is
         bitwise identical; recording never feeds back into the solve.
         The serving loop permutes its (B, cap) rows with their samples
         under compaction; the head survives admission resets (unlike
         the fold-and-reset ``iterations``), so it counts all-time
         body iterations.
    """

    x: Array
    x_prev: Array
    t: Array
    h: Array
    key: Array
    nfe: Array
    accepted: Array
    rejected: Array
    done: Array
    iterations: Array
    cond: Any = None
    atol: Any = None
    rtol: Any = None
    telemetry: Any = None

    @property
    def batch(self) -> int:
        return self.x.shape[0]

    @property
    def per_slot_keys(self) -> bool:
        return self.key.ndim == 2


def init_carry(
    sde: SDE,
    x_init: Array,
    key: Array,
    *,
    config: AdaptiveConfig | None = None,
    sharding=None,
    cond=None,
    atol=None,
    rtol=None,
    h0=None,
    telemetry=None,
    **overrides,
) -> SolverCarry:
    """Fresh carry at t = T. ``key`` may be (2,) shared or (B, 2) per-slot.

    x is cast to the policy's ``state_dtype`` (no-op under fp32); t / h /
    counters are always fp32 / int32 (control path). ``cond`` is the
    optional per-slot condition payload (DESIGN.md §9): every leaf must
    lead with the batch dim; leaves keep their own dtype (fp32 — the
    projection/guidance math is control-path, never state-dtype).

    ``atol``/``rtol`` (DESIGN.md §14) install per-slot tolerance leaves:
    scalars broadcast to (B,), (B,) arrays are taken as-is, and the loop
    body then reads ε from the carry instead of the static config. Pass
    both or neither. ``h0`` likewise overrides the initial step size
    per-slot (scalar or (B,)); it is clamped to the t-span like
    ``cfg.h_init``.

    ``telemetry`` overrides ``cfg.telemetry_capacity`` (a records-per-
    slot capacity; DESIGN.md §15): any positive value attaches a fresh
    ``StepTelemetry`` ring, 0 forces it off, None defers to the config.
    """
    cfg = resolve_config(config, overrides)
    policy = resolve_policy(cfg.precision)
    x_init = x_init.astype(policy.state)
    c_arr, c_vec, c_tel = _constraints(sharding)
    batch = x_init.shape[0]
    if (atol is None) != (rtol is None):
        raise ValueError("per-slot tolerances come in pairs: pass both "
                         "atol and rtol, or neither")
    if atol is not None:
        atol = c_vec(jnp.broadcast_to(
            jnp.asarray(atol, jnp.float32), (batch,)))
        rtol = c_vec(jnp.broadcast_to(
            jnp.asarray(rtol, jnp.float32), (batch,)))
    if cond is not None:
        cb = cond_batch(cond)
        if cb is not None and cb != batch:
            raise ValueError(
                f"condition payload batch {cb} != state batch {batch}"
            )
        cond = jax.tree_util.tree_map(
            lambda l: c_arr(l) if l.ndim == x_init.ndim
            else (c_vec(l) if l.ndim == 1 else l),
            cond,
        )
    t0 = c_vec(jnp.full((batch,), sde.T, jnp.float32))
    h_of = cfg.h_init if h0 is None else h0
    h_vec = c_vec(jnp.minimum(
        jnp.broadcast_to(jnp.asarray(h_of, jnp.float32), (batch,)),
        t0 - sde.t_eps,
    ))
    zeros = c_vec(jnp.zeros((batch,), jnp.int32))
    x_init = c_arr(x_init)
    cap = int(cfg.telemetry_capacity if telemetry is None else telemetry)
    tel = None
    if cap > 0:
        tel = init_telemetry(batch, cap)
        tel = StepTelemetry(
            t=c_tel(tel.t), h=c_tel(tel.h), err=c_tel(tel.err),
            accept=c_tel(tel.accept), head=tel.head,
        )
    return SolverCarry(
        x=x_init,
        x_prev=x_init,
        t=t0,
        h=h_vec,
        key=key,
        nfe=zeros,
        accepted=zeros,
        rejected=zeros,
        done=c_vec(jnp.zeros((batch,), bool)),
        iterations=jnp.asarray(0, jnp.int32),
        cond=cond,
        atol=atol,
        rtol=rtol,
        telemetry=tel,
    )


def resolve_config(config, overrides) -> AdaptiveConfig:
    """Merge an optional AdaptiveConfig with kwarg overrides (public API:
    ``sample()``/launchers use it to accept either form)."""
    cfg = config or AdaptiveConfig(**overrides)
    if overrides and config is not None:
        cfg = dataclasses.replace(config, **overrides)
    return cfg


#: backward-compat alias (pre-PR-3 private name)
_resolve_config = resolve_config


def _constraints(sharding):
    """(c_arr, c_vec, c_tel) sharding-constraint closures for the
    (B, ...) state, (B,) control vectors, and (B, cap) telemetry
    buffers."""
    if sharding is None or not len(sharding.spec):
        # a P() spec (fully replicated) has no leading entry — treat as None
        ident = lambda a: a
        return ident, ident, ident
    from jax.sharding import NamedSharding, PartitionSpec as P

    vec_sharding = NamedSharding(sharding.mesh, P(sharding.spec[0]))
    tel_sharding = NamedSharding(sharding.mesh, P(sharding.spec[0], None))
    c_arr = lambda a: jax.lax.with_sharding_constraint(a, sharding)
    c_vec = lambda v: jax.lax.with_sharding_constraint(v, vec_sharding)
    c_tel = lambda m: jax.lax.with_sharding_constraint(m, tel_sharding)
    return c_arr, c_vec, c_tel


def _draw_noise(key: Array, x: Array):
    """Advance the PRNG and draw z ~ N(0, I) shaped like x.

    Shared key (2,): one batched draw — the monolithic-loop convention.
    Per-slot keys (B, 2): each sample's row comes from its own key, so
    the draw is invariant to which slot the sample occupies.

    The draw is always generated in fp32 (full-precision noise stream,
    identical bits under every precision policy) and cast to x's state
    dtype — a no-op under fp32 policies.
    """
    if key.ndim == 1:
        key, sub = jax.random.split(key)
        z = jax.random.normal(sub, x.shape, jnp.float32)
        return key, z.astype(x.dtype)
    pairs = jax.vmap(jax.random.split)(key)  # (B, 2, 2)
    subs = pairs[:, 1]
    z = jax.vmap(
        lambda k: jax.random.normal(k, x.shape[1:], jnp.float32)
    )(subs)
    return pairs[:, 0], z.astype(x.dtype)


def _make_body(sde, score_fn, cfg, eps_abs, step_math, c_arr, c_vec,
               c_tel=lambda a: a):
    """One Algorithm-1 iteration: SolverCarry → SolverCarry.

    ``score_fn`` arrives *raw*: the body composes the conditioner's
    score-field transform (innermost, so a label-aware score sees real
    labels — DESIGN.md §9) and then the precision policy's cast seam
    (outermost, DESIGN.md §8) around it. With ``cfg.conditioner=None``
    the composition collapses to exactly the pre-conditioning wrapping.

    Tolerance resolution (DESIGN.md §14): when the carry holds per-slot
    ``atol``/``rtol`` leaves the body reads ε from *them* — live carry
    data, so compaction permutations and tiered admissions apply without
    retracing — otherwise from the static ``eps_abs``/``cfg.eps_rel``
    floats (the pre-tolerance-class closure, bitwise unchanged).
    """
    conditioner = cfg.conditioner
    policy = resolve_policy(cfg.precision)
    projecting = conditioner is not None and conditioner.has_projection
    mom = float(cfg.momentum)
    pf = bool(cfg.probability_flow)

    def em_coeffs(t, h):
        """x' = c0·x + c1·score + c2·z coefficients (per-sample scalars).

        Probability-flow variant (DESIGN.md §11): dx = [f − ½g²s] dt, so
        the score coefficient halves and the noise coefficient is zero.
        """
        a = sde.drift_coeff(t)
        g = sde.diffusion(t)
        if pf:
            return 1.0 - h * a, 0.5 * h * g * g, jnp.zeros_like(h)
        return 1.0 - h * a, h * g * g, jnp.sqrt(h) * g

    def body(s: SolverCarry) -> SolverCarry:
        x, x_prev, t, h = s.x, s.x_prev, s.t, s.h
        sf = score_fn
        if conditioner is not None:
            sf = conditioner.wrap_score(sf, s.cond)
        sf = policy.wrap_score_fn(sf)
        active = t > sde.t_eps + 1e-12
        # Clamp the times fed to the score net for frozen samples.
        t_c = jnp.clip(t, sde.t_eps, sde.T)
        h_c = jnp.where(active, h, 0.0)
        t2 = jnp.clip(t_c - h_c, sde.t_eps, sde.T)

        if pf:
            # deterministic ODE path: no diffusion noise, and the PRNG
            # stream is not advanced (the projection draw below still is,
            # when a projecting conditioner needs re-noising)
            key, z = s.key, c_arr(jnp.zeros_like(x))
        else:
            key, z = _draw_noise(s.key, x)
            z = c_arr(z)
        if projecting:
            # projection noise is its own draw, taken only when a
            # projecting conditioner is active — the unconditional noise
            # stream is untouched by the conditioning seam
            key, z_proj = _draw_noise(key, x)

        # --- low-order proposal: one reverse-EM step --------------------
        # coefficients are fp32 control values, so the EM arithmetic
        # promotes to fp32 even for bf16 state; the result is stored back
        # at the state dtype (no-op under fp32 policies)
        score1 = sf(x, t_c)
        c0, c1, c2 = em_coeffs(t_c, h_c)
        x_base = x
        if mom:
            # heavy-ball transport (DESIGN.md §11): v is the last
            # accepted displacement (x_prev holds the previous accepted
            # *state* in this family). β·v is added to both proposals —
            # shared transport, so the embedded error estimate still
            # measures the EM-vs-Improved-Euler discrepancy only.
            v = x.astype(jnp.float32) - x_prev.astype(jnp.float32)
            x_base = c_arr((x.astype(jnp.float32) + mom * v).astype(x.dtype))
        x_prime = (
            _expand(c0, x) * x + _expand(c1, x) * score1 + _expand(c2, x) * z
        )
        if mom:
            x_prime = x_prime + mom * v
        x_prime = c_arr(x_prime.astype(x.dtype))

        # --- high-order proposal: stochastic Improved Euler -------------
        score2 = sf(x_prime, t2)
        e0 = h_c * sde.drift_coeff(t2)
        g2 = sde.diffusion(t2)
        d1 = (0.5 if pf else 1.0) * h_c * g2 * g2
        d2 = jnp.zeros_like(h_c) if pf else jnp.sqrt(h_c) * g2
        # per-slot tolerance leaves win over the static config floats;
        # the None-check is pytree structure (trace-time), not traced data
        ea = eps_abs if s.atol is None else s.atol
        er = cfg.eps_rel if s.rtol is None else s.rtol
        x_high, err = step_math(
            x_base, x_prime, score2, z, x_prev, e0, d1, d2, cfg, ea, er
        )
        # the jnp step math returns x'' in fp32 (the fused kernel already
        # emits the operand dtype); the carry stores the state dtype
        proposal = (x_high if cfg.extrapolate else x_prime).astype(x.dtype)

        accept = jnp.logical_and(err <= 1.0, active)
        acc_e = _expand(accept, x)
        x_new = c_arr(jnp.where(acc_e, proposal, x))
        # momentum family: x_prev tracks the last accepted *state* (the
        # point we stepped from) so v = x − x_prev is the accepted
        # displacement; otherwise the last accepted low-order proposal
        # (mixed tolerance, Eq. 5)
        x_prev_new = c_arr(jnp.where(acc_e, x if mom else x_prime, x_prev))
        t_new = c_vec(jnp.where(accept, t - h, t))

        if projecting:
            # post-accept projection (DESIGN.md §9): observed data is
            # re-noised to each slot's *new* time t − h, in fp32 under
            # every precision preset, and only accepted slots move —
            # projecting inside the proposal would corrupt the Eq. 4/5
            # error estimate, and projecting rejected slots would drift
            # state the controller decided not to advance. x'_prev stays
            # unprojected: the mixed tolerance tracks the raw field.
            projected = conditioner.project(sde, x_new, t_new, s.cond, z_proj)
            x_new = c_arr(jnp.where(acc_e, projected.astype(x.dtype), x_new))

        remaining = jnp.maximum(t_new - sde.t_eps, 0.0)
        h_new = next_step_size(
            h, err, remaining, safety=cfg.safety, r_exponent=cfg.r_exponent
        )
        h_new = c_vec(jnp.where(active, h_new, h))

        # step telemetry (DESIGN.md §15): record this iteration's
        # attempted step — entry t, the active-clamped h, the fp32
        # scaled error, and the accept bit — into the ring. The None
        # check is treedef structure (trace time), so the telemetry-off
        # body is the exact pre-§15 program; the write consumes values
        # already computed and feeds nothing back.
        tel = s.telemetry
        if tel is not None:
            tel = record_step(tel, t=t, h=h_c, err=err, accept=accept,
                              constrain=c_tel)

        two = jnp.where(active, 2, 0).astype(jnp.int32)
        return SolverCarry(
            x=x_new,
            x_prev=x_prev_new,
            t=t_new,
            h=h_new,
            key=key,
            nfe=c_vec(s.nfe + two),
            accepted=c_vec(s.accepted + accept.astype(jnp.int32)),
            rejected=c_vec(
                s.rejected + jnp.logical_and(~accept, active).astype(jnp.int32)
            ),
            done=c_vec(t_new <= sde.t_eps + 1e-12),
            iterations=s.iterations + 1,
            cond=s.cond,
            atol=s.atol,
            rtol=s.rtol,
            telemetry=tel,
        )

    return body


def _pick_step_math(cfg: AdaptiveConfig, sharding):
    batch_axes = (
        sharding.spec[0] if sharding is not None and len(sharding.spec) else None
    )
    if not cfg.use_fused_kernel:
        return _step_math_jnp
    if batch_axes is not None:
        return functools.partial(_step_math_fused_sharded, sharding=sharding)
    return _step_math_fused


def solve_chunk(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    carry: SolverCarry,
    *,
    max_sync_iters: int,
    config: AdaptiveConfig | None = None,
    sharding=None,
    **overrides,
) -> SolverCarry:
    """Run at most ``max_sync_iters`` Algorithm-1 iterations device-side.

    Stops early when every sample has converged (t <= t_eps) or the
    solve's global ``cfg.max_iters`` budget is exhausted. Chaining
    ``solve_chunk`` calls until ``carry.done.all()`` is bit-identical to
    the monolithic ``adaptive()`` solve with the same key: the body is
    the same function and the PRNG threading does not depend on where
    chunk boundaries fall. This is the yield point the serving loop uses
    to retire and refill slots between horizons (DESIGN.md §7).

    ``cfg.precision`` wraps ``score_fn`` at this seam: x casts to the
    policy's compute dtype on entry and the score casts to the state
    dtype on exit — policy-aware score functions (built with
    ``make_score_fn(..., policy=...)``) see idempotent casts.
    ``cfg.conditioner`` (DESIGN.md §9) composes *inside* that cast pair,
    consuming ``carry.cond``; with a ``ClassifierFree`` conditioner the
    raw ``score_fn`` must be label-aware (``s(x, t, y)``).
    """
    cfg = resolve_config(config, overrides)
    eps_abs = float(sde.abs_tolerance if cfg.eps_abs is None else cfg.eps_abs)
    c_arr, c_vec, c_tel = _constraints(sharding)
    body = _make_body(
        sde, score_fn, cfg, eps_abs, _pick_step_math(cfg, sharding),
        c_arr, c_vec, c_tel,
    )
    start = carry.iterations

    def cond(s: SolverCarry):
        return (
            jnp.any(s.t > sde.t_eps + 1e-12)
            & (s.iterations - start < max_sync_iters)
            & (s.iterations < cfg.max_iters)
        )

    return jax.lax.while_loop(cond, body, carry)


def events_pending(carry: SolverCarry, occupied: Array, *,
                   wait_all: bool = False) -> Array:
    """Device-side serving event flag (DESIGN.md §12): does the host
    have anything to do with this carry?

    An *event* is a pending delivery: with ``wait_all=False`` (the
    compaction discipline) any occupied slot whose sample converged;
    with ``wait_all=True`` (the monolithic-wave baseline) the whole
    occupied set having converged. ``occupied`` is the host's (B,) slot-
    occupancy mask — host knowledge the device cannot derive from
    ``done`` alone, since idle slots also ride with ``done=True``.
    Returns a scalar bool that stays on device until the host chooses to
    read it — the sole per-horizon-window device→host transfer of the
    device-resident serve loop.
    """
    running = jnp.logical_and(occupied, jnp.logical_not(carry.done))
    if wait_all:
        return jnp.logical_and(jnp.any(occupied), jnp.logical_not(jnp.any(running)))
    return jnp.any(jnp.logical_and(occupied, carry.done))


def solve_horizons(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    carry: SolverCarry,
    occupied: Array,
    *,
    sync_horizon: int,
    max_horizons: int,
    config: AdaptiveConfig | None = None,
    sharding=None,
    wait_all: bool = False,
    **overrides,
) -> tuple[SolverCarry, Array]:
    """Multi-horizon device driver: chain ``solve_chunk`` horizons in a
    ``lax.while_loop`` until a serving event is pending (DESIGN.md §12).

    Each outer iteration runs one ``sync_horizon``-bounded chunk — the
    exact unit the host-driven serve loop dispatches per round-trip — so
    retirement granularity is identical to chaining the chunks from the
    host, and the per-slot-key invariance makes the delivered samples
    bit-identical. What changes is *where the polling loop runs*: the
    convergence check between horizons happens device-side against the
    ``occupied`` mask, and the host reads back a single scalar event
    flag per driver call instead of per horizon. ``max_horizons`` bounds
    one call (the host regains control even if nothing converges, e.g.
    a straggler-bound monolithic wave).

    Returns ``(carry, events)`` with ``events`` the scalar
    ``events_pending`` flag at exit. Stops as soon as the event fires,
    every occupied sample converged, or ``max_horizons`` chunks ran.
    """
    cfg = resolve_config(config, overrides)

    def cond(state):
        c, n = state
        running = jnp.any(jnp.logical_and(occupied, jnp.logical_not(c.done)))
        no_event = jnp.logical_not(
            events_pending(c, occupied, wait_all=wait_all)
        )
        return running & no_event & (n < max_horizons)

    def body(state):
        c, n = state
        c = solve_chunk(
            sde, score_fn, c,
            max_sync_iters=sync_horizon, config=cfg, sharding=sharding,
        )
        return c, n + 1

    carry, _ = jax.lax.while_loop(cond, body, (carry, jnp.asarray(0, jnp.int32)))
    return carry, events_pending(carry, occupied, wait_all=wait_all)


def finalize(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    carry: SolverCarry,
    *,
    denoise: bool = True,
    precision: "str | PrecisionPolicy" = "fp32",
    conditioner: Optional[Conditioner] = None,
) -> SolveResult:
    """SolveResult from a finished carry (+ the paper's Tweedie denoise).

    Under a precision policy the final score evaluation runs in the
    compute dtype like every other, but the Tweedie arithmetic itself is
    fp32 — the denoised delivery is never quantized by the state dtype.

    With a ``conditioner`` (DESIGN.md §9) the denoising score is the
    *conditioned* field (consuming ``carry.cond``), and the delivered
    sample gets the conditioner's exact, noise-free constraint
    replacement (``finalize_project``) — e.g. inpainting pins observed
    pixels to the observation exactly at t_eps.
    """
    policy = resolve_policy(precision)
    if conditioner is not None:
        score_fn = conditioner.wrap_score(score_fn, carry.cond)
    x, nfe = carry.x, carry.nfe
    if denoise:
        t = jnp.full((carry.batch,), sde.t_eps)
        score = score_fn(policy.to_compute(x), t).astype(jnp.float32)
        x = sde.tweedie_denoise(x.astype(jnp.float32), score)
        nfe = nfe + 1
    if conditioner is not None:
        x = conditioner.finalize_project(x, carry.cond)
    return SolveResult(
        x=x,
        nfe=nfe,
        iterations=carry.iterations,
        accepted=carry.accepted,
        rejected=carry.rejected,
    )


@register_solver("adaptive", nfe_per_iter=2)
def adaptive(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    config: AdaptiveConfig | None = None,
    denoise: bool = True,
    sharding=None,
    cond=None,
    atol=None,
    rtol=None,
    h0=None,
    **overrides,
) -> SolveResult:
    """Algorithm 1: solve the reverse diffusion from T to t_eps adaptively.

    One maximal ``solve_chunk`` over a fresh ``SolverCarry`` — the
    monolithic reference that horizon-chunked solves must reproduce
    bit-for-bit.

    ``cond`` is the optional per-sample condition payload consumed by
    ``cfg.conditioner`` (DESIGN.md §9); both default to None, the
    bit-identical unconditional path.

    ``atol``/``rtol``/``h0`` (DESIGN.md §14) install per-slot tolerance
    (and initial-step) leaves in the carry — scalars or (B,) arrays —
    so one batch can mix quality tiers; None (the default) keeps the
    static-config tolerance, bitwise identical to the pre-tier solver.

    ``sharding`` (a batch-axis NamedSharding, normally produced by
    ``repro.parallel.sharding.sample_state_shardings`` and threaded down
    from ``sample(..., mesh=...)``) constrains every (B, ...) and (B,)
    carry of the while loop so GSPMD keeps the whole loop — both score
    evaluations, the step math, and the accept/adapt bookkeeping — data
    parallel with zero resharding (DESIGN.md §3). Numerics are identical
    to the unsharded run: the batch is embarrassingly parallel and the
    PRNG is sharding-invariant.
    """
    cfg = resolve_config(config, overrides)
    carry = init_carry(sde, x_init, key, config=cfg, sharding=sharding,
                       cond=cond, atol=atol, rtol=rtol, h0=h0)
    carry = solve_chunk(
        sde, score_fn, carry,
        max_sync_iters=cfg.max_iters, config=cfg, sharding=sharding,
    )
    return finalize(sde, score_fn, carry, denoise=denoise,
                    precision=cfg.precision, conditioner=cfg.conditioner)


# ---------------------------------------------------------------------------
# Algorithm 2: arbitrary forward-time diffusion dx = f(x,t)dt + g(x,t)dw
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ForwardAdaptiveConfig:
    eps_rel: float = 0.01
    eps_abs: float = 1e-3
    h_init: float = 0.01
    safety: float = 0.9
    r_exponent: float = 0.9
    max_iters: int = 100_000
    stratonovich: bool = False  # True (or state-indep g) → s = 0


def adaptive_forward(
    drift_fn: Callable[[Array, Array], Array],
    diffusion_fn: Callable[[Array, Array], Array],
    x0: Array,
    t_begin: float,
    t_end: float,
    key: Array,
    *,
    config: ForwardAdaptiveConfig | None = None,
) -> SolveResult:
    """Algorithm 2 (paper App. C): forward-time general diffusion solver.

    Differences from Algorithm 1 (per the paper): forward time; g may
    depend on x, handled with the Itô correction s ~ U{-1,+1} of Roberts
    (2012); the Gaussian draw z is *retained across rejections* so the
    rejection does not bias the driving noise.
    """
    cfg = config or ForwardAdaptiveConfig()
    batch = x0.shape[0]
    span = t_end - t_begin
    t0 = jnp.full((batch,), float(t_begin), jnp.float32)
    h0 = jnp.minimum(jnp.full((batch,), cfg.h_init, jnp.float32), span)

    def cond(s):
        _, _, t, _, _, _, _, _, _, _, iters = s
        return jnp.logical_and(jnp.any(t < t_end - 1e-12), iters < cfg.max_iters)

    def body(s):
        x, x_prev, t, h, z, ssign, key, nfe, acc, rej, iters = s
        active = t < t_end - 1e-12
        h_c = jnp.where(active, jnp.minimum(h, t_end - t), 0.0)

        g1 = diffusion_fn(x, t)
        f1 = drift_fn(x, t)
        sq = jnp.sqrt(h_c)
        se = _expand(ssign, x)
        x_prime = (
            x + _expand(h_c, x) * f1 + _expand(sq, x) * g1 * (z - se)
        )
        t2 = t + h_c
        g2 = diffusion_fn(x_prime, t2)
        f2 = drift_fn(x_prime, t2)
        x_tilde = x + _expand(h_c, x) * f2 + _expand(sq, x) * g2 * (z + se)
        x_high = 0.5 * (x_prime + x_tilde)

        delta = mixed_tolerance(x_prime, x_prev, cfg.eps_abs, cfg.eps_rel)
        err = scaled_error_l2(x_prime, x_high, delta)

        accept = jnp.logical_and(err <= 1.0, active)
        acc_e = _expand(accept, x)
        x_new = jnp.where(acc_e, x_high, x)
        x_prev_new = jnp.where(acc_e, x_prime, x_prev)
        t_new = jnp.where(accept, t + h_c, t)

        # Redraw the noise only after acceptance (rejection keeps z).
        key, kz, ks = jax.random.split(key, 3)
        z_fresh = jax.random.normal(kz, x.shape, x.dtype)
        s_fresh = (
            jnp.zeros((batch,), x.dtype)
            if cfg.stratonovich
            else jax.random.rademacher(ks, (batch,), x.dtype)
        )
        z_new = jnp.where(acc_e, z_fresh, z)
        s_new = jnp.where(accept, s_fresh, ssign)

        remaining = jnp.maximum(t_end - t_new, 0.0)
        h_new = next_step_size(
            h, err, remaining, safety=cfg.safety, r_exponent=cfg.r_exponent
        )
        h_new = jnp.where(active, h_new, h)
        two = jnp.where(active, 2, 0).astype(jnp.int32)
        return (
            x_new, x_prev_new, t_new, h_new, z_new, s_new, key,
            nfe + two,
            acc + accept.astype(jnp.int32),
            rej + jnp.logical_and(~accept, active).astype(jnp.int32),
            iters + 1,
        )

    key, kz, ks = jax.random.split(key, 3)
    z0 = jax.random.normal(kz, x0.shape, x0.dtype)
    s0 = (
        jnp.zeros((batch,), x0.dtype)
        if cfg.stratonovich
        else jax.random.rademacher(ks, (batch,), x0.dtype)
    )
    zeros = jnp.zeros((batch,), jnp.int32)
    init = (x0, x0, t0, h0, z0, s0, key, zeros, zeros, zeros, jnp.asarray(0, jnp.int32))
    out = jax.lax.while_loop(cond, body, init)
    x, _, _, _, _, _, _, nfe, acc, rej, iters = out
    return SolveResult(x=x, nfe=nfe, iterations=iters, accepted=acc, rejected=rej)
