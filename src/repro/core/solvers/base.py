"""Common solver API.

Every solver consumes an ``SDE``, a score function ``s(x, t)`` (with t a
per-sample vector), an initial state drawn from the prior, and returns a
``SolveResult``. Solvers integrate the *reverse* diffusion from t=T down
to t=sde.t_eps and (optionally) apply the corrected Tweedie denoising
step of paper Appendix D.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SolveResult:
    """Output of a solver run.

    Attributes:
      x: final samples, shape (B, ...).
      nfe: per-sample number of score-function evaluations, shape (B,).
           For fixed-step solvers this is constant across the batch.
      iterations: number of solver loop iterations actually executed
           (scalar). Wall-clock cost on accelerators is proportional to
           iterations, not per-sample NFE, because finished samples ride
           along masked.
      accepted / rejected: per-sample accept/reject counts (adaptive
           solvers only; zeros otherwise), shape (B,).
    """

    x: Array
    nfe: Array
    iterations: Array
    accepted: Array
    rejected: Array

    @property
    def mean_nfe(self) -> Array:
        return jnp.mean(self.nfe)

    @property
    def max_nfe(self) -> Array:
        return jnp.max(self.nfe)


_REGISTRY: Dict[str, Callable[..., Any]] = {}

#: solver name → per-iteration NFE rule: an int, or a callable over the
#: solver's own kwargs returning one. One loop iteration = one pass of
#: the solver's device body over the whole batch (what serving pays per
#: ``total_iterations`` tick), so this is the exact conversion factor
#: between iterations and issued score-net evaluations (DESIGN.md §7).
_NFE_PER_ITER: Dict[str, Any] = {}


def register_solver(name: str, *, nfe_per_iter: Any = None):
    """Register a solver, optionally with its per-iteration NFE rule.

    ``nfe_per_iter`` is an int for fixed-cost bodies (2 for the
    Algorithm-1 families: two score evaluations per iteration) or a
    callable over the solver's keyword arguments for families whose cost
    is a function of their configuration (``pc_hmc`` issues
    ``1 + corrector_steps·hmc_leapfrog`` per grid step). Serving's waste
    accounting reads it via ``solver_nfe_per_iteration`` — hardcoding 2
    there produced negative waste fractions for any non-adaptive family.
    """

    def deco(fn):
        _REGISTRY[name] = fn
        if nfe_per_iter is not None:
            _NFE_PER_ITER[name] = nfe_per_iter
        return fn

    return deco


def solver_nfe_per_iteration(name: str, **solver_kwargs) -> int:
    """Score-net evaluations one loop iteration of ``name`` issues.

    ``solver_kwargs`` are the same keyword arguments the solver itself
    would receive (only the cost-relevant ones are consulted; the rest
    are ignored). Raises ``ValueError`` for unregistered solvers or
    solvers that declared no rule, so accounting can never silently fall
    back to a wrong constant (DESIGN.md §7).
    """
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown solver '{name}'; available: {sorted(_REGISTRY)}"
        )
    try:
        rule = _NFE_PER_ITER[name]
    except KeyError:
        raise ValueError(
            f"solver '{name}' declared no per-iteration NFE rule"
        ) from None
    return int(rule(**solver_kwargs)) if callable(rule) else int(rule)


def get_solver(name: str) -> Callable[..., Any]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver '{name}'; available: {sorted(_REGISTRY)}"
        ) from None


def available_solvers():
    return sorted(_REGISTRY)
