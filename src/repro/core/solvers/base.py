"""Common solver API.

Every solver consumes an ``SDE``, a score function ``s(x, t)`` (with t a
per-sample vector), an initial state drawn from the prior, and returns a
``SolveResult``. Solvers integrate the *reverse* diffusion from t=T down
to t=sde.t_eps and (optionally) apply the corrected Tweedie denoising
step of paper Appendix D.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SolveResult:
    """Output of a solver run.

    Attributes:
      x: final samples, shape (B, ...).
      nfe: per-sample number of score-function evaluations, shape (B,).
           For fixed-step solvers this is constant across the batch.
      iterations: number of solver loop iterations actually executed
           (scalar). Wall-clock cost on accelerators is proportional to
           iterations, not per-sample NFE, because finished samples ride
           along masked.
      accepted / rejected: per-sample accept/reject counts (adaptive
           solvers only; zeros otherwise), shape (B,).
    """

    x: Array
    nfe: Array
    iterations: Array
    accepted: Array
    rejected: Array

    @property
    def mean_nfe(self) -> Array:
        return jnp.mean(self.nfe)

    @property
    def max_nfe(self) -> Array:
        return jnp.max(self.nfe)


_REGISTRY: Dict[str, Callable[..., Any]] = {}


def register_solver(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_solver(name: str) -> Callable[..., Any]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver '{name}'; available: {sorted(_REGISTRY)}"
        ) from None


def available_solvers():
    return sorted(_REGISTRY)
