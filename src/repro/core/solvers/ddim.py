"""DDIM (Song et al. 2020b) — deterministic VP-only fast sampler baseline.

Defined only for VP diffusions (as in the paper, Sec. 4 "which is only
defined for VP models"). Uses the continuous-time VP marginals:
ᾱ(t) = exp(−∫β) so that x_t = sqrt(ᾱ) x₀ + sqrt(1−ᾱ) ε, and the
score relates to the noise prediction by ε̂ = −sqrt(1−ᾱ) · s(x, t).

η = 0 (deterministic) update:
  x_{t'} = sqrt(ᾱ') x̂₀ + sqrt(1−ᾱ') ε̂,   x̂₀ = (x − sqrt(1−ᾱ) ε̂)/sqrt(ᾱ)
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import VPSDE
from .base import SolveResult, register_solver

Array = jax.Array


@register_solver("ddim", nfe_per_iter=1)
def ddim(
    sde: VPSDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,  # unused, deterministic
    *,
    n_steps: int = 100,
    eta: float = 0.0,
    denoise: bool = True,
) -> SolveResult:
    if not isinstance(sde, VPSDE):
        raise TypeError("DDIM is defined only for VP diffusions (paper Sec. 4)")
    del key
    batch = x_init.shape[0]
    ts = jnp.linspace(sde.T, sde.t_eps, n_steps + 1)

    def alpha_bar(t):
        m, _ = sde.marginal(t)
        return m * m

    def body(carry, i):
        x = carry
        t = jnp.full((batch,), ts[i])
        t_next = jnp.full((batch,), ts[i + 1])
        ab = alpha_bar(t).reshape((-1,) + (1,) * (x.ndim - 1))
        ab_n = alpha_bar(t_next).reshape((-1,) + (1,) * (x.ndim - 1))
        score = score_fn(x, t)
        eps_hat = -jnp.sqrt(1.0 - ab) * score
        x0_hat = (x - jnp.sqrt(1.0 - ab) * eps_hat) / jnp.sqrt(ab)
        x = jnp.sqrt(ab_n) * x0_hat + jnp.sqrt(jnp.maximum(1.0 - ab_n, 0.0)) * eps_hat
        return x, None

    x, _ = jax.lax.scan(body, x_init, jnp.arange(n_steps))
    nfe = jnp.full((batch,), n_steps, jnp.int32)
    if denoise:
        t = jnp.full((batch,), sde.t_eps)
        x = sde.tweedie_denoise(x, score_fn(x, t))
        nfe = nfe + 1
    zeros = jnp.zeros((batch,), jnp.int32)
    return SolveResult(
        x=x, nfe=nfe, iterations=jnp.asarray(n_steps, jnp.int32),
        accepted=zeros, rejected=zeros,
    )
