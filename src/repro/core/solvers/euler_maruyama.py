"""Fixed-step Euler–Maruyama for the reverse diffusion (the baseline).

Follows the conventions of Song et al. 2020a as described in paper
Appendix D: time follows t_0 = T, t_i = t_{i-1} - (T - t_eps)/N, the
solver stops at t = t_eps, and the sample is then denoised with the
corrected Tweedie formula.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE
from .base import SolveResult, register_solver

Array = jax.Array


@register_solver("em", nfe_per_iter=1)
def euler_maruyama(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    n_steps: int = 1000,
    denoise: bool = True,
) -> SolveResult:
    batch = x_init.shape[0]
    h = (sde.T - sde.t_eps) / n_steps

    def body(carry, i):
        x, key = carry
        key, sub = jax.random.split(key)
        t = jnp.full((batch,), sde.T - i * h)
        z = jax.random.normal(sub, x.shape, x.dtype)
        score = score_fn(x, t)
        drift = sde.reverse_drift(x, t, score)
        g = sde.diffusion(t).reshape((-1,) + (1,) * (x.ndim - 1))
        # reverse-time step: dt = -h; noise enters with sqrt(h).
        x = x - h * drift + jnp.sqrt(h) * g * z
        return (x, key), None

    (x, key), _ = jax.lax.scan(body, (x_init, key), jnp.arange(n_steps))

    nfe = jnp.full((batch,), n_steps, jnp.int32)
    if denoise:
        t = jnp.full((batch,), sde.t_eps)
        x = sde.tweedie_denoise(x, score_fn(x, t))
        nfe = nfe + 1
    zeros = jnp.zeros((batch,), jnp.int32)
    return SolveResult(
        x=x,
        nfe=nfe,
        iterations=jnp.asarray(n_steps, jnp.int32),
        accepted=zeros,
        rejected=zeros,
    )
