"""Adaptive Heun solver for the probability-flow ODE (DESIGN.md §11).

High-order deterministic sampling: with ``AdaptiveConfig.
probability_flow`` the Algorithm-1 body integrates dx = [f − ½g²s] dt —
the score coefficients halve, the diffusion noise vanishes and the main
noise draw is skipped — and the paper's extrapolation trick becomes
exactly Heun's trapezoidal method with an embedded Euler/Heun pair for
the local-error estimate: x' is the Euler predictor, x̃ re-evaluates the
drift at x', and x'' = ½(x' + x̃) is the 2nd-order trapezoidal update
the controller accepts or rejects per sample.

Contrast with the ``ode`` baseline (``probability_flow.py``): that is
batch-global RK45 matching how scipy (and the paper) report ODE NFE;
this family keeps *per-sample* step sizes and the full ``SolverCarry``
contract, so it chunks, compacts, shards, conditions, and serves
exactly like the adaptive SDE solver.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.core.sde import SDE
from .adaptive import AdaptiveConfig, adaptive, resolve_config
from .base import SolveResult, register_solver

Array = jax.Array


@register_solver("heun", nfe_per_iter=2)
def heun(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    config: Optional[AdaptiveConfig] = None,
    denoise: bool = True,
    sharding=None,
    cond=None,
    **overrides,
) -> SolveResult:
    """Adaptive 2nd-order probability-flow solve (per-sample steps).

    Accepts everything ``adaptive`` accepts; ``probability_flow`` is
    forced on. ``key`` only feeds a projecting conditioner's re-noising
    draw — the unconditional solve is deterministic given ``x_init``.
    """
    cfg = resolve_config(config, overrides)
    cfg = dataclasses.replace(cfg, probability_flow=True)
    return adaptive(sde, score_fn, x_init, key, config=cfg, denoise=denoise,
                    sharding=sharding, cond=cond)
