"""Adaptive heavy-ball momentum sampler (DESIGN.md §11).

Heavy-ball acceleration on the score field, in the spirit of
accelerated/momentum diffusion samplers (see PAPERS.md): each proposal
gains β·v where v = x − x_prev is the last *accepted* displacement.
Momentum is pure transport shared by both embedded proposals (x' and
x̃), so the paper's fp32 error controller still measures the
EM-vs-Improved-Euler discrepancy and keeps the per-sample step-size
adaptation intact; the analytic W2 conformance gate is what adjudicates
the momentum-induced bias (``tests/test_solver_conformance.py``).

This is not a new loop: it is the Algorithm-1 body of
``repro.core.solvers.adaptive`` with ``AdaptiveConfig.momentum`` set,
which is exactly why the family rides every existing seam unmodified —
``SolverCarry`` (x_prev doubles as the momentum buffer, so v = 0 at
``init_carry`` and at serving admission where x_prev = x = prior),
chunked ``solve_chunk``/compaction, precision policy, Conditioner
payloads, and mesh sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.core.sde import SDE
from .adaptive import AdaptiveConfig, adaptive, resolve_config
from .base import SolveResult, register_solver

Array = jax.Array

#: default heavy-ball coefficient: strong enough to cut NFE below the
#: plain adaptive solver at equal tolerance, weak enough to hold the
#: analytic W2 conformance gate on both OU and trajectory workloads
DEFAULT_BETA = 0.15


@register_solver("momentum", nfe_per_iter=2)
def momentum(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    config: Optional[AdaptiveConfig] = None,
    denoise: bool = True,
    sharding=None,
    cond=None,
    **overrides,
) -> SolveResult:
    """Heavy-ball variant of Algorithm 1 (``AdaptiveConfig.momentum``).

    Accepts everything ``adaptive`` accepts; when the resolved config
    leaves ``momentum`` at its off-default 0.0, the family default
    ``DEFAULT_BETA`` is applied (pass ``momentum=...`` or a config with
    the field set to choose β explicitly).
    """
    cfg = resolve_config(config, overrides)
    if cfg.momentum == 0.0:
        cfg = dataclasses.replace(cfg, momentum=DEFAULT_BETA)
    return adaptive(sde, score_fn, x_init, key, config=cfg, denoise=denoise,
                    sharding=sharding, cond=cond)
