"""Reverse-Diffusion (ancestral) predictor + Langevin corrector.

This is the paper's strongest VE baseline ("Reverse-Diffusion &
Langevin", Table 1) following Song et al. 2020a's PC sampler:

  predictor (VE): x ← x + (σ_i² − σ_{i-1}²) s(x, t_i) + sqrt(σ_i² − σ_{i-1}²) z
  predictor (VP): x ← (2 − sqrt(1 − β_i)) x + β_i s(x, t_i) + sqrt(β_i) z
  corrector     : annealed Langevin with step ε = 2 α (r ‖z‖/‖s‖)²

with signal-to-noise ratio r (0.16 for VE, 0.01 for VP in the original
code) and α = 1 (VE) or 1 − β_i (VP).

Corrector seam (DESIGN.md §11): the corrector is a pluggable
``(x, t, key) -> (x, key)`` pass selected by name, so MCMC-corrector
families compose with the same ancestral predictor. Besides the default
``"langevin"`` there is ``"hmc"`` — uncorrected Hamiltonian Monte Carlo
(no Metropolis accept/reject, as in score-based HMC correctors where
only ∇log p is available): refresh p ~ N(0, I), take L leapfrog steps
with the score as −∇U at step size ε = sqrt(2·step)/L, where ``step``
is the same snr-derived Langevin step. The trajectory length L·ε then
matches the Langevin move's noise scale while the transport is
ballistic rather than diffusive; at L = 1 the update reduces *exactly*
to the Langevin corrector. Each HMC pass costs L score evaluations (the
final half-kick only updates the momentum, which is discarded and
refreshed next pass, so it is skipped rather than spent).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE, VESDE, VPSDE
from .base import SolveResult, register_solver

Array = jax.Array


def _e(v, x):
    return v.reshape(v.shape + (1,) * (x.ndim - 1))


def _norm(v: Array) -> Array:
    return jnp.sqrt(jnp.sum(v * v, axis=tuple(range(1, v.ndim))))


def _pc_nfe_per_iter(corrector_steps: int = 1, corrector: str = "langevin",
                     hmc_leapfrog: int = 3, **_) -> int:
    """1 predictor eval + corrector passes: Langevin costs 1 eval each,
    HMC costs L leapfrog evals (final half-kick elided, see ``hmc``)."""
    per_pass = hmc_leapfrog if corrector == "hmc" else 1
    return 1 + corrector_steps * per_pass


@register_solver("pc", nfe_per_iter=_pc_nfe_per_iter)
def predictor_corrector(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    n_steps: int = 1000,
    corrector_steps: int = 1,
    snr: float | None = None,
    denoise: bool = True,
    corrector: str = "langevin",
    hmc_leapfrog: int = 3,
) -> SolveResult:
    batch = x_init.shape[0]
    is_ve = isinstance(sde, VESDE)
    if snr is None:
        snr = 0.16 if is_ve else 0.01
    ts = jnp.linspace(sde.T, sde.t_eps, n_steps + 1)

    def _alpha(t):
        return jnp.ones_like(t) if is_ve else 1.0 - sde.beta(t) / n_steps

    def _step_size(t, z, score):
        """snr-derived Langevin step ε = 2 α (r ‖z‖/‖s‖)², shape (B,)."""
        return (
            2.0 * _alpha(t)
            * (snr * _norm(z) / jnp.maximum(_norm(score), 1e-12)) ** 2
        )

    def langevin(x, t, key):
        key, sub = jax.random.split(key)
        score = score_fn(x, t)
        z = jax.random.normal(sub, x.shape, x.dtype)
        step = _step_size(t, z, score)
        x = x + _e(step, x) * score + _e(jnp.sqrt(2.0 * step), x) * z
        return x, key

    def hmc(x, t, key):
        # uncorrected HMC: the refreshed momentum p plays z's role in the
        # snr step-size rule; L leapfrog steps at ε = sqrt(2·step)/L keep
        # the trajectory length on the Langevin move's scale (L=1 ⇒
        # exactly the Langevin update). Final half-kick skipped: p is
        # discarded and refreshed next pass.
        key, sub = jax.random.split(key)
        p = jax.random.normal(sub, x.shape, x.dtype)
        score = score_fn(x, t)
        step = _step_size(t, p, score)
        eps = _e(jnp.sqrt(2.0 * step) / hmc_leapfrog, x)
        p = p + 0.5 * eps * score
        for leap in range(hmc_leapfrog):
            x = x + eps * p
            if leap + 1 < hmc_leapfrog:
                p = p + eps * score_fn(x, t)
        return x, key

    correctors = {"langevin": (langevin, 1), "hmc": (hmc, hmc_leapfrog)}
    if corrector not in correctors:
        raise ValueError(
            f"unknown corrector {corrector!r}; have {sorted(correctors)}"
        )
    corrector_fn, evals_per_corrector = correctors[corrector]

    def body(carry, i):
        x, key = carry
        t = jnp.full((batch,), ts[i])
        t_next = jnp.full((batch,), ts[i + 1])

        # --- corrector first (as in Song et al.'s released sampler) ----
        def corr_body(j, val):
            x, key = val
            return corrector_fn(x, t, key)

        x, key = jax.lax.fori_loop(0, corrector_steps, corr_body, (x, key))

        # --- reverse-diffusion (ancestral) predictor --------------------
        key, sub = jax.random.split(key)
        z = jax.random.normal(sub, x.shape, x.dtype)
        score = score_fn(x, t)
        if is_ve:
            s_t = sde.sigma(t)
            s_n = sde.sigma(t_next)
            var = jnp.maximum(s_t**2 - s_n**2, 0.0)
            x = x + _e(var, x) * score + _e(jnp.sqrt(var), x) * z
        else:
            beta = sde.beta(t) * (sde.T - sde.t_eps) / n_steps  # discrete β_i
            x = (
                _e(2.0 - jnp.sqrt(1.0 - beta), x) * x
                + _e(beta, x) * score
                + _e(jnp.sqrt(beta), x) * z
            )
        return (x, key), None

    (x, key), _ = jax.lax.scan(body, (x_init, key), jnp.arange(n_steps))
    nfe_per_step = 1 + corrector_steps * evals_per_corrector
    nfe = jnp.full((batch,), n_steps * nfe_per_step, jnp.int32)
    if denoise:
        t = jnp.full((batch,), sde.t_eps)
        x = sde.tweedie_denoise(x, score_fn(x, t))
        nfe = nfe + 1
    zeros = jnp.zeros((batch,), jnp.int32)
    return SolveResult(
        x=x, nfe=nfe, iterations=jnp.asarray(n_steps, jnp.int32),
        accepted=zeros, rejected=zeros,
    )


def _pc_hmc_nfe_per_iter(corrector_steps: int = 1, hmc_leapfrog: int = 3,
                         **_) -> int:
    return _pc_nfe_per_iter(corrector_steps=corrector_steps, corrector="hmc",
                            hmc_leapfrog=hmc_leapfrog)


@register_solver("pc_hmc", nfe_per_iter=_pc_hmc_nfe_per_iter)
def predictor_corrector_hmc(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    n_steps: int = 1000,
    corrector_steps: int = 1,
    snr: float | None = None,
    denoise: bool = True,
    hmc_leapfrog: int = 3,
) -> SolveResult:
    """Ancestral predictor + uncorrected-HMC corrector (DESIGN.md §11).

    The same PC sampler through the corrector seam with
    ``corrector="hmc"``; NFE accounting reflects the L score evaluations
    each HMC pass spends (``1 + corrector_steps·L`` per grid step).
    """
    return predictor_corrector(
        sde, score_fn, x_init, key,
        n_steps=n_steps, corrector_steps=corrector_steps, snr=snr,
        denoise=denoise, corrector="hmc", hmc_leapfrog=hmc_leapfrog,
    )
