"""Reverse-Diffusion (ancestral) predictor + Langevin corrector.

This is the paper's strongest VE baseline ("Reverse-Diffusion &
Langevin", Table 1) following Song et al. 2020a's PC sampler:

  predictor (VE): x ← x + (σ_i² − σ_{i-1}²) s(x, t_i) + sqrt(σ_i² − σ_{i-1}²) z
  predictor (VP): x ← (2 − sqrt(1 − β_i)) x + β_i s(x, t_i) + sqrt(β_i) z
  corrector     : annealed Langevin with step ε = 2 α (r ‖z‖/‖s‖)²

with signal-to-noise ratio r (0.16 for VE, 0.01 for VP in the original
code) and α = 1 (VE) or 1 − β_i (VP).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE, VESDE, VPSDE
from .base import SolveResult, register_solver

Array = jax.Array


def _e(v, x):
    return v.reshape(v.shape + (1,) * (x.ndim - 1))


def _norm(v: Array) -> Array:
    return jnp.sqrt(jnp.sum(v * v, axis=tuple(range(1, v.ndim))))


@register_solver("pc")
def predictor_corrector(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,
    *,
    n_steps: int = 1000,
    corrector_steps: int = 1,
    snr: float | None = None,
    denoise: bool = True,
) -> SolveResult:
    batch = x_init.shape[0]
    is_ve = isinstance(sde, VESDE)
    if snr is None:
        snr = 0.16 if is_ve else 0.01
    ts = jnp.linspace(sde.T, sde.t_eps, n_steps + 1)

    def langevin(x, t, key):
        key, sub = jax.random.split(key)
        score = score_fn(x, t)
        z = jax.random.normal(sub, x.shape, x.dtype)
        alpha = jnp.ones_like(t) if is_ve else 1.0 - sde.beta(t) / n_steps
        step = 2.0 * alpha * (snr * _norm(z) / jnp.maximum(_norm(score), 1e-12)) ** 2
        x = x + _e(step, x) * score + _e(jnp.sqrt(2.0 * step), x) * z
        return x, key

    def body(carry, i):
        x, key = carry
        t = jnp.full((batch,), ts[i])
        t_next = jnp.full((batch,), ts[i + 1])

        # --- corrector first (as in Song et al.'s released sampler) ----
        def corr_body(j, val):
            x, key = val
            return langevin(x, t, key)

        x, key = jax.lax.fori_loop(0, corrector_steps, corr_body, (x, key))

        # --- reverse-diffusion (ancestral) predictor --------------------
        key, sub = jax.random.split(key)
        z = jax.random.normal(sub, x.shape, x.dtype)
        score = score_fn(x, t)
        if is_ve:
            s_t = sde.sigma(t)
            s_n = sde.sigma(t_next)
            var = jnp.maximum(s_t**2 - s_n**2, 0.0)
            x = x + _e(var, x) * score + _e(jnp.sqrt(var), x) * z
        else:
            beta = sde.beta(t) * (sde.T - sde.t_eps) / n_steps  # discrete β_i
            x = (
                _e(2.0 - jnp.sqrt(1.0 - beta), x) * x
                + _e(beta, x) * score
                + _e(jnp.sqrt(beta), x) * z
            )
        return (x, key), None

    (x, key), _ = jax.lax.scan(body, (x_init, key), jnp.arange(n_steps))
    nfe_per_step = 1 + corrector_steps
    nfe = jnp.full((batch,), n_steps * nfe_per_step, jnp.int32)
    if denoise:
        t = jnp.full((batch,), sde.t_eps)
        x = sde.tweedie_denoise(x, score_fn(x, t))
        nfe = nfe + 1
    zeros = jnp.zeros((batch,), jnp.int32)
    return SolveResult(
        x=x, nfe=nfe, iterations=jnp.asarray(n_steps, jnp.int32),
        accepted=zeros, rejected=zeros,
    )
