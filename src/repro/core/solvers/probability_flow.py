"""Probability-flow ODE baseline, solved with adaptive RK45 (Dormand–Prince).

Song et al. 2020a solve dx = [f(x,t) − ½ g(t)² s(x,t)] dt with
scipy's RK45 at rtol=atol=1e-5. We implement Dormand–Prince 5(4) as a
device-side ``lax.while_loop`` with the same global (whole-batch) error
control scipy uses on the flattened state, so NFE is batch-global —
matching how the paper reports it.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.sde import SDE
from .base import SolveResult, register_solver

Array = jax.Array

# Dormand–Prince Butcher tableau.
_C = jnp.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0])
_A = [
    [],
    [1 / 5],
    [3 / 40, 9 / 40],
    [44 / 45, -56 / 15, 32 / 9],
    [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
    [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
    [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
]
_B5 = jnp.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
_B4 = jnp.array(
    [5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40]
)


@register_solver("ode", nfe_per_iter=6)
def probability_flow_rk45(
    sde: SDE,
    score_fn: Callable[[Array, Array], Array],
    x_init: Array,
    key: Array,  # unused (deterministic); kept for API uniformity
    *,
    rtol: float = 1e-5,
    atol: float = 1e-5,
    h_init: float = 0.01,
    max_iters: int = 100_000,
    denoise: bool = True,
) -> SolveResult:
    del key
    batch = x_init.shape[0]

    def f(x: Array, t: Array) -> Array:
        """Reverse-time ODE drift as dx/ds with s = T − t (so s runs up)."""
        tt = jnp.full((batch,), t)
        return -sde.ode_drift(x, tt, score_fn(x, tt))

    span = sde.T - sde.t_eps

    def cond(state):
        x, s, h, nfe, iters, k1 = state
        return jnp.logical_and(s < span - 1e-12, iters < max_iters)

    def body(state):
        x, s, h, nfe, iters, k1 = state
        h = jnp.minimum(h, span - s)
        ks = [k1]
        for i in range(1, 7):
            xi = x
            for j, a in enumerate(_A[i]):
                xi = xi + h * a * ks[j]
            ks.append(f(xi, sde.T - (s + _C[i] * h)))
        x5 = x
        x4 = x
        for i in range(7):
            x5 = x5 + h * _B5[i] * ks[i]
            x4 = x4 + h * _B4[i] * ks[i]
        scale = atol + rtol * jnp.maximum(jnp.abs(x), jnp.abs(x5))
        err = jnp.sqrt(jnp.mean(((x5 - x4) / scale) ** 2))  # global norm
        accept = err <= 1.0
        x_new = jnp.where(accept, x5, x)
        s_new = jnp.where(accept, s + h, s)
        # FSAL: on accept, k7 is next step's k1; on reject, keep k1.
        k1_new = jnp.where(accept, ks[6], k1)
        factor = jnp.clip(0.9 * err ** (-0.2), 0.2, 10.0)
        h_new = h * factor
        # 6 fresh evals per attempt (k1 reused via FSAL).
        return (x_new, s_new, h_new, nfe + 6, iters + 1, k1_new)

    k1_0 = f(x_init, jnp.asarray(sde.T))
    init = (
        x_init,
        jnp.asarray(0.0, jnp.float32),
        jnp.asarray(h_init, jnp.float32),
        jnp.asarray(1, jnp.int32),
        jnp.asarray(0, jnp.int32),
        k1_0,
    )
    x, s, h, nfe, iters, _ = jax.lax.while_loop(cond, body, init)

    if denoise:
        t = jnp.full((batch,), sde.t_eps)
        x = sde.tweedie_denoise(x, score_fn(x, t))
        nfe = nfe + 1
    nfe_b = jnp.full((batch,), nfe, jnp.int32)
    zeros = jnp.zeros((batch,), jnp.int32)
    return SolveResult(x=x, nfe=nfe_b, iterations=iters, accepted=zeros, rejected=zeros)
