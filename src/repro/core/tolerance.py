"""Mixed tolerance and scaled error norms (paper Sec. 3.1.2–3.1.3).

The mixed tolerance follows DifferentialEquations.jl's variant
(Eq. 5 of the paper), which the ablation found much faster for VE:

    δ(x', x'_prev) = max(ε_abs, ε_rel * max(|x'|, |x'_prev|))

The scaled error uses the dimension-normalized ℓ2 norm (Sec. 3.1.3):

    E₂ = sqrt( mean( ((x' - x'') / δ)² ) )

so one bad pixel out of 65k cannot stall the whole solver the way the
traditional ℓ∞ norm does.  Both the paper's choice and the ablation
alternatives (δ(x') only, q=∞) are provided for the ablation benchmark.

All reductions are per-sample: state is (B, ...) and norms reduce over
every axis except the first, returning (B,).

Precision (DESIGN.md §8): tolerance and error arithmetic is *control
path* — every function here upcasts its tensor inputs to fp32 before
doing math and returns fp32, regardless of the precision policy the
state tensors run under. Under the default fp32 policy the upcasts are
same-dtype no-ops, so the numerics are bit-identical to the unpoliced
code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def mixed_tolerance(
    x_low: Array,
    x_prev: Array | None,
    eps_abs: "float | Array",
    eps_rel: "float | Array",
) -> Array:
    """δ per element (fp32). Pass x_prev=None for the δ(x') ablation variant.

    ``eps_abs``/``eps_rel`` may be Python floats (one tolerance for the
    whole batch — the static-config path) or fp32 arrays broadcastable
    against ``x_low`` (per-sample tolerance classes, DESIGN.md §14, e.g.
    (B, 1, ..., 1)-expanded (B,) carry leaves). The float path is
    bitwise identical either way: same fp32 elementwise max/multiply.
    """
    mag = jnp.abs(x_low.astype(jnp.float32))
    if x_prev is not None:
        mag = jnp.maximum(mag, jnp.abs(x_prev.astype(jnp.float32)))
    return jnp.maximum(eps_abs, eps_rel * mag)


def _reduce_axes(x: Array) -> tuple:
    return tuple(range(1, x.ndim))


def scaled_error_l2(x_low: Array, x_high: Array, delta: Array) -> Array:
    """Per-sample E₂ = ||(x' - x'')/δ||₂ / sqrt(n); fp32, shape (B,)."""
    r = (x_low.astype(jnp.float32) - x_high.astype(jnp.float32)) / delta
    return jnp.sqrt(jnp.mean(r * r, axis=_reduce_axes(x_low)))


def scaled_error_linf(x_low: Array, x_high: Array, delta: Array) -> Array:
    """Per-sample E∞ (ablation variant); fp32, shape (B,)."""
    r = jnp.abs(
        (x_low.astype(jnp.float32) - x_high.astype(jnp.float32)) / delta
    )
    return jnp.max(r, axis=_reduce_axes(x_low))


def next_step_size(
    h: Array,
    err: Array,
    t_remaining: Array,
    *,
    safety: float = 0.9,
    r_exponent: float = 0.9,
    h_min: float = 0.0,
) -> Array:
    """h ← clip(θ · h · E^{-r}, h_min, t_remaining)  (paper Sec. 3.1.4).

    ``err`` is clamped below to avoid h → inf when the error is ~0.
    Control-path math: fp32 regardless of the state dtype.
    """
    err = jnp.maximum(err.astype(jnp.float32), 1e-8)
    h_new = safety * h * err ** (-r_exponent)
    return jnp.clip(h_new, h_min, jnp.maximum(t_remaining, h_min))
