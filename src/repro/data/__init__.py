from .images import GMM2D, GMMImageConfig, data_moments, sample_images
from .tokens import (
    TokenPipelineConfig,
    apply_delay_pattern,
    batches,
    lm_loss,
    synth_batch,
)

__all__ = [
    "GMM2D", "GMMImageConfig", "data_moments", "sample_images",
    "TokenPipelineConfig", "apply_delay_pattern", "batches", "lm_loss",
    "synth_batch",
]
