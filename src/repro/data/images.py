"""Synthetic image datasets with analytically known structure.

The paper measures FID against CIFAR/LSUN/FFHQ; offline we need data
whose true distribution is *known* so quality can be scored exactly:

  * ``GaussianMixtureImages`` — each image is a smooth random field from
    a K-component Gaussian mixture in a low-dim latent, decoded through
    a fixed random linear map + tanh. Mean/covariance of the pixel
    distribution are estimable to high precision from the generator.
  * ``gmm_2d`` — the 2-D mixture used by solver-validation tests where
    the exact score is available in closed form.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GMMImageConfig:
    image_size: int = 32
    channels: int = 3
    latent_dim: int = 16
    n_components: int = 8
    seed: int = 1234
    value_range: Tuple[float, float] = (-1.0, 1.0)  # match VP convention


def _generator_params(cfg: GMMImageConfig):
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2, k3 = jax.random.split(key, 3)
    means = 2.0 * jax.random.normal(k1, (cfg.n_components, cfg.latent_dim))
    d = cfg.image_size * cfg.image_size * cfg.channels
    # smooth decoder: random low-freq basis
    basis = jax.random.normal(k2, (cfg.latent_dim, d)) / jnp.sqrt(cfg.latent_dim)
    scales = 0.3 + 0.7 * jax.random.uniform(k3, (cfg.n_components,))
    return means, basis, scales


def sample_images(cfg: GMMImageConfig, key: Array, n: int) -> Array:
    means, basis, scales = _generator_params(cfg)
    kc, kz = jax.random.split(key)
    comp = jax.random.randint(kc, (n,), 0, cfg.n_components)
    z = jax.random.normal(kz, (n, cfg.latent_dim))
    z = means[comp] + scales[comp][:, None] * z
    flat = jnp.tanh(z @ basis)
    lo, hi = cfg.value_range
    flat = lo + (hi - lo) * (flat + 1.0) / 2.0
    return flat.reshape(n, cfg.image_size, cfg.image_size, cfg.channels)


def data_moments(cfg: GMMImageConfig, n: int = 8192, seed: int = 7):
    """Monte-Carlo estimate of the data mean/cov used by the Fréchet metric."""
    x = sample_images(cfg, jax.random.PRNGKey(seed), n)
    flat = x.reshape(n, -1)
    mu = jnp.mean(flat, axis=0)
    xc = flat - mu
    # full covariance is d×d (3072²) — use diagonal + low-rank summary:
    var = jnp.mean(xc * xc, axis=0)
    return mu, var


# --------------------------------------------------------------------------
# 2-D Gaussian mixture with exact score (solver validation)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GMM2D:
    means: tuple = ((-2.0, -2.0), (2.0, 2.0), (-2.0, 2.0), (2.0, -2.0))
    std: float = 0.5
    weights: tuple = (0.25, 0.25, 0.25, 0.25)

    def sample(self, key: Array, n: int) -> Array:
        kc, kz = jax.random.split(key)
        comp = jax.random.choice(
            kc, len(self.weights), (n,), p=jnp.asarray(self.weights)
        )
        mu = jnp.asarray(self.means)[comp]
        return mu + self.std * jax.random.normal(kz, (n, 2))

    def score_at_time(self, sde):
        """Exact ∇log p_t for this mixture diffused by ``sde``."""
        means = jnp.asarray(self.means)  # (K, 2)
        w = jnp.asarray(self.weights)

        def score(x: Array, t: Array) -> Array:
            m, s = sde.marginal(t)  # (B,)
            mu_t = m[:, None, None] * means[None]          # (B, K, 2)
            var_t = (m * self.std) ** 2 + s**2             # (B,)
            diff = x[:, None, :] - mu_t                    # (B, K, 2)
            sq = jnp.sum(diff * diff, axis=-1)             # (B, K)
            logw = jnp.log(w)[None] - 0.5 * sq / var_t[:, None] \
                - jnp.log(var_t[:, None])
            post = jax.nn.softmax(logw, axis=-1)           # (B, K)
            grad = -jnp.einsum("bk,bkd->bd", post, diff) / var_t[:, None]
            return grad

        return score
