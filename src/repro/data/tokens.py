"""Deterministic synthetic token pipeline for LM training/serving.

Offline container ⇒ no real corpora. The stream is a seeded Markov-ish
mixture that is (a) deterministic per (seed, step) so multi-host data
sharding is reproducible without coordination, (b) non-uniform (Zipfian
marginals + local repetition structure) so cross-entropy actually
decreases during the smoke trainings, and (c) cheap to generate on
device inside the input pipeline.

MusicGen-style multi-codebook streams add the delay pattern: codebook k
is shifted right by k steps (arXiv:2306.05284 §2.2), with token 0 as the
pad/start id.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_codebooks: int = 1
    seed: int = 0
    zipf_a: float = 1.2  # Zipf exponent for the marginal distribution


def _zipf_logits(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    return np.log(ranks ** -a)


def synth_batch(cfg: TokenPipelineConfig, step: int) -> Array:
    """Batch of tokens (B, S) or (B, S, K), deterministic in (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    logits = jnp.asarray(_zipf_logits(cfg.vocab_size, cfg.zipf_a), jnp.float32)

    def one_stream(k):
        k1, k2, k3 = jax.random.split(k, 3)
        base = jax.random.categorical(
            k1, jnp.broadcast_to(logits, (cfg.seq_len, cfg.vocab_size))
        )
        # local repetition: with p=0.3 copy the previous token (bigram mass)
        rep = jax.random.bernoulli(k2, 0.3, (cfg.seq_len,))
        shifted = jnp.concatenate([base[:1], base[:-1]])
        toks = jnp.where(rep, shifted, base)
        # periodic motif: every 64 tokens insert a "header" id
        pos = jnp.arange(cfg.seq_len)
        motif = (pos % 64 == 0)
        return jnp.where(motif, jnp.zeros_like(toks), toks)

    n_streams = cfg.global_batch * max(cfg.num_codebooks, 1)
    keys = jax.random.split(key, n_streams)
    toks = jax.vmap(one_stream)(keys)
    if cfg.num_codebooks > 1:
        toks = toks.reshape(cfg.global_batch, cfg.num_codebooks, cfg.seq_len)
        toks = jnp.transpose(toks, (0, 2, 1))  # (B, S, K)
        toks = apply_delay_pattern(toks)
    else:
        toks = toks.reshape(cfg.global_batch, cfg.seq_len)
    return toks.astype(jnp.int32)


def apply_delay_pattern(tokens: Array) -> Array:
    """MusicGen delay: codebook k shifted right by k, pad id 0. (B,S,K)."""
    B, S, K = tokens.shape
    cols = []
    for k in range(K):
        shifted = jnp.concatenate(
            [jnp.zeros((B, k), tokens.dtype), tokens[:, : S - k, k]], axis=1
        )
        cols.append(shifted)
    return jnp.stack(cols, axis=-1)


def batches(cfg: TokenPipelineConfig, start_step: int = 0) -> Iterator[Array]:
    step = start_step
    while True:
        yield synth_batch(cfg, step)
        step += 1


def lm_loss(logits: Array, tokens: Array) -> Array:
    """Next-token CE. logits (B,S,V) or (B,S,K,V); tokens (B,S[,K])."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    pred = logp[:, :-1]
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
