"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

Each kernel subpackage ships: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper, CPU-interpret fallback), ref.py (pure-jnp
oracle used by the allclose test sweeps).
"""
