"""Pallas TPU flash attention (forward), causal + sliding-window, GQA.

Online-softmax tiling: grid = (B, Hq, Sq/bq, Sk/bk). The trailing grid
axis (key blocks) executes sequentially on TPU, so the running max `m`,
normalizer `l`, and output accumulator live in VMEM scratch revisited
across key blocks. Out-of-band blocks (fully masked by causality or the
sliding window) are skipped with ``pl.when`` — with a window W the skip
turns O(S²) work into O(S·W), which is what lets the dense architectures
run the long_500k shape (DESIGN.md §4).

Block sizes default to the MXU-native (128, 128); D rides whole (the
head dim is ≤ 256 for every assigned architecture).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_k: int, seq_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[:, :] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[:, :] = jnp.zeros_like(l_scr)
        acc_scr[:, :] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Block-level visibility: causal ⇒ need k_start <= q_end;
    # window  ⇒ need k_end > q_start - window.
    visible = jnp.asarray(True)
    if causal:
        visible = jnp.logical_and(visible, k_start <= q_start + block_q - 1)
    if window is not None:
        visible = jnp.logical_and(visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0, :, :].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[:, :]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = corr * l_scr[:, :] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[:, :] = corr * acc_scr[:, :] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[:, :] = m_new
        l_scr[:, :] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, :]
        # Rows with no visible keys (can't happen under causal; guard anyway).
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[:, :] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "block_q", "block_k", "true_len", "interpret"
    ),
)
def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    true_len: int | None = None,
    interpret: bool = False,
) -> Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(S, bk)

    q_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0))
    o_spec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))

    return pl.pallas_call(
        functools.partial(
            _fa_kernel,
            scale=float(scale), causal=causal, window=window,
            block_q=bq, block_k=bk, seq_len=true_len if true_len is not None else S,
        ),
        grid=(B, Hq, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
