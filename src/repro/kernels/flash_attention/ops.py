"""Public wrapper for the flash-attention kernel.

Pads S up to the q/k block size (padded keys are masked off inside the
kernel via ``kpos < seq_len``… note the kernel masks with the *padded*
length, so we mask padded keys here by padding k with -inf-safe zeros
and relying on the causal mask: padded queries only attend to padded
keys and are sliced away; padded keys sit at positions ≥ true S and are
invisible to true queries under causality). For the non-causal case we
explicitly pass the true sequence length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _k

Array = jax.Array


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = _k.DEFAULT_BLOCK_Q,
    block_k: int = _k.DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    B, Hq, S, D = q.shape
    bq = min(block_q, max(S, 8))
    pad = (-S) % bq
    if pad:
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    out = _k.flash_attention(
        q, k, v,
        causal=causal, window=window, scale=scale,
        block_q=bq, block_k=min(block_k, q.shape[2]),
        true_len=S,
        interpret=interpret,
    )
    return out[:, :, :S, :]
