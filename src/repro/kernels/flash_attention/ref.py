"""Pure-jnp oracle for flash attention: causal (+sliding-window) GQA MHA.

q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
window: None → full causal; int W → position i attends to [i-W+1, i].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale

    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)
