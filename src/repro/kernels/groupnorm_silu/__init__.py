"""Fused GroupNorm→SiLU Pallas kernel family (DESIGN.md §13).

Same layout as ``kernels/solver_step``: ``kernel.py`` is the Pallas TPU
kernel, ``ops.py`` the shape-handling public wrapper (CPU interpreter
fallback included), ``ref.py`` the pure-jnp oracle the parity tests
compare against.
"""

from . import kernel, ops, ref  # noqa: F401

from .ops import groupnorm_silu  # noqa: F401
