"""Pallas TPU kernel: fused GroupNorm → SiLU over (B, H, C) activations.

Why a kernel: the temporal UNet's residual blocks (DESIGN.md §10) run
GroupNorm→SiLU twice per block, and left to XLA the chain streams the
activation HBM→VMEM four times (read for the mean/var reduction, read
for the normalize, write the norm, read+write for the SiLU — the
cross-axis group reduction splits the fusion the same way the solver
step's error reduction does, §2). One sample's (H, C) slab is tiny
(≤ 32×128 for every trajectory shape), so the whole per-sample
statistics + normalize + activation chain fits in VMEM: one HBM read,
one HBM write.

Tiling: grid = (B/bb,); each program holds a (bb, H, C) block. Group
statistics are per (sample, group) — reductions over H (sublanes) use
the VPU, and the C-lane → group-lane reduction goes through the MXU as
a matmul with the one-hot group-membership matrix ``m`` (C, g): lane
reshapes are not TPU-native, matmuls are. The inverse map (broadcast
group stats back to their C lanes) is the transposed contraction of the
same matrix.

Precision (DESIGN.md §8): operands may be bf16 — the tile is upcast to
fp32 in-register, statistics use the two-pass form (mean first, then
mean of squared deviations — no E[x²]−μ² cancellation), scale/bias
apply in fp32, SiLU runs in fp32, and ONE rounding happens at the
store. The jnp reference path rounds twice (GroupNorm output, then
SiLU); the oracle in ``ref.py`` mirrors the kernel's single-rounding
contract and the parity tests hold the unfused chain to bf16 tolerance
against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# batch rows per grid program; (H, C) ride whole — per-sample statistics
# need the full slab, and every temporal-UNet shape fits VMEM with room
# to spare.
DEFAULT_BLOCK_B = 8


def _gn_silu_kernel(x_ref, s_ref, b_ref, m_ref, o_ref, *, eps: float,
                    inv_n: float):
    x = x_ref[...].astype(jnp.float32)       # (bb, H, C)
    m = m_ref[...]                           # (C, g) fp32 one-hot

    # mean per (sample, group): VPU sum over H, MXU fold C → g
    sum_h = jnp.sum(x, axis=1)               # (bb, C)
    mu_g = jax.lax.dot_general(
        sum_h, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) * inv_n                                # (bb, g)
    # broadcast group means back onto their C lanes (contract m's g axis)
    mu = jax.lax.dot_general(
        mu_g, m, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (bb, C)

    # two-pass variance: mean of squared deviations (matches jnp.var's
    # numerics; no large-offset cancellation)
    d = x - mu[:, None, :]                   # (bb, H, C)
    ssq_h = jnp.sum(d * d, axis=1)           # (bb, C)
    var_g = jax.lax.dot_general(
        ssq_h, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) * inv_n                                # (bb, g)
    rstd = jax.lax.dot_general(
        jax.lax.rsqrt(var_g + eps), m, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (bb, C)

    y = d * rstd[:, None, :] * s_ref[...] + b_ref[...]  # (1, C) broadcasts
    o_ref[...] = (y * jax.nn.sigmoid(y)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_b", "interpret")
)
def groupnorm_silu(
    x: Array,
    scale: Array,
    bias: Array,
    member: Array,
    *,
    eps: float = 1e-6,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> Array:
    """silu(groupnorm(x)) in one HBM pass.

    x (B, H, C); scale/bias (1, C) fp32; member (C, g) fp32 one-hot
    group membership (column j is 1 on group j's lanes). Statistics are
    per (sample, group) over the (H, C/g) slab — ``inv_n`` below is the
    exact reciprocal element count. Output is in x's dtype; all
    intermediate math is fp32 (DESIGN.md §8 norm rule).
    """
    B, H, C = x.shape
    g = member.shape[1]
    bb = min(block_b, B)
    inv_n = 1.0 / (H * (C // g))
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        functools.partial(_gn_silu_kernel, eps=float(eps), inv_n=inv_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, H, C), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((C, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H, C), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, C), x.dtype),
        interpret=interpret,
    )(x, scale, bias, member)
