"""Public wrapper for the fused GroupNorm→SiLU kernel.

Resolves the group count the way the temporal UNet's ``_groupnorm``
does (``g = min(groups, C)``), builds the one-hot group-membership
matrix the kernel's MXU lane→group reduction consumes, upcasts the
affine params to fp32 (norm math is fp32 under every precision preset,
DESIGN.md §8), and dispatches with ``interpret=True`` on CPU so the
same code path is exercised everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _k

Array = jax.Array


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def groupnorm_silu(
    x: Array,
    scale: Array,
    bias: Array,
    *,
    groups: int,
    eps: float = 1e-6,
    block_b: int = _k.DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> Array:
    """silu(groupnorm(x, scale, bias)) fused; x (B, H, C) → (B, H, C).

    ``scale``/``bias`` are (C,) and may be any float dtype (a precision
    policy stores bf16 copies) — they apply in fp32 either way. Output
    is in x's dtype, rounded once.
    """
    interpret = _on_cpu() if interpret is None else interpret
    B, H, C = x.shape
    g = min(groups, C)
    if C % g:
        raise ValueError(f"channels {C} not divisible by groups {g}")
    # one-hot membership: lane c belongs to group c // (C/g)
    member = (
        jnp.arange(C)[:, None] // (C // g) == jnp.arange(g)[None, :]
    ).astype(jnp.float32)
    return _k.groupnorm_silu(
        x,
        scale.astype(jnp.float32).reshape(1, C),
        bias.astype(jnp.float32).reshape(1, C),
        member,
        eps=float(eps),
        block_b=block_b,
        interpret=interpret,
    )
