"""Pure-jnp oracle for the fused GroupNorm→SiLU kernel.

x (B, H, C); scale/bias (C,). Statistics are per (sample, group) over
the (H, C/g) slab with g = min(groups, C), exactly the temporal UNet's
``_groupnorm`` contract (DESIGN.md §10).

Precision contract (mirrors the kernel, DESIGN.md §8): operands may be
bf16; the statistics, normalize, affine, and SiLU all run in fp32 and
the output rounds ONCE to the operand dtype. For fp32 operands every
cast is a no-op, which makes the oracle bit-comparable to the unfused
``silu(_groupnorm(...))`` chain there (same jnp reductions, same order).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def groupnorm_silu(x: Array, scale: Array, bias: Array, *, groups: int,
                   eps: float = 1e-6) -> Array:
    B, H, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, C)
    y = xn * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return (y * jax.nn.sigmoid(y)).astype(x.dtype)
