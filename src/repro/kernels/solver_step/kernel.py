"""Pallas TPU kernel: fused adaptive-solver step (paper Algorithm 1 body).

Why a kernel: one adaptive step performs ~10 elementwise passes over
(B, D) fp32 state (second Euler form, extrapolated average, tolerance,
scaled residual, square, reduce). Left to XLA these fuse only partially
(the reduction splits the fusion), so the state streams HBM→VMEM several
times. At image scale (B=128, D=196k for 256²×3) the step is purely
HBM-bandwidth-bound; fusing everything into a single pass with an
in-VMEM error accumulation is the TPU-native adaptation of the paper's
"only two score evaluations" economy (DESIGN.md §3).

Tiling: grid = (B/bb, D/bd); each program handles a (bb, bd) tile held
in VMEM. The per-sample squared-residual sum accumulates into a (bb,)
output tile revisited across the D-grid dimension (TPU grids execute
the trailing axis sequentially, so accumulation is race-free).

Per-sample coefficients (c's/d's, shape (B,)) ride in SMEM-friendly
(bb, 1) blocks.

Precision (DESIGN.md §8): tensor operands may be bf16 — that halves the
HBM traffic of the (already bandwidth-bound) step. Each VMEM tile is
upcast to fp32 in-register, the whole step arithmetic and the
squared-residual accumulation run in fp32 (the (bb, 1) accumulator
block is an fp32 output living in VMEM across the D-grid sweep), and
only the x'' store rounds back to the operand dtype. The accept/reject
decision therefore sees the same fp32 error the jnp reference computes
from identical inputs. bf16 tiles use a 16-sublane minimum (vs 8 for
fp32), so the default batch block doubles for bf16 operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# (sublane, lane)-aligned default tile.
DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_D = 512


def _block_b_for(dtype, block_b: int) -> int:
    """Sublane-align the batch block: bf16 tiles want 16 sublanes."""
    if jnp.dtype(dtype).itemsize < 4 and block_b == DEFAULT_BLOCK_B:
        return 2 * DEFAULT_BLOCK_B
    return block_b


def _blocks_for(dtype, B: int, D: int, block_b: int, block_d: int):
    """Resolve the (bb, bd) tile for a (B, D) operand.

    Image-scale states (D ≫ 512) keep the default (8, 512) tile. But
    trajectory-planning states flatten *narrow*: (H=16, D=6) → 96 and
    (H=32, D=8) → 256 flat features, lane-padded to 128/256 — far below
    DEFAULT_BLOCK_D, and not multiples of the default 512 either. With
    the default tile those rows launch one grid program per 8 slots
    touching a sliver of VMEM each, so the per-program overhead dominates
    the (tiny) elementwise work. When the caller left both blocks at
    their defaults and D underfills the default lane block, widen the
    *batch* block instead to keep roughly the default tile footprint
    (bb·bd ≈ 8·512 elements), sublane-aligned (8 fp32 / 16 bf16) and
    clamped to B — measured ~2-4× fewer grid programs on the
    traj16x6/traj32x8 serving rows (benchmarks/bench_device_serving.py)
    with bit-identical outputs (rows are independent; the D-grid sweep
    per row is unchanged).
    """
    bb = _block_b_for(dtype, block_b)
    bd = min(block_d, D)
    if (block_b == DEFAULT_BLOCK_B and block_d == DEFAULT_BLOCK_D
            and D < DEFAULT_BLOCK_D):
        sublanes = 16 if jnp.dtype(dtype).itemsize < 4 else 8
        widened = (DEFAULT_BLOCK_B * DEFAULT_BLOCK_D // bd) // sublanes * sublanes
        bb = max(bb, min(widened, B))
    return min(bb, B), bd


def _em_kernel(x_ref, s_ref, z_ref, c0_ref, c1_ref, c2_ref, out_ref):
    c0 = c0_ref[:, :]  # (bb, 1) fp32, broadcasts over lanes
    c1 = c1_ref[:, :]
    c2 = c2_ref[:, :]
    x = x_ref[:, :].astype(jnp.float32)
    s = s_ref[:, :].astype(jnp.float32)
    z = z_ref[:, :].astype(jnp.float32)
    out_ref[:, :] = (c0 * x + c1 * s + c2 * z).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d", "interpret"))
def em_step(
    x: Array,
    score: Array,
    z: Array,
    c0: Array,
    c1: Array,
    c2: Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> Array:
    """x' = c0·x + c1·score + c2·z, one fused HBM pass (fp32 math)."""
    B, D = x.shape
    bb, bd = _blocks_for(x.dtype, B, D, block_b, block_d)
    grid = (pl.cdiv(B, bb), pl.cdiv(D, bd))
    coeff_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))
    state_spec = pl.BlockSpec((bb, bd), lambda i, j: (i, j))
    return pl.pallas_call(
        _em_kernel,
        grid=grid,
        in_specs=[state_spec, state_spec, state_spec,
                  coeff_spec, coeff_spec, coeff_spec],
        out_specs=state_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=interpret,
    )(x, score, z, c0[:, None], c1[:, None], c2[:, None])


def _error_kernel(
    x_ref, xp_ref, s2_ref, z_ref, xprev_ref,
    e0_ref, d1_ref, d2_ref,
    xh_ref, acc_ref,
    *, eps_abs: float, eps_rel: float, use_prev: bool,
):
    j = pl.program_id(1)

    # upcast the VMEM tile to fp32: the step arithmetic, tolerance, and
    # residual accumulation are fp32 even for bf16 operands (no-op for
    # fp32 operands); only the x'' store rounds back down
    x = x_ref[:, :].astype(jnp.float32)
    xp = xp_ref[:, :].astype(jnp.float32)
    s2 = s2_ref[:, :].astype(jnp.float32)
    z = z_ref[:, :].astype(jnp.float32)
    x_tilde = x - e0_ref[:, :] * xp + d1_ref[:, :] * s2 + d2_ref[:, :] * z
    x_high = 0.5 * (xp + x_tilde)
    xh_ref[:, :] = x_high.astype(xh_ref.dtype)

    mag = jnp.abs(xp)
    if use_prev:
        mag = jnp.maximum(mag, jnp.abs(xprev_ref[:, :].astype(jnp.float32)))
    delta = jnp.maximum(eps_abs, eps_rel * mag)
    r = (xp - x_high) / delta
    partial = jnp.sum(r * r, axis=1, keepdims=True)  # (bb, 1) fp32

    @pl.when(j == 0)
    def _init():
        acc_ref[:, :] = jnp.zeros_like(acc_ref)

    acc_ref[:, :] += partial


@functools.partial(
    jax.jit,
    static_argnames=("eps_abs", "eps_rel", "use_prev", "block_b", "block_d", "interpret"),
)
def error_step(
    x: Array,
    x_prime: Array,
    score2: Array,
    z: Array,
    x_prev: Array,
    e0: Array,
    d1: Array,
    d2: Array,
    *,
    eps_abs: float,
    eps_rel: float,
    use_prev: bool = True,
    block_b: int = DEFAULT_BLOCK_B,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """Fused x̃/x''/δ/residual-reduction. Returns (x'' (B,D) in x's
    dtype, e2 (B,) fp32 — the error/decision path never downcasts)."""
    B, D = x.shape
    bb, bd = _blocks_for(x.dtype, B, D, block_b, block_d)
    grid = (pl.cdiv(B, bb), pl.cdiv(D, bd))
    state_spec = pl.BlockSpec((bb, bd), lambda i, j: (i, j))
    coeff_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))
    acc_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))

    x_high, acc = pl.pallas_call(
        functools.partial(
            _error_kernel, eps_abs=eps_abs, eps_rel=eps_rel, use_prev=use_prev
        ),
        grid=grid,
        in_specs=[state_spec] * 5 + [coeff_spec] * 3,
        out_specs=(state_spec, acc_spec),
        out_shape=(
            jax.ShapeDtypeStruct((B, D), x.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x, x_prime, score2, z, x_prev, e0[:, None], d1[:, None], d2[:, None])
    e2 = jnp.sqrt(acc[:, 0] / D)
    return x_high, e2


def _error_kernel_vec(
    x_ref, xp_ref, s2_ref, z_ref, xprev_ref,
    e0_ref, d1_ref, d2_ref, ea_ref, er_ref,
    xh_ref, acc_ref,
    *, use_prev: bool,
):
    """``_error_kernel`` with ε_abs/ε_rel as per-sample (bb, 1) coeff
    blocks instead of compile-time floats (DESIGN.md §14): tolerance is
    carry *data*, so one compiled kernel serves every quality tier and a
    tier change never retraces. The fp32 max/multiply against a
    broadcast (bb, 1) block is bitwise identical to the same value as a
    scalar constant — the per-slot path reproduces the static kernel
    exactly when all slots agree."""
    j = pl.program_id(1)

    x = x_ref[:, :].astype(jnp.float32)
    xp = xp_ref[:, :].astype(jnp.float32)
    s2 = s2_ref[:, :].astype(jnp.float32)
    z = z_ref[:, :].astype(jnp.float32)
    x_tilde = x - e0_ref[:, :] * xp + d1_ref[:, :] * s2 + d2_ref[:, :] * z
    x_high = 0.5 * (xp + x_tilde)
    xh_ref[:, :] = x_high.astype(xh_ref.dtype)

    mag = jnp.abs(xp)
    if use_prev:
        mag = jnp.maximum(mag, jnp.abs(xprev_ref[:, :].astype(jnp.float32)))
    delta = jnp.maximum(ea_ref[:, :], er_ref[:, :] * mag)
    r = (xp - x_high) / delta
    partial = jnp.sum(r * r, axis=1, keepdims=True)  # (bb, 1) fp32

    @pl.when(j == 0)
    def _init():
        acc_ref[:, :] = jnp.zeros_like(acc_ref)

    acc_ref[:, :] += partial


@functools.partial(
    jax.jit,
    static_argnames=("use_prev", "block_b", "block_d", "interpret"),
)
def error_step_vec(
    x: Array,
    x_prime: Array,
    score2: Array,
    z: Array,
    x_prev: Array,
    e0: Array,
    d1: Array,
    d2: Array,
    eps_abs: Array,
    eps_rel: Array,
    *,
    use_prev: bool = True,
    block_b: int = DEFAULT_BLOCK_B,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """``error_step`` with per-sample (B,) fp32 ε_abs/ε_rel operands
    (tolerance-class serving, DESIGN.md §14). Same tiling, same fp32
    arithmetic; the tolerances ride next to the step coefficients as
    two more (bb, 1) blocks."""
    B, D = x.shape
    bb, bd = _blocks_for(x.dtype, B, D, block_b, block_d)
    grid = (pl.cdiv(B, bb), pl.cdiv(D, bd))
    state_spec = pl.BlockSpec((bb, bd), lambda i, j: (i, j))
    coeff_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))
    acc_spec = pl.BlockSpec((bb, 1), lambda i, j: (i, 0))

    x_high, acc = pl.pallas_call(
        functools.partial(_error_kernel_vec, use_prev=use_prev),
        grid=grid,
        in_specs=[state_spec] * 5 + [coeff_spec] * 5,
        out_specs=(state_spec, acc_spec),
        out_shape=(
            jax.ShapeDtypeStruct((B, D), x.dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ),
        interpret=interpret,
    )(x, x_prime, score2, z, x_prev, e0[:, None], d1[:, None], d2[:, None],
      eps_abs.astype(jnp.float32)[:, None], eps_rel.astype(jnp.float32)[:, None])
    e2 = jnp.sqrt(acc[:, 0] / D)
    return x_high, e2
