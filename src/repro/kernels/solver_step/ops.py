"""jit'd public wrappers for the fused solver-step kernel.

Handles arbitrary trailing shapes (images (B, H, W, C), tokens (B, S, E))
by flattening to (B, D), padding D up to the lane width, and dispatching
to the Pallas kernel (interpret=True on CPU so the same code path is
exercised everywhere). Padding is with zeros, which contribute exactly 0
to the error sum (δ ≥ ε_abs > 0), and the e2 normalization uses the true
unpadded D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k

Array = jax.Array

_LANES = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _flatten_pad(x: Array):
    B = x.shape[0]
    flat = x.reshape(B, -1)
    D = flat.shape[1]
    pad = (-D) % _LANES
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, D


def em_step(x, score, z, c0, c1, c2, *, interpret: bool | None = None) -> Array:
    """Fused x' = c0·x + c1·score + c2·z for arbitrary state shapes."""
    interpret = _on_cpu() if interpret is None else interpret
    orig_shape = x.shape
    xf, D = _flatten_pad(x)
    sf, _ = _flatten_pad(score)
    zf, _ = _flatten_pad(z)
    out = _k.em_step(xf, sf, zf, c0, c1, c2, interpret=interpret)
    return out[:, :D].reshape(orig_shape)


def error_step(
    x, x_prime, score2, z, x_prev, e0, d1, d2,
    *,
    eps_abs: float,
    eps_rel: float,
    use_prev: bool = True,
    interpret: bool | None = None,
):
    """Fused x̃/x''/δ/error. Returns (x'' with x's shape, e2 (B,))."""
    interpret = _on_cpu() if interpret is None else interpret
    orig_shape = x.shape
    xf, D = _flatten_pad(x)
    xpf, _ = _flatten_pad(x_prime)
    s2f, _ = _flatten_pad(score2)
    zf, _ = _flatten_pad(z)
    xvf, _ = _flatten_pad(x_prev)
    x_high, acc_e2 = _k.error_step(
        xf, xpf, s2f, zf, xvf, e0, d1, d2,
        eps_abs=float(eps_abs), eps_rel=float(eps_rel), use_prev=use_prev,
        interpret=interpret,
    )
    # kernel normalized by padded D; rescale to the true dimension count.
    Dpad = xf.shape[1]
    e2 = acc_e2 * jnp.sqrt(Dpad / D)
    return x_high[:, :D].reshape(orig_shape), e2
