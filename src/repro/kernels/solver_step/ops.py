"""jit'd public wrappers for the fused solver-step kernel.

Handles arbitrary trailing shapes (images (B, H, W, C), tokens (B, S, E))
by flattening to (B, D), padding D up to the lane width, and dispatching
to the Pallas kernel (interpret=True on CPU so the same code path is
exercised everywhere). Padding is with zeros, which contribute exactly 0
to the error sum (δ ≥ ε_abs > 0), and the e2 normalization uses the true
unpadded D.

Operands may be bf16 (precision policy, DESIGN.md §8): the kernel
upcasts each tile to fp32 in-register, the error accumulator and the
padded→true-D renormalization here are fp32 throughout, and x'' comes
back in the operand dtype. Zero padding is exact in every dtype.

``sharded_error_step`` is the mesh-parallel form (DESIGN.md §3): a
``shard_map`` whose per-shard body runs the same Pallas kernel on its
local batch (and optionally feature) block, keeping the error reduction
in VMEM per shard and combining across feature shards with the O(B)
collective in ``repro.parallel.collectives``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import kernel as _k

Array = jax.Array

_LANES = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _flatten_pad_to(x: Array, multiple: int):
    """Flatten to (B, D) and zero-pad D up to ``multiple``."""
    B = x.shape[0]
    flat = x.reshape(B, -1)
    D = flat.shape[1]
    pad = (-D) % multiple
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, D


def _flatten_pad(x: Array):
    return _flatten_pad_to(x, _LANES)


def em_step(x, score, z, c0, c1, c2, *, interpret: bool | None = None) -> Array:
    """Fused x' = c0·x + c1·score + c2·z for arbitrary state shapes."""
    interpret = _on_cpu() if interpret is None else interpret
    orig_shape = x.shape
    xf, D = _flatten_pad(x)
    sf, _ = _flatten_pad(score)
    zf, _ = _flatten_pad(z)
    out = _k.em_step(xf, sf, zf, c0, c1, c2, interpret=interpret)
    return out[:, :D].reshape(orig_shape)


def _eps_is_vector(eps_abs, eps_rel) -> bool:
    """Per-sample (B,) tolerance operands (DESIGN.md §14) vs static
    floats. Tracers (jit-staged (B,) carry leaves) count as vectors;
    0-d values are treated as floats so scalar callers keep the
    compile-time-constant kernel."""
    return any(getattr(e, "ndim", 0) >= 1 for e in (eps_abs, eps_rel))


def _eps_vectors(eps_abs, eps_rel, batch: int):
    ea = jnp.broadcast_to(jnp.asarray(eps_abs, jnp.float32), (batch,))
    er = jnp.broadcast_to(jnp.asarray(eps_rel, jnp.float32), (batch,))
    return ea, er


def error_step(
    x, x_prime, score2, z, x_prev, e0, d1, d2,
    *,
    eps_abs,
    eps_rel,
    use_prev: bool = True,
    interpret: bool | None = None,
):
    """Fused x̃/x''/δ/error. Returns (x'' with x's shape, e2 (B,)).

    ``eps_abs``/``eps_rel`` are floats (static tolerance, compile-time
    kernel constants — the pre-tier path, bitwise unchanged) or (B,)
    arrays (per-slot tolerance classes, DESIGN.md §14 — dispatched to
    the vector-ε kernel where they ride as two more coeff blocks).
    Zero padding stays exact either way: padded columns have mag 0 and
    residual 0, contributing 0 to the error sum for any δ ≥ ε_abs > 0.
    """
    interpret = _on_cpu() if interpret is None else interpret
    orig_shape = x.shape
    xf, D = _flatten_pad(x)
    xpf, _ = _flatten_pad(x_prime)
    s2f, _ = _flatten_pad(score2)
    zf, _ = _flatten_pad(z)
    xvf, _ = _flatten_pad(x_prev)
    if _eps_is_vector(eps_abs, eps_rel):
        ea, er = _eps_vectors(eps_abs, eps_rel, xf.shape[0])
        x_high, acc_e2 = _k.error_step_vec(
            xf, xpf, s2f, zf, xvf, e0, d1, d2, ea, er,
            use_prev=use_prev, interpret=interpret,
        )
    else:
        x_high, acc_e2 = _k.error_step(
            xf, xpf, s2f, zf, xvf, e0, d1, d2,
            eps_abs=float(eps_abs), eps_rel=float(eps_rel), use_prev=use_prev,
            interpret=interpret,
        )
    # kernel normalized by padded D; rescale to the true dimension count.
    Dpad = xf.shape[1]
    e2 = acc_e2 * jnp.sqrt(Dpad / D)
    return x_high[:, :D].reshape(orig_shape), e2


def sharded_error_step(
    x, x_prime, score2, z, x_prev, e0, d1, d2,
    *,
    eps_abs,
    eps_rel,
    mesh: Mesh,
    batch_axes,
    feature_axis: str | None = None,
    use_prev: bool = True,
    interpret: bool | None = None,
):
    """``error_step`` with the batch axis sharded over ``batch_axes``.

    Each shard dispatches the Pallas kernel on its local (B/n, Dpad/f)
    block, so the ~10-pass elementwise math and the squared-residual
    reduction never leave the shard's VMEM. With ``feature_axis`` the
    flattened feature dim additionally shards and the per-sample error is
    combined exactly across shards via
    ``repro.parallel.collectives.scaled_error_l2_psum`` (zero padding
    contributes 0 to every partial sum). Numerics match ``error_step``
    bit-for-bit in the batch-only case: rows are independent and each
    shard walks the same D-grid sequence.

    Returns (x'' with x's shape, e2 (B,)). Per-slot (B,) tolerances
    shard over the batch axes like every other per-sample coefficient,
    so each device reads only its own slots' ε (DESIGN.md §14).
    """
    from repro.parallel.collectives import scaled_error_l2_psum
    from repro.parallel.compat import shard_map

    interpret = _on_cpu() if interpret is None else interpret
    batch_axes = (batch_axes,) if isinstance(batch_axes, str) else tuple(batch_axes)
    fsize = mesh.shape[feature_axis] if feature_axis else 1
    orig_shape = x.shape

    xf, D = _flatten_pad_to(x, fsize * _LANES)
    xpf, _ = _flatten_pad_to(x_prime, fsize * _LANES)
    s2f, _ = _flatten_pad_to(score2, fsize * _LANES)
    zf, _ = _flatten_pad_to(z, fsize * _LANES)
    xvf, _ = _flatten_pad_to(x_prev, fsize * _LANES)
    Dpad = xf.shape[1]
    vec_eps = _eps_is_vector(eps_abs, eps_rel)

    def _local(xl, xpl, s2l, zl, xvl, e0l, d1l, d2l, eal=None, erl=None):
        if vec_eps:
            return _k.error_step_vec(
                xl, xpl, s2l, zl, xvl, e0l, d1l, d2l, eal, erl,
                use_prev=use_prev, interpret=interpret,
            )
        return _k.error_step(
            xl, xpl, s2l, zl, xvl, e0l, d1l, d2l,
            eps_abs=float(eps_abs), eps_rel=float(eps_rel), use_prev=use_prev,
            interpret=interpret,
        )

    def body(xl, xpl, s2l, zl, xvl, e0l, d1l, d2l, *eps_loc):
        x_high, e2_loc = _local(xl, xpl, s2l, zl, xvl, e0l, d1l, d2l, *eps_loc)
        D_loc = xl.shape[1]
        if feature_axis is None:
            # per-sample reduction is shard-local; renormalize padded→true D
            return x_high, e2_loc * jnp.sqrt(D_loc / D)
        acc = e2_loc * e2_loc * D_loc  # undo the kernel's local normalization
        return x_high, scaled_error_l2_psum(acc, D / fsize, feature_axis)

    state_spec = P(batch_axes, feature_axis)
    coeff_spec = P(batch_axes)
    n_eps = 2 if vec_eps else 0
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(state_spec,) * 5 + (coeff_spec,) * (3 + n_eps),
        out_specs=(state_spec, coeff_spec),
        check_rep=False,  # no replication rule for pallas_call
    )
    operands = (xf, xpf, s2f, zf, xvf, e0, d1, d2)
    if vec_eps:
        operands += _eps_vectors(eps_abs, eps_rel, xf.shape[0])
    x_high, e2 = fn(*operands)
    return x_high[:, :D].reshape(orig_shape), e2
