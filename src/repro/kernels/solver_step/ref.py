"""Pure-jnp oracle for the fused adaptive-solver step kernel.

Shapes: state tensors are (B, D); per-sample coefficients are (B,) fp32.

``em_step``   : x' = c0·x + c1·score + c2·z
``error_step``: x̃  = x − e0·x' + d1·score2 + d2·z
                x'' = ½ (x' + x̃)
                δ   = max(ε_abs, ε_rel · max(|x'|, |x'_prev|))   [or |x'| only]
                e2  = sqrt(mean(((x' − x'')/δ)²))               per sample
returns (x'', e2).

Precision contract (mirrors the kernel, DESIGN.md §8): tensor operands
may be bf16; all arithmetic — including δ and the residual reduction —
runs in fp32, x'' is returned in the operand dtype, and e2 is always
fp32. For fp32 operands every cast is a no-op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def em_step(x: Array, score: Array, z: Array, c0: Array, c1: Array, c2: Array) -> Array:
    out = (
        c0[:, None] * x.astype(jnp.float32)
        + c1[:, None] * score.astype(jnp.float32)
        + c2[:, None] * z.astype(jnp.float32)
    )
    return out.astype(x.dtype)


def error_step(
    x: Array,
    x_prime: Array,
    score2: Array,
    z: Array,
    x_prev: Array,
    e0: Array,
    d1: Array,
    d2: Array,
    *,
    eps_abs,
    eps_rel,
    use_prev: bool = True,
):
    """Mirrors the kernel contract, including the per-sample tolerance
    form (DESIGN.md §14): ``eps_abs``/``eps_rel`` may be floats or (B,)
    fp32 arrays; arrays broadcast per-row like the (bb, 1) coeff blocks."""
    out_dtype = x.dtype
    x, x_prime, score2, z, x_prev = (
        a.astype(jnp.float32) for a in (x, x_prime, score2, z, x_prev)
    )
    if getattr(eps_abs, "ndim", 0) >= 1:
        eps_abs = jnp.asarray(eps_abs, jnp.float32)[:, None]
    if getattr(eps_rel, "ndim", 0) >= 1:
        eps_rel = jnp.asarray(eps_rel, jnp.float32)[:, None]
    x_tilde = x - e0[:, None] * x_prime + d1[:, None] * score2 + d2[:, None] * z
    x_high = 0.5 * (x_prime + x_tilde)
    mag = jnp.abs(x_prime)
    if use_prev:
        mag = jnp.maximum(mag, jnp.abs(x_prev))
    delta = jnp.maximum(eps_abs, eps_rel * mag)
    r = (x_prime - x_high) / delta
    e2 = jnp.sqrt(jnp.mean(r * r, axis=1))
    return x_high.astype(out_dtype), e2
