"""Pallas TPU kernel: chunked SSD (state-space duality) scan for Mamba2.

The SSD insight (Dao & Gu 2024, arXiv:2405.21060) is that the selective
state-space recurrence factors into chunk-local *matrix multiplies*
(MXU-friendly) plus a tiny cross-chunk recurrence of the (N, P) state:

  within chunk:  Y_intra = (M ⊙ (C Bᵀ)) (dt·X)      M[t,s] = e^{L_t−L_s}, s ≤ t
  from carry  :  Y_inter = e^{L_t} · (C · state)
  state update:  state'  = e^{L_Q} state + Bᵀ diag(e^{L_Q−L_s} dt_s) X

where L is the within-chunk cumsum of dt·A (A < 0, so every exponent is
≤ 0 — no overflow). This is the TPU-native adaptation: the original CUDA
kernel leans on warp shuffles for the scan; here the chunk-local work is
three (Q×N)/(Q×Q) matmuls on the MXU and the carried state lives in VMEM
scratch across the sequential chunk grid axis.

Grid: (B, H, S/Q). Layout: x (B,H,S,P), dt (B,H,S), A (H,1),
Bm/C (B,G,S,N). All compute fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[:, :] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, :, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0, :].astype(jnp.float32)       # (Q,)
    A = a_ref[0, 0]                                 # scalar
    Bm = b_ref[0, 0, :, :].astype(jnp.float32)     # (Q, N)
    C = c_ref[0, 0, :, :].astype(jnp.float32)      # (Q, N)

    l = dt * A                                      # (Q,) all ≤ 0
    Lc = jnp.cumsum(l)                              # (Q,) decreasing
    Ltot = Lc[-1]

    # Intra-chunk: (M ⊙ C Bᵀ) (dt·x)
    scores = jax.lax.dot_general(
        C, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (Q, Q) = C_t · B_s
    seg = Lc[:, None] - Lc[None, :]                 # L_t − L_s
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(spos <= tpos, jnp.exp(seg), 0.0)
    dx = dt[:, None] * x                            # (Q, P)
    y = jax.lax.dot_general(
        scores * M, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # Inter-chunk: e^{L_t} C_t · state_prev
    state = state_scr[:, :]                         # (N, P)
    y += jnp.exp(Lc)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update: e^{L_Q} state + Bᵀ diag(e^{L_Q−L_s} dt) x
    w = jnp.exp(Ltot - Lc) * dt                     # (Q,)
    state_scr[:, :] = jnp.exp(Ltot) * state + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: Array,
    dt: Array,
    A: Array,
    Bm: Array,
    C: Array,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> Array:
    """Chunked SSD scan. S must be a multiple of ``chunk`` (ops.py pads)."""
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    assert H % G == 0 and S % chunk == 0, (H, G, S, chunk)
    group = H // G
    nc = S // chunk

    x_spec = pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0))
    dt_spec = pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c))
    a_spec = pl.BlockSpec((1, 1), lambda b, h, c: (h, 0))
    bc_spec = pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h // group, c, 0))

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[x_spec, dt_spec, a_spec, bc_spec, bc_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(-1, 1), Bm, C)
