"""Public wrapper for the SSD scan kernel.

Model-native layout is (B, S, H, P) / (B, S, G, N); the kernel wants the
head axis ahead of sequence. Pads S up to the chunk size with dt = 0
(decay e⁰ = 1, injection dt·B⊗x = 0 ⇒ padded steps are identity on the
state and their outputs are sliced away).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _k

Array = jax.Array


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ssd_scan(
    x: Array,        # (B, S, H, P)
    dt: Array,       # (B, S, H)
    A: Array,        # (H,)
    Bm: Array,       # (B, S, G, N)
    C: Array,        # (B, S, G, N)
    *,
    chunk: int = _k.DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    B, S, H, P = x.shape
    chunk = min(chunk, max(8, S))
    pad = (-S) % chunk

    xt = jnp.transpose(x, (0, 2, 1, 3))      # (B,H,S,P)
    dtt = jnp.transpose(dt, (0, 2, 1))       # (B,H,S)
    bt = jnp.transpose(Bm, (0, 2, 1, 3))     # (B,G,S,N)
    ct = jnp.transpose(C, (0, 2, 1, 3))
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
        bt = jnp.pad(bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, 0), (0, pad), (0, 0)))
    y = _k.ssd_scan(xt, dtt, A, bt, ct, chunk=chunk, interpret=interpret)
    y = y[:, :, :S, :]
    return jnp.transpose(y, (0, 2, 1, 3))    # (B,S,H,P)
