"""Pure-jnp oracle for the Mamba2 SSD scan: exact sequential recurrence.

Layout (kernel-native): x (B,H,S,P), dt (B,H,S), A (H,), Bm (B,G,S,N),
C (B,G,S,N), H % G == 0. Per head h with group g = h // (H//G):

  a_t     = exp(dt_t · A_h)
  state_t = a_t · state_{t-1} + dt_t · B_t ⊗ x_t        (N, P)
  y_t     = C_tᵀ state_t                                 (P,)

Returns (y (B,H,S,P), final_state (B,H,N,P)). The D-skip connection and
gating are applied by the model layer, not the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_scan(x: Array, dt: Array, A: Array, Bm: Array, C: Array):
    B, H, S, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    group = H // G
    Bh = jnp.repeat(Bm, group, axis=1)  # (B,H,S,N)
    Ch = jnp.repeat(C, group, axis=1)

    def per_bh(xh, dth, Ah, Bmh, Chh):
        # xh (S,P), dth (S,), Ah (), Bmh (S,N), Chh (S,N)
        def step(state, inp):
            xt, dtt, bt, ct = inp
            a = jnp.exp(dtt * Ah)
            state = a * state + dtt * bt[:, None] * xt[None, :]
            y = ct @ state  # (P,)
            return state, y

        init = jnp.zeros((N, P), jnp.float32)
        state, ys = jax.lax.scan(step, init, (xh, dth, Bmh, Chh))
        return ys, state

    fn = jax.vmap(jax.vmap(per_bh, in_axes=(0, 0, 0, 0, 0)), in_axes=(0, 0, None, 0, 0))
    y, state = fn(
        x.astype(jnp.float32),
        dt.astype(jnp.float32),
        A.astype(jnp.float32),
        Bh.astype(jnp.float32),
        Ch.astype(jnp.float32),
    )
    return y.astype(x.dtype), state


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, C: Array,
                chunk: int = 128):
    """Chunked SSD in pure jnp — same math as the Pallas kernel, with the
    cross-chunk recurrence done by an associative scan (parallel depth
    O(log S/Q) instead of a length-S while loop). This is the production
    non-Pallas path used by model forward passes and the dry-run.

    Layout matches the model side: x (B,S,H,P), dt (B,S,H),
    Bm/C (B,S,G,N). Returns y (B,S,H,P).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    group = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    Bf = jnp.repeat(Bm.astype(jnp.float32), group, axis=2).reshape(B, nc, Q, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), group, axis=2).reshape(B, nc, Q, H, N)
    Af = A.astype(jnp.float32)

    l = dtf * Af  # (B,nc,Q,H) ≤ 0
    Lc = jnp.cumsum(l, axis=2)
    Ltot = Lc[:, :, -1, :]  # (B,nc,H)

    # intra-chunk
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cf, Bf)  # (B,nc,H,Q,Q)
    seg = Lc[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - Lc[:, :, None, :, :].transpose(0, 1, 4, 2, 3)  # (B,nc,H,Q,Q) L_t−L_s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri, jnp.exp(seg), 0.0)
    dx = dtf[..., None] * xf  # (B,nc,Q,H,P)
    y = jnp.einsum("bchqk,bckhp->bcqhp", scores * M, dx)

    # per-chunk state injection and decay
    w = jnp.exp(Ltot[:, :, None, :] - Lc) * dtf  # (B,nc,Q,H)
    inj = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bf, w, xf)  # (B,nc,H,N,P)
    decay = jnp.exp(Ltot)  # (B,nc,H)

    # cross-chunk linear recurrence: s_c = decay_c · s_{c-1} + inj_c
    def combine(a, b):
        da, ia = a
        db, ib = b
        return da * db, ib + db[..., None, None] * ia

    dec_s, inj_s = jax.lax.associative_scan(combine, (decay, inj), axis=1)
    # state entering chunk c is inj_s[c-1]
    state_in = jnp.concatenate(
        [jnp.zeros_like(inj_s[:, :1]), inj_s[:, :-1]], axis=1
    )  # (B,nc,H,N,P)
    y = y + jnp.exp(Lc)[..., None] * jnp.einsum(
        "bcqhn,bchnp->bcqhp", Cf, state_in
    )

    y = y.reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype)
