"""Pre-argparse argv scanning for flags that must be read before jax
initializes (fake device counts lock in at first init). No jax imports
here — launchers import this above ``import jax``."""

from __future__ import annotations

import sys


def argv_value(flag: str, default: str | None = None):
    """Value of ``--flag N`` or ``--flag=N`` from sys.argv, else default."""
    for i, a in enumerate(sys.argv):
        if a == flag and i + 1 < len(sys.argv):
            return sys.argv[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return default
