"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

Proves the distribution config is coherent without hardware: sharding
mismatches, unsupported collectives, and compile-time OOM all surface
here. Records memory_analysis / cost_analysis / collective bytes to
experiments/dryrun/<arch>_<shape>_<mesh>.json for the roofline pass.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--remat dots]
"""

# The 512 placeholder devices MUST be requested before any other import
# triggers jax initialization (device count locks on first init).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # Dry-run code is never executed — skip CPU codegen effort (validated:
    # identical flops + collective bytes, ~2.4× faster compile).
    "--xla_backend_optimization_level=0 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax

from repro.analysis.hlo import collective_bytes_from_text, summarize_cost
from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_dryrun

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _compile_spec(cfg, shape, mesh, remat, unroll):
    spec = build_dryrun(cfg, shape, mesh, remat=remat, unroll=unroll)
    with mesh:
        jitted = jax.jit(
            spec.fn,
            in_shardings=spec.in_shardings,
            out_shardings=spec.out_shardings,
            donate_argnums=spec.donate_argnums,
        )
        lowered = jitted.lower(*spec.args)
        compiled = lowered.compile()
    return lowered, compiled


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            remat: str = "none", save: bool = True, verbose: bool = True) -> dict:
    """One (arch × shape × mesh) dry-run.

    Two-phase accounting (see EXPERIMENTS.md §Dry-run methodology):
      1. compile the PRODUCTION program (scan over layer super-blocks) —
         this is the pass/fail gate and the source of memory_analysis;
      2. compile 1-repeat and 2-repeat unrolled variants and extrapolate
         cost linearly in depth: total(R) = c1 + (R−1)·(c2−c1). Exact
         because every per-layer cost here is depth-linear, and it
         sidesteps XLA's cost_analysis counting loop bodies once.
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2pod" if multi_pod else "1pod"
    t0 = time.time()

    # phase 1: production (scanned) program
    lowered, compiled = _compile_spec(cfg, shape, mesh, remat, unroll=False)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()

    # phase 2: depth-extrapolated exact costs
    period = len(cfg.mixer_pattern)
    R = cfg.num_repeats
    t1 = time.time()
    costs, colls, traffics = [], [], []
    for reps in (1, 2):
        c_small = cfg.replace(num_layers=reps * period)
        _, comp = _compile_spec(c_small, shape, mesh, remat, unroll=True)
        costs.append(summarize_cost(comp.cost_analysis()))
        colls.append(collective_bytes_from_text(comp.as_text()))
        m = comp.memory_analysis()
        # HBM traffic estimate: every argument/output crosses HBM once,
        # every temp buffer is written + read ≥ once. (XLA's per-module
        # cost_analysis drops 'bytes accessed' for multi-computation
        # modules, so this memory_analysis-based estimate stands in.)
        traffics.append(
            (getattr(m, "argument_size_in_bytes", 0) or 0)
            + (getattr(m, "output_size_in_bytes", 0) or 0)
            + 2 * (getattr(m, "temp_size_in_bytes", 0) or 0)
        )
    t_extra = time.time() - t1
    est_traffic = traffics[0] + (R - 1) * max(traffics[1] - traffics[0], 0)

    def _extrapolate(key_fn):
        # per-layer increment clamped at >= 0: tiny decode layers fall
        # below XLA's const-folding noise floor and can make c2 < c1.
        c1, c2 = key_fn(costs[0], colls[0]), key_fn(costs[1], colls[1])
        return c1 + (R - 1) * max(c2 - c1, 0.0)

    cost = {
        k: costs[0].get(k, 0.0)
        + (R - 1) * max(costs[1].get(k, 0.0) - costs[0].get(k, 0.0), 0.0)
        for k in set(costs[0]) | set(costs[1])
    }
    cost["est_hbm_traffic_bytes"] = float(max(est_traffic, 0))
    coll_total = _extrapolate(lambda c, x: x["total_bytes"])
    coll = {
        "total_bytes": int(max(coll_total, 0)),
        "bytes_by_kind": {
            k: int(max(
                colls[0]["bytes_by_kind"].get(k, 0)
                + (R - 1) * (colls[1]["bytes_by_kind"].get(k, 0)
                             - colls[0]["bytes_by_kind"].get(k, 0)),
                0,
            ))
            for k in set(colls[0]["bytes_by_kind"]) | set(colls[1]["bytes_by_kind"])
        },
        "counts_r2": colls[1]["counts"],
        "method": "depth-extrapolated (R1/R2 unrolled)",
    }
    t_lower, t_compile = 0.0, t_full  # phase-1 timings dominate

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "remat": remat,
        "devices": int(len(mesh.devices.flat)),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "extrapolation_s": round(t_extra, 1),
        "num_repeats": R,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": cost,  # already summarized per R1/R2 piece
        "collectives": coll,
    }
    if verbose:
        gb = 1024 ** 3
        pk = record["memory"]["peak_bytes"]
        print(
            f"[{arch} × {shape_name} × {mesh_name}] OK  "
            f"compile {t_compile:.0f}s (+{t_extra:.0f}s extrap)  "
            f"flops/dev {record['cost'].get('flops', 0):.3e}  "
            f"peak/dev {pk / gb if pk else float('nan'):.2f} GiB  "
            f"coll {coll['total_bytes'] / gb:.2f} GiB",
            flush=True,
        )
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.json".replace("/", "-")
        with open(os.path.join(OUT_DIR, fname), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in combos:
        mesh_name = "2pod" if args.multi_pod else "1pod"
        fname = os.path.join(OUT_DIR, f"{arch}_{shape}_{mesh_name}.json")
        if args.skip_existing and os.path.exists(fname):
            print(f"[{arch} × {shape} × {mesh_name}] cached, skipping")
            continue
        try:
            run_one(arch, shape, multi_pod=args.multi_pod, remat=args.remat)
        except Exception as e:  # noqa: BLE001 — report every combo
            failures.append((arch, shape, repr(e)))
            print(f"[{arch} × {shape}] FAILED: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
