"""Production meshes (TPU v5e target).

``make_production_mesh`` is a function, not a module-level constant, so
importing this module never touches jax device state (the dry-run must
set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh for CPU smoke runs of the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"))
