"""Perf-iteration harness (§Perf): run named variants of an
(arch × shape) dry-run and append the roofline deltas to
experiments/perf/<arch>_<shape>.jsonl.

Each variant is a knob set (remat / moe_dispatch / fsdp / group size…).
The hypothesis → change → before/after → verdict narrative lives in
EXPERIMENTS.md; this harness produces the numbers.

  PYTHONPATH=src python -m repro.launch.perf --arch granite-moe-3b-a800m \
      --shape prefill_32k --variant moe-gather
"""

# Must precede any jax-initializing import (see dryrun.py).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_backend_optimization_level=0 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time

import jax

from repro.analysis.hlo import collective_bytes_from_text, summarize_cost
from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_dryrun

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")

# Named variants: kwargs forwarded to build_dryrun.
VARIANTS = {
    "baseline": {"last_logits_only": False},
    "last-logits": {},  # prefill head only on final position (now default)
    "seq-shard-attn": {"cfg_overrides": {"attn_q_seq_shard": "model"}},
    "seq-parallel": {"cfg_overrides": {"attn_q_seq_shard": "model",
                                       "residual_seq_shard": "model"}},
    "moe-pad48": {"moe_padded_experts": 48},
    "seq-shard+moe-pad48": {"moe_padded_experts": 48,
                            "cfg_overrides": {"attn_q_seq_shard": "model"}},
    "moe-gather": {"cfg_overrides": {"moe_dispatch": "gather"}},
    "remat-full": {"remat": "full"},
    "remat-dots": {"remat": "dots"},
    "fsdp": {"fsdp": True},
    "fsdp+remat": {"fsdp": True, "remat": "full"},
    "fsdp+moe-gather": {"fsdp": True,
                        "cfg_overrides": {"moe_dispatch": "gather"}},
    "zero1": {"zero1": True},
    "zero1+remat": {"zero1": True, "remat": "full"},
    "zero1+seqpar": {"zero1": True,
                     "cfg_overrides": {"residual_seq_shard": "model"}},
    "flash-decode": {"cfg_overrides": {"decode_flash_shard": "model"}},
    "flash-decode-2d": {"cfg_overrides": {"decode_flash_shard": "data,model"}},
}


def run_variant(arch: str, shape_name: str, variant: str,
                *, multi_pod: bool = False) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = dict(VARIANTS[variant])
    pad = kw.pop("moe_padded_experts", None)
    if pad:
        ov = dict(kw.get("cfg_overrides", {}))
        ov["moe"] = _dc.replace(cfg.moe, padded_experts=pad)
        kw["cfg_overrides"] = ov

    def compile_one(c, unroll):
        spec = build_dryrun(
            c, shape, mesh, unroll=unroll,
            **{k: v for k, v in kw.items()},
        )
        with mesh:
            jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                             out_shardings=spec.out_shardings,
                             donate_argnums=spec.donate_argnums)
            return jitted.lower(*spec.args).compile()

    t0 = time.time()
    full = compile_one(cfg, unroll=False)
    mem = full.memory_analysis()

    period = len(cfg.mixer_pattern)
    R = cfg.num_repeats
    pieces = []
    for reps in (1, 2):
        comp = compile_one(cfg.replace(num_layers=reps * period), unroll=True)
        m = comp.memory_analysis()
        pieces.append({
            "cost": summarize_cost(comp.cost_analysis()),
            "coll": collective_bytes_from_text(comp.as_text()),
            "traffic": (getattr(m, "argument_size_in_bytes", 0) or 0)
            + (getattr(m, "output_size_in_bytes", 0) or 0)
            + 2 * (getattr(m, "temp_size_in_bytes", 0) or 0),
        })

    def ext(f):
        return f(pieces[0]) + (R - 1) * max(f(pieces[1]) - f(pieces[0]), 0.0)

    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2pod" if multi_pod else "1pod",
        "flops": ext(lambda p: p["cost"].get("flops", 0.0)),
        "est_hbm_traffic_bytes": ext(lambda p: p["traffic"]),
        "collective_bytes": ext(lambda p: p["coll"]["total_bytes"]),
        "coll_by_kind": {
            k: int(max(ext(lambda p: p["coll"]["bytes_by_kind"].get(k, 0)), 0))
            for k in set().union(*(p["coll"]["bytes_by_kind"] for p in pieces))
        },
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "wall_s": round(time.time() - t0, 1),
    }
    # roofline terms (v5e)
    rec["t_compute_s"] = rec["flops"] / 197e12
    rec["t_memory_s"] = rec["est_hbm_traffic_bytes"] / 819e9
    rec["t_collective_s"] = rec["collective_bytes"] / 50e9
    terms = {k: rec[f"t_{k}_s"] for k in ("compute", "memory", "collective")}
    rec["dominant"] = max(terms, key=terms.get)

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{arch}_{shape_name}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    gb = 1024 ** 3
    print(f"[{arch} × {shape_name} × {variant}] "
          f"compute {rec['t_compute_s']:.3f}s  "
          f"memory {rec['t_memory_s']:.3f}s  "
          f"coll {rec['t_collective_s']:.3f}s  "
          f"dominant={rec['dominant']}  "
          f"peak {(rec['peak_bytes'] or 0) / gb:.1f} GiB  "
          f"args {(rec['argument_bytes'] or 0) / gb:.1f} GiB", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=sorted(SHAPES), required=True)
    ap.add_argument("--variant", choices=sorted(VARIANTS), default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.variant, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
