"""Receding-horizon planner launcher (DESIGN.md §10).

Runs the trajectory-diffusion planning closed loop on an analytic
environment: every control round each environment submits a plan
request (current state pinned via horizon-axis inpainting, optional
returns-bin CFG label) into the continuous-batching
``DiffusionBatcher``, executes the first action of its delivered plan,
and re-admits the re-conditioned request — the live form of the §7
retire/compact/admit lifecycle with §9 condition payloads aboard.

By default the score is the analytic returns-binned Gaussian
(``class_gaussian_noise_pred`` — exact, train-free, so the loop is
meaningful without a checkpoint); ``--unet`` swaps in a train-free
``temporal_unet`` to exercise the real network path (zero-init output
⇒ prior plans). ``--compare-em`` additionally prints the single-shot
adaptive-vs-EM NFE comparison on the trajectory shape — the paper's
headline economy on the third workload.

  PYTHONPATH=src python -m repro.launch.plan [--env ou|pointmass]
      [--envs 6] [--steps 4] [--slots 4] [--sync-horizon 4]
      [--horizon 8] [--cfg-scale 1.5] [--precision fp32] [--unet]
      [--unet-attention] [--fused-norm] [--compare-em 200]
      [--no-compaction]

``launch/serve --plan`` exposes the same loop through the serving CLI.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import AdaptiveConfig, VPSDE, sample
from repro.core.analytic import class_gaussian_noise_pred, gaussian_score
from repro.core.precision import PRESETS, resolve_policy
from repro.planning import (
    PlannerConfig, RecedingHorizonPlanner, get_env,
)

MU, S0 = 0.3, 0.5
RETURNS_BINS = 5


def _make_forward(pcfg: PlannerConfig, unet: bool, precision: str,
                  attention: bool = False, fused_norm: bool = False):
    """Noise-prediction ``forward_fn(params, x, t, y=None)`` + params:
    analytic returns-binned Gaussian (default) or a train-free
    ``temporal_unet`` (DESIGN.md §10). ``attention`` adds the
    bottleneck flash-attention block and ``fused_norm`` the fused
    GroupNorm→SiLU kernel — the §13 hot-path levers, flags so the
    serving loop can A/B them in place."""
    sde = VPSDE()
    policy = resolve_policy(precision)
    if not unet:
        fwd = class_gaussian_noise_pred(
            sde, MU + 0.5 * jnp.linspace(-1.0, 1.0, RETURNS_BINS), S0, MU)
        return sde, fwd, None
    from repro.models.temporal_unet import (
        TemporalUNetConfig, init_temporal_unet, temporal_unet_forward,
    )

    ucfg = TemporalUNetConfig(
        horizon=pcfg.horizon, transition_dim=pcfg.transition_dim,
        base=16, mults=(1, 2), t_dim=32, groups=4,
        returns_bins=RETURNS_BINS if pcfg.guidance_scale else 0,
        attention=attention, use_flash=attention,
        use_fused_norm=fused_norm,
    )
    params = policy.cast_params(
        init_temporal_unet(ucfg, jax.random.PRNGKey(0)))

    def fwd(p, x, t, y=None):
        return temporal_unet_forward(p, x, t, ucfg, policy=policy, y=y)

    return sde, fwd, params


def serve_planning(
    *, env_name: str = "ou", envs: int = 6, steps: int = 4,
    slots: int = 4, sync_horizon: int = 4, compaction: bool = True,
    horizon: int = 8, cfg_scale: float = 0.0, precision: str = "fp32",
    unet: bool = False, unet_attention: bool = False,
    fused_norm: bool = False,
) -> dict:
    """Closed-loop planning as a service (DESIGN.md §10): drain
    ``envs × steps`` plan requests through the batcher, executing each
    plan's first action between rounds. Prints plans/s, per-plan NFE,
    reward, and the §7 waste accounting."""
    env = get_env(env_name)
    pcfg = PlannerConfig(horizon=horizon, obs_dim=env.obs_dim,
                         act_dim=env.act_dim, guidance_scale=cfg_scale)
    sde, fwd, params = _make_forward(pcfg, unet, precision,
                                     attention=unet_attention,
                                     fused_norm=fused_norm)
    rh = RecedingHorizonPlanner(
        sde, fwd, params, pcfg, env,
        cfg=AdaptiveConfig(eps_rel=0.05, precision=precision),
        slots=slots, sync_horizon=sync_horizon, compaction=compaction,
    )
    returns_label = RETURNS_BINS - 1 if cfg_scale else None
    t0 = time.time()
    out = rh.rollout(jax.random.PRNGKey(1), n_envs=envs, n_steps=steps,
                     returns_label=returns_label)
    dt = time.time() - t0
    n_plans = envs * steps
    rec = {
        "env": env_name,
        "envs": envs,
        "steps": steps,
        "slots": slots,
        "sync_horizon": sync_horizon,
        "compaction": compaction,
        "score": "temporal_unet" if unet else "analytic",
        "cfg_scale": cfg_scale,
        "plans": n_plans,
        "plans_per_sec": n_plans / dt,
        "mean_nfe": float(out["nfe"].mean()),
        "mean_reward": float(out["rewards"].mean()),
        "final_round_reward": float(out["rewards"][-1].mean()),
        "wasted_nfe_fraction": out["wasted_nfe_fraction"],
        "passenger_nfe_fraction": out["passenger_nfe_fraction"],
        "refills_per_device": out["refills_per_device"],
    }
    print(f"plan serve[{env_name}, {rec['score']}, "
          f"cfg={cfg_scale}]: {n_plans} plans in {dt:.1f}s "
          f"({rec['plans_per_sec']:.2f} plans/s), "
          f"{envs} envs × {steps} rounds on {slots} slots "
          f"(horizon {sync_horizon}), mean NFE {rec['mean_nfe']:.0f}, "
          f"mean reward {rec['mean_reward']:.3f} "
          f"(final round {rec['final_round_reward']:.3f}), "
          f"wasted NFE {rec['wasted_nfe_fraction']:.1%}, "
          f"refills/device {rec['refills_per_device']}")
    return rec


def compare_em(horizon: int = 8, dim: int = 4, batch: int = 64,
               em_steps: int = 200) -> dict:
    """Single-shot adaptive-vs-EM NFE on the trajectory shape — the
    paper's headline on the third workload, same default tolerances as
    images (DESIGN.md §10)."""
    sde = VPSDE()
    score = gaussian_score(sde, MU, S0)
    shape = (batch, horizon, dim)
    key = jax.random.PRNGKey(0)
    res_ad = jax.jit(lambda k: sample(
        sde, score, shape, k, method="adaptive", eps_rel=0.05))(key)
    res_em = jax.jit(lambda k: sample(
        sde, score, shape, k, method="em", n_steps=em_steps))(key)
    rec = {
        "shape": shape,
        "adaptive_nfe": float(res_ad.mean_nfe),
        "em_nfe": float(res_em.mean_nfe),
        "nfe_ratio": float(res_ad.mean_nfe) / float(res_em.mean_nfe),
    }
    print(f"trajectory ({horizon}×{dim}): adaptive NFE "
          f"{rec['adaptive_nfe']:.0f} vs EM-{em_steps} NFE "
          f"{rec['em_nfe']:.0f} ({rec['nfe_ratio']:.2f}×)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="ou", choices=["ou", "pointmass"])
    ap.add_argument("--envs", type=int, default=6)
    ap.add_argument("--steps", type=int, default=4,
                    help="control rounds per environment")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--sync-horizon", type=int, default=4)
    ap.add_argument("--no-compaction", action="store_true")
    ap.add_argument("--horizon", type=int, default=8,
                    help="plan horizon H (trajectory rows)")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="returns-CFG guidance scale (DESIGN.md §10)")
    ap.add_argument("--precision", choices=sorted(PRESETS), default="fp32")
    ap.add_argument("--unet", action="store_true",
                    help="train-free temporal UNet instead of the "
                         "analytic score")
    ap.add_argument("--unet-attention", action="store_true",
                    help="with --unet: bottleneck self-attention block "
                         "routed through the flash kernel (DESIGN.md "
                         "§13; fresh block is the identity)")
    ap.add_argument("--fused-norm", action="store_true",
                    help="with --unet: fused GroupNorm→SiLU Pallas "
                         "kernel in every residual block (DESIGN.md §13)")
    ap.add_argument("--compare-em", type=int, default=None, metavar="N",
                    help="also print adaptive vs EM-N NFE on the "
                         "trajectory shape")
    args = ap.parse_args()
    serve_planning(
        env_name=args.env, envs=args.envs, steps=args.steps,
        slots=args.slots, sync_horizon=args.sync_horizon,
        compaction=not args.no_compaction, horizon=args.horizon,
        cfg_scale=args.cfg_scale, precision=args.precision, unet=args.unet,
        unet_attention=args.unet_attention, fused_norm=args.fused_norm,
    )
    if args.compare_em is not None:
        compare_em(horizon=args.horizon, em_steps=args.compare_em)


if __name__ == "__main__":
    main()
