"""Diffusion-sampling launcher + production-mesh dry-run of the paper's
technique itself (beyond the assigned 40 combos).

Three entry points:

  * run mode (CPU or mesh): train-free demo sampling from a DiT score
    net with any solver;
  * ``--dryrun``: lower + compile ONE adaptive-solver iteration
    ("sample_step": two score-net forwards + the fused step math +
    per-sample accept/adapt) for the high-res DiT on the 16×16 / 2×16×16
    meshes, with the batch sharded over data axes and the DiT weights
    tensor-parallel — proving the paper's sampler distributes on the
    same production mesh as the LM stack, and feeding §Roofline;
  * ``--dryrun-loop``: lower + compile the ENTIRE adaptive sampling
    loop — ``sample(..., mesh=...)``: sharded prior draw, the
    lax.while_loop with its per-sample carry, both score forwards, and
    the final Tweedie denoise — on a fake multi-device data mesh
    (DESIGN.md §3). This is the full distributed program the serving
    path repeats, checkable on a CPU-only host.

  PYTHONPATH=src python -m repro.launch.sample --dryrun [--multi-pod]
  PYTHONPATH=src python -m repro.launch.sample --dryrun-loop [--loop-devices 64]

All modes take ``--precision {fp32,bf16,bf16_full}`` (DESIGN.md §8):
the score net / solver state run at the policy's dtypes (error control
always fp32) and the dry-run JSONs record the per-device byte savings.
"""

import os  # noqa: E402
import sys  # noqa: E402

from repro.launch._argv import argv_value  # noqa: E402

if "--dryrun" in sys.argv:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        "--xla_backend_optimization_level=0 "
        + os.environ.get("XLA_FLAGS", "")
    )
elif "--dryrun-loop" in sys.argv:
    _n = argv_value("--loop-devices", "64")
    if not (_n.isdigit() and int(_n) > 0):
        _n = "64"  # argparse reports the malformed value after imports
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        "--xla_backend_optimization_level=0 "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.analysis.hlo import collective_bytes_from_text, summarize_cost
from repro.configs.diffusion import CIFAR_DIT, HIGHRES_DIT
from repro.core import VESDE, VPSDE, AdaptiveConfig, sample
from repro.core.precision import PRESETS, resolve_policy
from repro.core.solvers.adaptive import SolverCarry, solve_chunk
from repro.models.dit import DiTConfig, dit_forward, init_dit, make_score_fn

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _dit_param_shardings(params_abs, mesh, *, pipeline_axis=None):
    """DiT tensor-parallel rules: attention heads + ffn over "model";
    with ``pipeline_axis``, stacked layer weights additionally shard
    their repeat (dim 0) over that axis (GPipe stages)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        msize = mesh.shape.get("model", 1)
        shape = leaf.shape
        stage = pipeline_axis if (
            pipeline_axis and name.startswith("layers")
            and shape[0] % mesh.shape.get(pipeline_axis, 1) == 0
        ) else None

        def ok(d):
            return shape[d] % msize == 0

        if name.endswith(("attn/wq", "attn/wk", "attn/wv")) and ok(2):
            return NamedSharding(mesh, P(stage, None, "model", None))
        if name.endswith("attn/wo") and ok(1):
            return NamedSharding(mesh, P(stage, "model", None, None))
        if name.endswith(("mlp/w_in", "mlp/w_gate")) and ok(2):
            return NamedSharding(mesh, P(stage, None, "model"))
        if name.endswith("mlp/w_out") and ok(1):
            return NamedSharding(mesh, P(stage, "model", None))
        if name.endswith("/ada") and leaf.ndim == 3 and ok(2):
            return NamedSharding(mesh, P(stage, None, "model"))
        if stage:
            return NamedSharding(mesh, P(stage))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(fn, params_abs)


def make_sample_step(net: DiTConfig, sde, cfg: AdaptiveConfig,
                     forward_fn=None):
    """Resumable Algorithm-1 chunk as a pjit-able step function.

    Returns ``step(params, carry, max_sync_iters=1) -> carry`` over the
    solver's ``SolverCarry`` pytree — the exact ``solve_chunk`` body the
    monolithic ``adaptive()`` runs, so serving inherits every solver
    feature (fused kernel, per-slot keys, NFE accounting) and chaining
    chunks reproduces the monolithic solve bit-for-bit. This is the unit
    the serving loop repeats until all samples land at t_eps, retiring
    and refilling slots at each sync horizon.

    ``forward_fn(params, x, t)`` is noise-prediction: score = -out/std.
    ``cfg.precision`` threads through (DESIGN.md §8): the default DiT
    forward runs in the policy's compute dtype, the 1/std rescale is
    fp32, and ``solve_chunk`` keeps the carry at the state dtype. A
    custom ``forward_fn`` is responsible for its own compute casting
    (``solve_chunk`` still casts its x input / score output).

    ``cfg.conditioner`` threads through the same way (DESIGN.md §9):
    ``solve_chunk`` consumes the carry's per-slot condition payload.
    With a ``ClassifierFree`` conditioner the score must be label-aware,
    so the step's score_fn forwards ``y`` whenever ``forward_fn``
    declares it (the default DiT forward does).
    """
    policy = resolve_policy(cfg.precision)
    if forward_fn is None:
        forward_fn = lambda p, x, t, y=None: dit_forward(
            p, x, t, net, policy=policy, y=y)
    import inspect

    accepts_y = "y" in inspect.signature(forward_fn).parameters

    def sample_step(params, carry, max_sync_iters: int = 1):
        def score_fn(x, t, y=None):
            _, std = sde.marginal(t)
            out = (forward_fn(params, x, t, y=y) if accepts_y
                   else forward_fn(params, x, t)).astype(jnp.float32)
            return -out / std.reshape((-1,) + (1,) * (x.ndim - 1))

        return solve_chunk(
            sde, score_fn, carry,
            max_sync_iters=max_sync_iters, config=cfg,
        )

    return sample_step


def make_pipelined_dit_forward(net: DiTConfig, *, num_microbatches: int = 4,
                               axis: str = "pod", policy=None):
    """DiT forward with the layer stack pipelined over ``axis`` (GPipe).

    The per-sample time embedding rides along as an extra token so the
    (activations, conditioning) pair crosses stage boundaries together.
    ``policy`` mirrors ``dit_forward``'s precision seams (DESIGN.md §8):
    activations and the weight copies in compute dtype, fp32
    timestep-embedding math from the stored weights.
    """
    import jax.numpy as jnp

    from repro.models.dit import _patchify, _unpatchify
    from repro.models.layers import apply_norm, timestep_embedding
    from repro.parallel.pipeline import pipeline_forward

    def body(stage_layers, hm):
        # hm (mb, S+1, D): last token is the time-conditioning vector
        h, temb = hm[:, :-1, :], hm[:, -1, :]

        def layer(h, lp):
            import jax
            from repro.models.attention import _ref_attention
            from repro.models.layers import apply_mlp

            mod = jax.nn.silu(temb) @ lp["ada"] + lp["ada_b"]
            s1, b1, g1, s2, b2, g2 = jnp.split(mod[:, None, :], 6, axis=-1)
            hn = apply_norm(lp["norm1"], h, "layernorm_np") * (1 + s1) + b1
            q = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wq"])
            k = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wk"])
            v = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wv"])
            att = _ref_attention(q, k, v, causal=False, window=None, softcap=0.0)
            h = h + g1 * jnp.einsum("bshd,hde->bse", att, lp["attn"]["wo"])
            hn = apply_norm(lp["norm2"], h, "layernorm_np") * (1 + s2) + b2
            h = h + g2 * apply_mlp(lp["mlp"], hn, "silu", True)
            return h, None

        h, _ = jax.lax.scan(layer, h, stage_layers)
        return jnp.concatenate([h, temb[:, None, :]], axis=1)

    def fwd(params, x, t):
        # fp32 timestep-embedding math from the stored (master) weights
        f32 = lambda w: w.astype(jnp.float32)
        temb = timestep_embedding(t, 256)
        temb = jax.nn.silu(temb @ f32(params["t_mlp1"])) @ f32(params["t_mlp2"])
        if policy is not None:
            x = x.astype(policy.compute)
            params = policy.params_for_compute(params)
        h = _patchify(x, net) @ params["patch_in"] + params["pos_emb"]
        temb = temb.astype(h.dtype)
        hm = jnp.concatenate([h, temb[:, None, :]], axis=1)
        hm = pipeline_forward(params["layers"], hm, body, axis=axis,
                              num_microbatches=num_microbatches)
        h, temb = hm[:, :-1, :], hm[:, -1, :]
        mod = jax.nn.silu(temb) @ params["final_ada"] + params["final_ada_b"]
        s, b = jnp.split(mod[:, None, :], 2, axis=-1)
        h = apply_norm(params["final_norm"], h, "layernorm_np") * (1 + s) + b
        return _unpatchify(h @ params["patch_out"], net)

    return fwd


def _precision_record(policy, params_abs, state_x_abs, mesh) -> dict:
    """Policy dtypes + the per-device byte footprint they imply, so the
    bf16 memory/collective savings are visible in experiments/dryrun/
    next to the fp32 artifacts. ``state_x_abs`` is the (B, ...) x spec;
    the carry holds two such tensors (x and x_prev)."""
    import numpy as np

    from repro.parallel.sharding import data_axes

    axes = data_axes(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    leaves = jax.tree_util.tree_leaves(params_abs)
    param_bytes = int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves))
    state_bytes = int(
        2 * state_x_abs.size * jnp.dtype(state_x_abs.dtype).itemsize
    )
    rec = policy.as_dict()
    rec["param_bytes_total"] = param_bytes
    rec["state_bytes_per_device"] = state_bytes // n_data
    return rec


def dryrun(multi_pod: bool, batch: int = 512, pipeline: bool = False,
           precision: str = "fp32") -> dict:
    from repro.launch.mesh import make_production_mesh

    net = HIGHRES_DIT  # 256×256×3, ~100M-param DiT
    sde = VESDE(sigma_max=50.0)  # paper's high-res process
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = resolve_policy(precision)

    if pipeline:
        assert multi_pod, "pipeline stages live on the pod axis (2-pod mesh)"
    params_abs = jax.eval_shape(lambda k: init_dit(net, k),
                                jax.random.PRNGKey(0))
    # weights lowered at the policy's storage dtype (bf16 halves both the
    # per-device weight HBM and the weight-collective bytes)
    params_abs = jax.eval_shape(policy.cast_params, params_abs)
    p_shard = _dit_param_shardings(
        params_abs, mesh, pipeline_axis="pod" if pipeline else None)
    shp = (batch, net.image_size, net.image_size, net.channels)
    arr = lambda s, d=jnp.float32: jax.ShapeDtypeStruct(s, d)
    state_abs = SolverCarry(
        x=arr(shp, policy.state), x_prev=arr(shp, policy.state),
        t=arr((batch,)), h=arr((batch,)),
        key=arr((batch, 2), jnp.uint32),  # per-slot keys: the serving form
        nfe=arr((batch,), jnp.int32),
        accepted=arr((batch,), jnp.int32),
        rejected=arr((batch,), jnp.int32),
        done=arr((batch,), jnp.bool_),
        iterations=arr((), jnp.int32),
    )
    from repro.parallel.sharding import solver_carry_shardings

    s_shard = solver_carry_shardings(mesh, batch, len(shp),
                                     per_slot_keys=True)

    fwd = (make_pipelined_dit_forward(net, axis="pod", policy=policy)
           if pipeline else None)
    step = make_sample_step(net, sde,
                            AdaptiveConfig(eps_rel=0.02, precision=precision),
                            forward_fn=fwd)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(
            step, in_shardings=(p_shard, s_shard), out_shardings=s_shard,
            donate_argnums=(1,),
        ).lower(params_abs, state_abs).compile()
    mem = compiled.memory_analysis()
    cost = summarize_cost(compiled.cost_analysis())
    coll = collective_bytes_from_text(compiled.as_text())
    rec = {
        "arch": "dit-highres-sampler" + ("-pipelined" if pipeline else ""),
        "shape": f"sample_b{batch}_256px",
        "mesh": "2pod" if multi_pod else "1pod",
        "devices": int(len(mesh.devices.flat)),
        "compile_s": round(time.time() - t0, 1),
        "memory": {"peak_bytes": getattr(mem, "peak_memory_in_bytes", None)},
        "cost": cost,
        "collectives": coll,
        "precision": _precision_record(policy, params_abs, state_abs.x, mesh),
        "note": "one Algorithm-1 chunk iteration (2 score-net fwd + step math)",
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = "" if policy.is_fp32 else f"_{policy.name}"
    with open(os.path.join(
            OUT_DIR,
            f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)  # stable key order across regenerations
    gb = 1024 ** 3
    print(f"[{rec['arch']} × {rec['shape']} × {rec['mesh']}] OK  "
          f"compile {rec['compile_s']}s  "
          f"flops/dev {cost.get('flops', 0):.3e}  "
          f"peak/dev {(rec['memory']['peak_bytes'] or 0) / gb:.2f} GiB  "
          f"coll {coll['total_bytes'] / gb:.3f} GiB")
    return rec


def dryrun_loop(batch: int = 256, precision: str = "fp32") -> dict:
    """Lower + compile the whole sharded sampling loop on a fake data mesh.

    Unlike ``dryrun`` (one solver iteration), this compiles the complete
    distributed program of ``sample(..., mesh=...)``: sharded prior draw,
    the adaptive lax.while_loop with its per-sample (B,) carry, both
    score-net forwards per iteration, and the Tweedie denoise — verifying
    that GSPMD keeps every iteration data-parallel (collective bytes
    should stay O(loop-bookkeeping), not O(activations)).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    net = CIFAR_DIT
    sde = VPSDE()
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    assert batch % ndev == 0, f"batch {batch} must divide {ndev} devices"
    policy = resolve_policy(precision)

    params_abs = jax.eval_shape(lambda k: init_dit(net, k),
                                jax.random.PRNGKey(0))
    params_abs = jax.eval_shape(policy.cast_params, params_abs)
    rep = NamedSharding(mesh, P())
    p_shard = jax.tree_util.tree_map(lambda _: rep, params_abs)
    shp = (batch, net.image_size, net.image_size, net.channels)

    def run(params, key):
        def score_fn(x, t):
            _, std = sde.marginal(t)
            out = dit_forward(params, x, t, net, policy=policy)
            return -out.astype(jnp.float32) / std.reshape(-1, 1, 1, 1)

        return sample(sde, score_fn, shp, key, method="adaptive", mesh=mesh,
                      config=AdaptiveConfig(eps_rel=0.02, precision=precision))

    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
    t0 = time.time()
    compiled = jax.jit(
        run, in_shardings=(p_shard, rep),
    ).lower(params_abs, key_abs).compile()
    mem = compiled.memory_analysis()
    cost = summarize_cost(compiled.cost_analysis())
    coll = collective_bytes_from_text(compiled.as_text())
    rec = {
        "arch": "dit-cifar-sampler-whole-loop",
        "shape": f"sample_b{batch}_32px",
        "mesh": f"data{ndev}",
        "devices": ndev,
        "compile_s": round(time.time() - t0, 1),
        "memory": {"peak_bytes": getattr(mem, "peak_memory_in_bytes", None)},
        "cost": cost,
        "collectives": coll,
        "precision": _precision_record(
            policy, params_abs, jax.ShapeDtypeStruct(shp, policy.state), mesh,
        ),
        "note": "full adaptive while_loop (prior + solver + denoise), batch sharded",
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = "" if policy.is_fp32 else f"_{policy.name}"
    with open(os.path.join(
            OUT_DIR,
            f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"), "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)  # stable key order across regenerations
    gb = 1024 ** 3
    print(f"[{rec['arch']} × {rec['shape']} × {rec['mesh']}] OK  "
          f"compile {rec['compile_s']}s  "
          f"flops/dev {cost.get('flops', 0):.3e}  "
          f"peak/dev {(rec['memory']['peak_bytes'] or 0) / gb:.2f} GiB  "
          f"coll {coll['total_bytes'] / gb:.3f} GiB")
    return rec


def demo(precision: str = "fp32", flash: bool = False) -> None:
    net = DiTConfig(image_size=16, patch=4, d_model=96, num_layers=2,
                    num_heads=4, d_ff=256, use_flash=flash)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    policy = resolve_policy(precision)
    params = init_dit(net, key)
    score = make_score_fn(params, net, sde, policy=policy)
    for method, kw in [
        ("adaptive", dict(eps_rel=0.05, precision=precision)),
        ("em", dict(n_steps=100)),
    ]:
        res = jax.jit(lambda k, kw=kw, method=method: sample(
            sde, score, (8, 16, 16, 3), k, method=method, **kw))(key)
        print(f"{method}[{policy.name}]: NFE {float(res.mean_nfe):.0f} "
              f"finite={bool(jnp.all(jnp.isfinite(res.x)))}")


def demo_cfg(scale: float, precision: str = "fp32") -> None:
    """Class-conditional demo (DESIGN.md §9): a train-free class-
    conditional DiT sampled with classifier-free guidance — one doubled
    batched forward per score evaluation, labels cycling 0..9."""
    from repro.core.guidance import class_conditional

    net = DiTConfig(image_size=16, patch=4, d_model=96, num_layers=2,
                    num_heads=4, d_ff=256, num_classes=10)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    policy = resolve_policy(precision)
    params = init_dit(net, key)
    score = make_score_fn(params, net, sde, policy=policy)
    conditioner, cond = class_conditional(jnp.arange(8) % 10, scale)
    res = jax.jit(lambda k: sample(
        sde, score, (8, 16, 16, 3), k, method="adaptive",
        config=AdaptiveConfig(eps_rel=0.05, precision=precision,
                              conditioner=conditioner),
        cond=cond))(key)
    print(f"cfg[scale={scale}, {policy.name}]: "
          f"NFE {float(res.mean_nfe):.0f} "
          f"finite={bool(jnp.all(jnp.isfinite(res.x)))}")


def demo_inpaint(precision: str = "fp32") -> None:
    """Inpainting demo (DESIGN.md §9): checkerboard-mask inpainting on
    the train-free DiT — observed pixels are projected (re-noised to
    each slot's own t) after every accepted step and pinned exactly at
    delivery. No checkpoint needed; see examples/inpaint_adaptive.py
    for the analytic-score version with exactness checks."""
    from repro.core.guidance import inpaint as make_inpaint

    net = DiTConfig(image_size=16, patch=4, d_model=96, num_layers=2,
                    num_heads=4, d_ff=256)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    policy = resolve_policy(precision)
    params = init_dit(net, key)
    score = make_score_fn(params, net, sde, policy=policy)
    yy, xx = jnp.mgrid[:16, :16]
    mask = jnp.broadcast_to(
        (((yy // 4 + xx // 4) % 2) == 0)[None, :, :, None],
        (8, 16, 16, 3)).astype(jnp.float32)
    observed = jnp.broadcast_to(
        jnp.linspace(-0.5, 0.5, 16)[None, :, None, None], (8, 16, 16, 3))
    conditioner, cond = make_inpaint(mask, observed)
    res = jax.jit(lambda k: sample(
        sde, score, (8, 16, 16, 3), k, method="adaptive",
        config=AdaptiveConfig(eps_rel=0.05, precision=precision,
                              conditioner=conditioner),
        cond=cond))(key)
    resid = float(jnp.abs((res.x - observed) * mask).max())
    print(f"inpaint[{policy.name}]: NFE {float(res.mean_nfe):.0f} "
          f"observed-pixel residual {resid:.2e} "
          f"finite={bool(jnp.all(jnp.isfinite(res.x)))}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--dryrun-loop", action="store_true",
                    help="compile the whole sharded sampling loop")
    ap.add_argument("--loop-devices", type=int, default=64,
                    help="fake host devices for --dryrun-loop (set pre-init)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="GPipe the DiT layer stack over the pod axis")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--precision", choices=sorted(PRESETS), default="fp32",
                    help="precision policy (DESIGN.md §8): network/state "
                         "dtypes; error control always stays fp32")
    ap.add_argument("--cfg-scale", type=float, default=None,
                    help="demo classifier-free guidance at this scale "
                         "on a class-conditional DiT (DESIGN.md §9)")
    ap.add_argument("--inpaint", action="store_true",
                    help="demo checkerboard-mask inpainting "
                         "(post-accept projection, DESIGN.md §9)")
    ap.add_argument("--flash", action="store_true",
                    help="route the demo DiT's attention through the "
                         "Pallas flash kernel (DESIGN.md §13; "
                         "interpreter mode on CPU)")
    args = ap.parse_args()
    if args.dryrun:
        dryrun(args.multi_pod, args.batch, pipeline=args.pipeline,
               precision=args.precision)
    elif args.dryrun_loop:
        dryrun_loop(args.batch, precision=args.precision)
    elif args.cfg_scale is not None:
        demo_cfg(args.cfg_scale, precision=args.precision)
    elif args.inpaint:
        demo_inpaint(precision=args.precision)
    else:
        demo(precision=args.precision, flash=args.flash)


if __name__ == "__main__":
    main()
