"""Serving launchers: batched LM decode, mesh-sharded diffusion, and
receding-horizon planning.

LM mode prefills a batch of prompts through ``forward`` (building the KV
caches by replaying tokens through ``serve_step`` — exact,
cache-consistent), then decodes greedily. On CPU this demonstrates the
full serving path with reduced configs; the production mesh lowers the
same ``serve_step``.

``--diffusion`` runs the continuous-batching diffusion server
(DESIGN.md §4) instead, optionally sharded over ``--fake-devices N``
placeholder devices so the per-device slot-refill path is exercised on a
CPU-only host exactly as it would run on a real data-parallel mesh.

``--plan`` runs the receding-horizon trajectory planner as a service
(DESIGN.md §10): closed-loop plan requests (state pinned via
horizon-axis inpainting, optional ``--cfg-scale`` returns guidance)
draining through the same ``DiffusionBatcher`` —
``repro.launch.plan`` is the underlying launcher.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32
  PYTHONPATH=src python -m repro.launch.serve --diffusion --fake-devices 4 \
      --slots 8 --requests 32
  PYTHONPATH=src python -m repro.launch.serve --plan --envs 6 --plan-steps 4
"""

from __future__ import annotations

# Placeholder devices MUST be requested before jax first initializes.
import os  # noqa: E402

from repro.launch._argv import argv_value  # noqa: E402

_n = argv_value("--fake-devices")
if _n and _n.isdigit() and int(_n) > 0:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core.precision import PRESETS
from repro.launch.steps import make_serve_step
from repro.models import init_decode_state, init_model
from repro.models.config import ModelConfig


def serve_batch(
    cfg: ModelConfig,
    params,
    prompts,  # (B, P[, K]) int32
    *,
    gen_len: int = 32,
    cache_len: int | None = None,
    cross_embeds=None,
):
    B = prompts.shape[0]
    P = prompts.shape[1]
    cache_len = cache_len or (P + gen_len)
    state = init_decode_state(cfg, B, cache_len)
    step = jax.jit(make_serve_step(cfg))

    # prefill by replay (exact; a fused prefill is a perf lever, §Perf)
    next_tok = None
    for i in range(P):
        b = {"tokens": prompts[:, i : i + 1]}
        if cross_embeds is not None:
            b["cross_embeds"] = cross_embeds
        next_tok, state = step(params, b, state)

    out = [next_tok]
    for _ in range(gen_len - 1):
        b = {"tokens": out[-1]}
        if cross_embeds is not None:
            b["cross_embeds"] = cross_embeds
        nt, state = step(params, b, state)
        out.append(nt)
    return jnp.concatenate(out, axis=1)


def serve_diffusion(*, slots: int, requests: int, image_size: int = 8,
                    sync_horizon: int = 4, compaction: bool = True,
                    precision: str = "fp32", inpaint: bool = False,
                    cfg_scale: float | None = None,
                    device_resident: bool = False,
                    tier: str | None = None,
                    deadline_ms: float | None = None,
                    telemetry: int = 0,
                    metrics_out: str | None = None,
                    trace_out: str | None = None) -> dict:
    """Continuous-batching diffusion serving on the ambient device set.

    Builds a data-parallel mesh over every available device, shards the
    slot batch across it, and drains ``requests`` prior-seeded requests
    through a small DiT score net with the horizon-chunked solver:
    ``sync_horizon`` Algorithm-1 iterations per host round-trip, with
    converged slots retired and refilled at every sync (DESIGN.md §7).
    Returns (and prints) throughput, the wasted-NFE fraction, and the
    per-device refill counts that evidence shard-local compaction.

    ``device_resident=True`` (DESIGN.md §12) runs the on-device serve
    loop instead: retirement polling, compaction, and admission execute
    in donated jitted programs, and the host is consulted only when a
    delivery or admission actually occurs — the printed record then
    also carries host-transfer counts.

    Per-request conditioning (DESIGN.md §9): ``inpaint=True`` attaches
    a checkerboard mask (phase alternating per request) to every
    request; ``cfg_scale`` switches to a class-conditional DiT with
    classifier-free guidance, labels cycling per request uid. The
    conditioner is per-server (one compiled program); the payload is
    per-request and travels with its slot through compaction.

    Tolerance tiers (DESIGN.md §14): ``tier`` names a quality class
    every request rides (``draft``/``standard``/``high_fidelity``), or
    ``"mixed"`` to cycle the presets across requests — the tiered
    server then runs EDF-within-priority-band admission and the record
    carries per-class NFE + deadline stats. ``deadline_ms`` sets each
    request's latency budget; late deliveries count as misses.

    Observability (DESIGN.md §15): ``telemetry=N`` attaches an N-deep
    per-slot step-telemetry ring to the carry (0 = off, bit-identical
    serve loop); ``metrics_out`` writes the metrics registry as JSON
    plus a sibling ``.prom`` Prometheus text file after the drain;
    ``trace_out`` turns on the stage tracer and writes the full
    ``trace_record()`` (requests, metrics, spans, step history) as JSON
    — the input of ``repro.analysis.telemetry``'s markdown report.
    """
    from repro.core import AdaptiveConfig, VPSDE
    from repro.core.guidance import ClassifierFree, Inpaint
    from repro.core.precision import resolve_policy
    from repro.launch.sample import make_sample_step
    from repro.models.dit import DiTConfig, init_dit
    from repro.observability.tracing import StageTracer
    from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest
    from repro.serving.scheduler import EdfPriorityAdmission

    if inpaint and cfg_scale is not None:
        raise ValueError("pick one conditioner per server: "
                         "--inpaint or --cfg-scale")
    ndev = jax.device_count()
    mesh = jax.make_mesh((ndev,), ("data",))
    num_classes = 10 if cfg_scale is not None else 0
    net = DiTConfig(image_size=image_size, patch=4, d_model=32, num_layers=2,
                    num_heads=2, d_ff=64, num_classes=num_classes)
    sde = VPSDE()
    policy = resolve_policy(precision)
    conditioner = None
    if inpaint:
        conditioner = Inpaint()
    elif cfg_scale is not None:
        conditioner = ClassifierFree(scale=float(cfg_scale))
    cfg = AdaptiveConfig(eps_rel=0.05, precision=precision,
                         conditioner=conditioner)
    # weights stored at the policy's param dtype; the per-device weight
    # HBM and weight-broadcast bytes halve under bf16_full
    params = policy.cast_params(init_dit(net, jax.random.PRNGKey(0)))
    step = make_sample_step(net, sde, cfg)
    shape = (image_size, image_size, net.channels)
    tiered = tier is not None
    if tiered and tier != "mixed":
        from repro.configs.diffusion import resolve_tier
        resolve_tier(tier)  # fail fast on a bad preset name
    tracer = StageTracer() if trace_out else None
    b = DiffusionBatcher(sde, step, params, shape,
                         slots=slots, cfg=cfg, mesh=mesh,
                         sync_horizon=sync_horizon, compaction=compaction,
                         device_resident=device_resident,
                         tolerance_classes=tiered or None,
                         admission=(EdfPriorityAdmission(aging_s=5.0)
                                    if tiered else None),
                         telemetry=telemetry, tracer=tracer)
    mixed_cycle = ("draft", "standard", "high_fidelity")

    def request_tier(uid: int):
        if not tiered:
            return None
        return mixed_cycle[uid % len(mixed_cycle)] if tier == "mixed" else tier

    def request_cond(uid: int):
        if inpaint:
            yy, xx = jnp.mgrid[:image_size, :image_size]
            mask = (((yy // 2 + xx // 2) + uid) % 2 == 0)
            mask = jnp.broadcast_to(mask[:, :, None], shape)
            observed = jnp.broadcast_to(
                jnp.linspace(-0.5, 0.5, image_size)[:, None, None], shape)
            return {"mask": mask.astype(jnp.float32),
                    "observed": jnp.asarray(observed, jnp.float32)}
        if cfg_scale is not None:
            return {"label": uid % num_classes}
        return None

    for uid in range(requests):
        b.submit(ImageRequest(uid=uid, seed=uid, cond=request_cond(uid),
                              tier=request_tier(uid),
                              deadline_ms=deadline_ms))
    t0 = time.time()
    done = b.run_to_completion()
    dt = time.time() - t0
    nfes = [done[u].nfe for u in sorted(done)]
    rec = {
        "devices": ndev,
        "slots": slots,
        "slots_per_device": b.slots_per_device,
        "sync_horizon": sync_horizon,
        "compaction": compaction,
        "precision": policy.as_dict(),
        "conditioner": ("inpaint" if inpaint
                        else f"cfg:{cfg_scale}" if cfg_scale is not None
                        else "none"),
        "completed": len(done),
        "samples_per_sec": len(done) / dt,
        "mean_nfe": sum(nfes) / len(nfes),
        "total_iterations": b.total_iterations,
        "wasted_nfe_fraction": b.wasted_nfe_fraction,
        "refills_per_device": list(b.refills_per_device),
        "device_resident": device_resident,
        "host_transfers": b.host_transfers,
        "host_transfers_per_request": b.host_transfers / max(len(done), 1),
        "tier": tier,
        "deadline_ms": deadline_ms,
        "class_stats": b.class_stats if tiered else None,
        "telemetry": telemetry,
        "metrics_out": metrics_out,
        "trace_out": trace_out,
    }
    if metrics_out:
        import json
        import pathlib

        reg = b.metrics_snapshot()
        path = pathlib.Path(metrics_out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(reg.to_json(), indent=2) + "\n")
        # Prometheus text exposition rides next to the JSON, same stem
        path.with_suffix(".prom").write_text(reg.to_prometheus())
        print(f"metrics -> {path} (+ {path.with_suffix('.prom').name})")
    if trace_out:
        import json
        import pathlib

        path = pathlib.Path(trace_out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(b.trace_record(), indent=2) + "\n")
        print(f"trace -> {path}")
    print(f"diffusion serve[{policy.name}, {rec['conditioner']}"
          f"{', device-resident' if device_resident else ''}]: "
          f"{rec['completed']}/{requests} requests in {dt:.1f}s "
          f"({rec['samples_per_sec']:.2f} samples/s) on {ndev} device(s), "
          f"{b.slots_per_device} slots/device, horizon {sync_horizon}, "
          f"mean NFE {rec['mean_nfe']:.0f}, "
          f"wasted NFE {rec['wasted_nfe_fraction']:.1%}, "
          f"host transfers/request {rec['host_transfers_per_request']:.1f}, "
          f"refills/device {rec['refills_per_device']}")
    if tiered:
        for name in sorted(rec["class_stats"]):
            s = rec["class_stats"][name]
            print(f"  tier {name:>13}: {s['delivered']} delivered, "
                  f"mean NFE {s['mean_nfe']:.0f}, "
                  f"deadline misses {s['deadline_misses']}, "
                  f"mean wait {s['mean_wait_s'] * 1e3:.0f}ms")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--diffusion", action="store_true",
                    help="run the mesh-sharded diffusion server instead")
    ap.add_argument("--plan", action="store_true",
                    help="run the receding-horizon planner service "
                         "(DESIGN.md §10)")
    ap.add_argument("--plan-env", default="ou", choices=["ou", "pointmass"],
                    help="analytic environment for --plan")
    ap.add_argument("--envs", type=int, default=6,
                    help="closed-loop environments for --plan")
    ap.add_argument("--plan-steps", type=int, default=4,
                    help="control rounds per environment for --plan")
    ap.add_argument("--plan-horizon", type=int, default=8,
                    help="plan horizon H for --plan")
    ap.add_argument("--unet", action="store_true",
                    help="--plan with a train-free temporal UNet score "
                         "instead of the analytic one")
    ap.add_argument("--fake-devices", type=int, default=None,
                    help="force N placeholder host devices (set pre-init)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--sync-horizon", type=int, default=4,
                    help="device iterations per host sync (diffusion mode)")
    ap.add_argument("--no-compaction", action="store_true",
                    help="monolithic-wave baseline: no mid-flight slot refill")
    ap.add_argument("--device-resident", action="store_true",
                    help="on-device serve loop (DESIGN.md §12): donated "
                         "carry, event-driven host syncs (diffusion mode)")
    ap.add_argument("--precision", default="fp32", choices=sorted(PRESETS),
                    help="precision policy for the diffusion server "
                         "(DESIGN.md §8); error control always stays fp32")
    ap.add_argument("--inpaint", action="store_true",
                    help="per-request checkerboard-mask inpainting "
                         "(diffusion mode, DESIGN.md §9)")
    ap.add_argument("--cfg-scale", type=float, default=None,
                    help="per-request classifier-free guidance at this "
                         "scale (diffusion mode, DESIGN.md §9)")
    ap.add_argument("--tier", default=None,
                    help="tolerance class for diffusion requests — a "
                         "preset (draft/standard/high_fidelity) or "
                         "'mixed' to cycle presets across requests "
                         "(DESIGN.md §14)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget; late deliveries "
                         "count as deadline misses in the per-class "
                         "stats (diffusion mode, DESIGN.md §14)")
    ap.add_argument("--telemetry", type=int, default=0,
                    help="per-slot step-telemetry ring capacity; 0 = off "
                         "(bit-identical serve loop, DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry as JSON here plus a "
                         "sibling .prom Prometheus text file "
                         "(diffusion mode, DESIGN.md §15)")
    ap.add_argument("--trace-out", default=None,
                    help="enable stage tracing and write the JSON trace "
                         "record here — feed it to "
                         "'python -m repro.analysis.telemetry' for the "
                         "markdown report (diffusion mode, DESIGN.md §15)")
    args = ap.parse_args()

    if args.plan:
        from repro.launch.plan import serve_planning

        serve_planning(env_name=args.plan_env, envs=args.envs,
                       steps=args.plan_steps, slots=args.slots,
                       sync_horizon=args.sync_horizon,
                       compaction=not args.no_compaction,
                       horizon=args.plan_horizon,
                       cfg_scale=args.cfg_scale or 0.0,
                       precision=args.precision, unet=args.unet)
        return
    if args.diffusion:
        serve_diffusion(slots=args.slots, requests=args.requests,
                        sync_horizon=args.sync_horizon,
                        compaction=not args.no_compaction,
                        precision=args.precision,
                        inpaint=args.inpaint, cfg_scale=args.cfg_scale,
                        device_resident=args.device_resident,
                        tier=args.tier, deadline_ms=args.deadline_ms,
                        telemetry=args.telemetry,
                        metrics_out=args.metrics_out,
                        trace_out=args.trace_out)
        return
    if args.arch is None:
        ap.error("--arch is required unless --diffusion is given")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape += (cfg.num_codebooks,)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)
    cross = (
        jax.random.normal(key, (args.batch, cfg.num_patches, cfg.vision_dim),
                          jnp.dtype(cfg.dtype))
        if cfg.vision_dim else None
    )
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, gen_len=args.gen_len,
                       cross_embeds=cross)
    dt = time.time() - t0
    n_new = toks.shape[1] * args.batch
    print(f"generated {toks.shape} in {dt:.1f}s ({n_new / dt:.1f} tok/s)")
    print("sample:", jax.device_get(toks[0, :16]).tolist())


if __name__ == "__main__":
    main()
