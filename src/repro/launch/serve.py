"""Batched decode serving launcher.

Prefills a batch of prompts through ``forward`` (building the KV caches
by replaying tokens through ``serve_step`` — exact, cache-consistent),
then decodes greedily. On CPU this demonstrates the full serving path
with reduced configs; the production mesh lowers the same ``serve_step``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_serve_step
from repro.models import init_decode_state, init_model
from repro.models.config import ModelConfig


def serve_batch(
    cfg: ModelConfig,
    params,
    prompts,  # (B, P[, K]) int32
    *,
    gen_len: int = 32,
    cache_len: int | None = None,
    cross_embeds=None,
):
    B = prompts.shape[0]
    P = prompts.shape[1]
    cache_len = cache_len or (P + gen_len)
    state = init_decode_state(cfg, B, cache_len)
    step = jax.jit(make_serve_step(cfg))

    # prefill by replay (exact; a fused prefill is a perf lever, §Perf)
    next_tok = None
    for i in range(P):
        b = {"tokens": prompts[:, i : i + 1]}
        if cross_embeds is not None:
            b["cross_embeds"] = cross_embeds
        next_tok, state = step(params, b, state)

    out = [next_tok]
    for _ in range(gen_len - 1):
        b = {"tokens": out[-1]}
        if cross_embeds is not None:
            b["cross_embeds"] = cross_embeds
        nt, state = step(params, b, state)
        out.append(nt)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape += (cfg.num_codebooks,)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)
    cross = (
        jax.random.normal(key, (args.batch, cfg.num_patches, cfg.vision_dim),
                          jnp.dtype(cfg.dtype))
        if cfg.vision_dim else None
    )
    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, gen_len=args.gen_len,
                       cross_embeds=cross)
    dt = time.time() - t0
    n_new = toks.shape[1] * args.batch
    print(f"generated {toks.shape} in {dt:.1f}s ({n_new / dt:.1f} tok/s)")
    print("sample:", jax.device_get(toks[0, :16]).tolist())


if __name__ == "__main__":
    main()
