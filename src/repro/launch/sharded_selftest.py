"""CPU self-test of the mesh-sharded sampling & serving path (DESIGN.md §3).

Forces fake host devices (the same ``xla_force_host_platform_device_count``
trick the production dry-run and tests/test_sharding_rules.py's sibling
integration test use), then executes — not just lowers — the multi-device
path end-to-end:

  1. ``sample(..., mesh=...)`` is bit-identical to the unsharded run for
     a fixed key, with both the jnp step math and the shard_map'd fused
     Pallas kernel;
  2. the fused ``sharded_error_step`` matches the single-device kernel,
     batch-sharded (bitwise) and batch+feature-sharded (the cross-device
     ``scaled_error_l2_psum`` combine, exact up to fp summation order);
  3. the mesh-sharded ``DiffusionBatcher`` completes every request and
     refills finished slots independently on every device.

Prints one JSON line with the results; exits non-zero on any failure.

  PYTHONPATH=src python -m repro.launch.sharded_selftest
  SELFTEST_DEVICES=8 PYTHONPATH=src python -m repro.launch.sharded_selftest
"""

# Fake devices MUST be requested before jax initializes.
import os  # noqa: E402

_DEVICES = int(os.environ.get("SELFTEST_DEVICES", "4"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DEVICES} "
    + os.environ.get("XLA_FLAGS", "")
)

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveConfig, VPSDE, sample
from repro.core.analytic import gaussian_noise_pred, gaussian_score


def check_sample_equivalence(mesh, *, fused: bool) -> dict:
    """sample() sharded vs unsharded: same key ⇒ bit-identical output."""
    sde = VPSDE()
    score = gaussian_score(sde)
    shape = (2 * jax.device_count(), 64)
    cfg = AdaptiveConfig(eps_rel=0.05, use_fused_kernel=fused)
    key = jax.random.PRNGKey(0)
    ref = jax.jit(lambda k: sample(sde, score, shape, k, config=cfg))(key)
    sh = jax.jit(lambda k: sample(sde, score, shape, k, config=cfg, mesh=mesh))(key)
    n_shards = len(sh.x.sharding.device_set)
    return {
        "bitwise_equal": bool(
            np.array_equal(np.asarray(ref.x), np.asarray(sh.x))
            and np.array_equal(np.asarray(ref.nfe), np.asarray(sh.nfe))
        ),
        "max_abs_diff": float(jnp.max(jnp.abs(ref.x - sh.x))),
        "mean_nfe": float(ref.mean_nfe),
        "n_shards": n_shards,
        "sharded_over_devices": n_shards == jax.device_count(),
    }


def check_fused_kernel(mesh2d) -> dict:
    """sharded_error_step vs error_step, batch- and batch+feature-sharded."""
    from repro.kernels.solver_step import ops

    ks = jax.random.split(jax.random.PRNGKey(1), 8)
    B, shape = 8, (8, 10, 10, 3)  # D=300: exercises lane padding too
    x, xp, s2, z, xv = (jax.random.normal(k, shape) for k in ks[:5])
    e0, d1, d2 = (0.01 * jax.random.normal(k, (B,)) for k in ks[5:])
    kw = dict(eps_abs=1e-2, eps_rel=0.01)
    ref_x, ref_e = ops.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    b_x, b_e = ops.sharded_error_step(
        x, xp, s2, z, xv, e0, d1, d2, mesh=mesh2d, batch_axes=("data",), **kw
    )
    f_x, f_e = ops.sharded_error_step(
        x, xp, s2, z, xv, e0, d1, d2,
        mesh=mesh2d, batch_axes=("data",), feature_axis="model", **kw
    )
    return {
        "batch_sharded_bitwise": bool(
            np.array_equal(np.asarray(ref_x), np.asarray(b_x))
            and np.array_equal(np.asarray(ref_e), np.asarray(b_e))
        ),
        "feature_sharded_close": bool(
            np.array_equal(np.asarray(ref_x), np.asarray(f_x))
            and np.allclose(np.asarray(ref_e), np.asarray(f_e), rtol=1e-5)
        ),
    }


def check_batcher(mesh) -> dict:
    """Sharded DiffusionBatcher: completion + per-device slot refill."""
    from repro.launch.sample import make_sample_step
    from repro.models.dit import DiTConfig
    from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    forward_fn = gaussian_noise_pred(sde)

    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # signature holder; forward_fn wins
    step = make_sample_step(net, sde, cfg, forward_fn=forward_fn)
    ndev = jax.device_count()
    slots = 2 * ndev
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(32,),
                         slots=slots, cfg=cfg, mesh=mesh, sync_horizon=4)
    n_req = 6 * ndev
    for uid in range(n_req):
        b.submit(ImageRequest(uid=uid, seed=uid))
    done = b.run_to_completion()
    xs = np.stack([done[u].result for u in range(n_req)]) \
        if len(done) == n_req else np.zeros((1, 1))

    # shard-locality + scheduling invariance: an unsharded batcher with a
    # different horizon must deliver bit-identical per-request samples —
    # per-slot keys make trajectories independent of slot placement,
    # compaction permutations, and device count
    b_ref = DiffusionBatcher(sde, step, params=None, sample_shape=(32,),
                             slots=slots, cfg=cfg, sync_horizon=1)
    for uid in range(n_req):
        b_ref.submit(ImageRequest(uid=uid, seed=uid))
    done_ref = b_ref.run_to_completion()
    invariant = len(done_ref) == n_req and len(done) == n_req and all(
        np.array_equal(done[u].result, done_ref[u].result)
        for u in range(n_req)
    )
    return {
        "all_completed": len(done) == n_req,
        "finite": bool(np.isfinite(xs).all()),
        "slots_per_device": b.slots_per_device,
        "refills_per_device": list(b.refills_per_device),
        # every device refilled beyond its initial fill ⇒ refill is
        # per-device, never gated on the global batch finishing
        "per_device_refill": all(
            r > b.slots_per_device for r in b.refills_per_device
        ),
        "total_assignments_match": sum(b.refills_per_device) == n_req,
        "wasted_nfe_fraction": b.wasted_nfe_fraction,
        "scheduling_invariant": bool(invariant),
    }


def check_device_resident(mesh) -> dict:
    """Device-resident serving on a real mesh (DESIGN.md §12): the
    donated multi-horizon driver + on-device event program must deliver
    bit-identical samples to the host-driven sharded loop, with fewer
    device→host transfers."""
    from repro.launch.sample import make_sample_step
    from repro.models.dit import DiTConfig
    from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)
    step = make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde))
    ndev = jax.device_count()
    slots, n_req = 2 * ndev, 6 * ndev

    def run(device_resident):
        b = DiffusionBatcher(sde, step, params=None, sample_shape=(32,),
                             slots=slots, cfg=cfg, mesh=mesh,
                             sync_horizon=4,
                             device_resident=device_resident)
        for uid in range(n_req):
            b.submit(ImageRequest(uid=uid, seed=uid))
        done = b.run_to_completion()
        return b, done

    b_host, done_host = run(False)
    b_res, done_res = run(True)
    completed = len(done_host) == n_req and len(done_res) == n_req
    return {
        "all_completed": completed,
        "bitwise_equal": completed and all(
            np.array_equal(done_host[u].result, done_res[u].result)
            for u in range(n_req)
        ),
        "iterations_equal": b_host.total_iterations == b_res.total_iterations,
        "host_transfers": b_host.host_transfers,
        "resident_transfers": b_res.host_transfers,
        "transfers_reduced": b_res.host_transfers < b_host.host_transfers,
    }


def main() -> int:
    ndev = jax.device_count()
    mesh = jax.make_mesh((ndev,), ("data",))
    mesh2d = jax.make_mesh((ndev // 2, 2), ("data", "model"))
    results = {
        "devices": ndev,
        "sample_jnp": check_sample_equivalence(mesh, fused=False),
        "sample_fused": check_sample_equivalence(mesh, fused=True),
        "fused_kernel": check_fused_kernel(mesh2d),
        "batcher": check_batcher(mesh),
        "device_resident": check_device_resident(mesh),
    }
    ok = (
        ndev >= 2
        and results["sample_jnp"]["bitwise_equal"]
        and results["sample_jnp"]["sharded_over_devices"]
        and results["sample_fused"]["bitwise_equal"]
        and results["fused_kernel"]["batch_sharded_bitwise"]
        and results["fused_kernel"]["feature_sharded_close"]
        and results["batcher"]["all_completed"]
        and results["batcher"]["finite"]
        and results["batcher"]["per_device_refill"]
        and results["batcher"]["total_assignments_match"]
        and results["batcher"]["scheduling_invariant"]
        and results["device_resident"]["bitwise_equal"]
        and results["device_resident"]["iterations_equal"]
        and results["device_resident"]["transfers_reduced"]
    )
    results["ok"] = ok
    print(json.dumps(results))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
