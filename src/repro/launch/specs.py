"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) combo.

``build_dryrun`` assembles everything ``dryrun.py`` needs for one combo:
the step function, abstract arguments (weak-type-correct, shardable, no
device allocation), and in/out shardings. The same builders back the
real train/serve launchers, which feed concrete arrays instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape, apply_shape_policy
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import init_decode_state, init_model
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.parallel.sharding import (
    MODEL_AXIS,
    batch_sharding,
    data_axes,
    kv_cache_sharding,
    param_shardings,
    replicated,
)

Array = jax.Array


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))


def abstract_opt_state(optimizer: AdamW, params_abs):
    return jax.eval_shape(optimizer.init, params_abs)


def token_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    if cfg.num_codebooks > 1:
        toks = _sds((batch, seq, cfg.num_codebooks), jnp.int32)
    else:
        toks = _sds((batch, seq), jnp.int32)
    specs = {"tokens": toks}
    if cfg.vision_dim:
        specs["cross_embeds"] = _sds(
            (batch, cfg.num_patches, cfg.vision_dim), cfg.dtype
        )
    return specs


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_specs) -> Dict[str, Any]:
    return {
        k: batch_sharding(mesh, v.shape[0], v.ndim) for k, v in batch_specs.items()
    }


def decode_state_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, cache_len))


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, state_abs):
    """Walk the stacked decode state; leaves carry a leading repeat dim."""
    axes = data_axes(mesh)

    def fn(path, leaf):
        ndim = leaf.ndim
        shape = leaf.shape
        name = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        if name in ("k", "v") and ndim == 5:  # stacked KV (R, B, Sc, Kv, Dh)
            inner = kv_cache_sharding(mesh, shape[1], shape[2], shape[3])
            return NamedSharding(mesh, P(None, *inner.spec))
        # MambaState stacked: conv (R, B, W-1, C), ssm (R, B, H, N, P)
        if name == "ssm" and ndim == 5:
            h = shape[2]
            ax = MODEL_AXIS if h % mesh.shape.get(MODEL_AXIS, 1) == 0 else None
            bsh = batch_sharding(mesh, shape[1], 1).spec
            bax = bsh[0] if bsh else None
            return NamedSharding(mesh, P(None, bax, ax, None, None))
        if name == "conv" and ndim == 4:
            bsh = batch_sharding(mesh, shape[1], 1).spec
            bax = bsh[0] if bsh else None
            return NamedSharding(mesh, P(None, bax, None, None))
        return replicated(mesh)

    return jax.tree_util.tree_map_with_path(fn, state_abs)


@dataclasses.dataclass
class DryRunSpec:
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    # Buffer donation mirrors production: train steps donate params +
    # optimizer state (updated in place), serve steps donate the KV/SSM
    # cache. Without it the dry-run double-buffers the largest state and
    # overstates peak memory ~2×.
    donate_argnums: Tuple[int, ...] = ()


def build_dryrun(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    *,
    remat: str = "none",
    dtype: str = "bfloat16",
    unroll: bool = True,
    fsdp: bool = False,
    zero1: bool = False,  # shard ONLY optimizer moments over data (ZeRO-1)
    cfg_overrides: Optional[dict] = None,
    last_logits_only: bool = True,
) -> DryRunSpec:
    """Assemble (fn, abstract args, shardings) for one (arch × shape)."""
    cfg = apply_shape_policy(cfg, shape).replace(dtype=dtype)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    nexp = cfg.moe.physical_experts if cfg.moe else None
    params_abs = abstract_params(cfg)
    p_shard = param_shardings(params_abs, mesh, nexp, fsdp=fsdp)

    if shape.kind == "train":
        optimizer = AdamW(lr=1e-4)
        opt_abs = abstract_opt_state(optimizer, params_abs)
        # moments shard like params; step replicated
        o_shard = type(opt_abs)(
            step=replicated(mesh),
            mu=param_shardings(opt_abs.mu, mesh, nexp, fsdp=fsdp or zero1),
            nu=param_shardings(opt_abs.nu, mesh, nexp, fsdp=fsdp or zero1),
        )
        batch_abs = token_specs(cfg, shape.global_batch, shape.seq_len)
        b_shard = batch_shardings(cfg, mesh, batch_abs)
        fn = make_train_step(cfg, optimizer, remat=remat, unroll=unroll)
        out_shardings = (p_shard, o_shard, None)
        return DryRunSpec(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=out_shardings,
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch_abs = token_specs(cfg, shape.global_batch, shape.seq_len)
        b_shard = batch_shardings(cfg, mesh, batch_abs)
        fn = make_prefill_step(cfg, unroll=unroll, last_logits_only=last_logits_only)
        return DryRunSpec(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(params_abs, batch_abs),
            in_shardings=(p_shard, b_shard),
            out_shardings=None,
        )

    # decode: one token, cache of seq_len
    batch_abs = token_specs(cfg, shape.global_batch, 1)
    b_shard = batch_shardings(cfg, mesh, batch_abs)
    state_abs = decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    s_shard = decode_state_shardings(cfg, mesh, state_abs)
    fn = make_serve_step(cfg, unroll=unroll)
    return DryRunSpec(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(params_abs, batch_abs, state_abs),
        in_shardings=(p_shard, b_shard, s_shard),
        out_shardings=(None, s_shard),
        donate_argnums=(2,),
    )
