"""Step functions: pjit-able train_step / serve_step per architecture.

``make_train_step`` returns f(params, opt_state, batch) → (params,
opt_state, metrics); ``make_serve_step`` returns f(params, batch, state)
→ (next_tokens, state). ``batch`` is a dict so VLM image embeddings ride
along uniformly.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.data.tokens import lm_loss
from repro.models import decode_step, forward
from repro.models.config import ModelConfig
from repro.optim import AdamW

Array = jax.Array
Batch = Dict[str, Array]


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    *,
    remat: str = "none",
    use_flash: bool = False,
    use_pallas_ssd: bool = False,
    unroll: bool = False,
) -> Callable:
    def loss_fn(params, batch: Batch):
        logits, aux = forward(
            params,
            batch["tokens"],
            cfg,
            cross_embeds=batch.get("cross_embeds"),
            use_flash=use_flash,
            use_pallas_ssd=use_pallas_ssd,
            remat=remat,
            unroll=unroll,
        )
        ce = lm_loss(logits, batch["tokens"])
        return ce + aux, (ce, aux)

    def train_step(params, opt_state, batch: Batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True,
                    unroll: bool = False) -> Callable:
    def serve_step(params, batch: Batch, state):
        logits, state = decode_step(
            params,
            batch["tokens"],
            state,
            cfg,
            cross_embeds=batch.get("cross_embeds"),
            start_pos=batch.get("start_pos"),
            unroll=unroll,
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, state

    return serve_step


def make_prefill_step(
    cfg: ModelConfig, *, use_flash: bool = False, use_pallas_ssd: bool = False,
    unroll: bool = False, last_logits_only: bool = True,
) -> Callable:
    """Full-sequence forward (the prefill shape lowers this)."""

    def prefill_step(params, batch: Batch):
        logits, _ = forward(
            params,
            batch["tokens"],
            cfg,
            cross_embeds=batch.get("cross_embeds"),
            use_flash=use_flash,
            use_pallas_ssd=use_pallas_ssd,
            unroll=unroll,
            last_logits_only=last_logits_only,
        )
        # next-token for the last position of every sequence
        return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    return prefill_step
