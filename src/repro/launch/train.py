"""LM training launcher (runs for real on whatever mesh fits the host).

On the production mesh this is the same code path the dry-run lowers;
on CPU it runs reduced configs end-to-end (the per-arch smoke tests and
the quickstart example call into this).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import TokenPipelineConfig, synth_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.parallel.sharding import batch_sharding, param_shardings


def train_loop(
    cfg: ModelConfig,
    *,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    mesh=None,
    ckpt_dir: str | None = None,
    log_every: int = 5,
):
    mesh = mesh or make_host_mesh()
    optimizer = AdamW(lr=warmup_cosine(lr, max(steps // 10, 1), steps))
    key = jax.random.PRNGKey(seed)

    with mesh:
        p_shard = param_shardings(
            jax.eval_shape(lambda k: init_model(cfg, k), key),
            mesh,
            cfg.moe.num_experts if cfg.moe else None,
        )
        params = jax.jit(lambda k: init_model(cfg, k), out_shardings=p_shard)(key)
        opt_state = optimizer.init(params)
        step_fn = jax.jit(make_train_step(cfg, optimizer))

        pipe = TokenPipelineConfig(
            vocab_size=cfg.vocab_size,
            seq_len=seq,
            global_batch=batch,
            num_codebooks=cfg.num_codebooks,
            seed=seed,
        )
        cross = (
            jax.random.normal(key, (batch, cfg.num_patches, cfg.vision_dim),
                              jnp.dtype(cfg.dtype))
            if cfg.vision_dim else None
        )

        losses = []
        t0 = time.time()
        for step in range(steps):
            tokens = synth_batch(pipe, step)
            b = {"tokens": tokens}
            if cross is not None:
                b["cross_embeds"] = cross
            params, opt_state, metrics = step_fn(params, opt_state, b)
            losses.append(float(metrics["ce"]))
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"step {step:4d}  ce {losses[-1]:.4f}  "
                    f"moe_aux {float(metrics['moe_aux']):.4f}  "
                    f"({(time.time() - t0) / (step + 1):.2f}s/step)"
                )
        if ckpt_dir:
            save_checkpoint(ckpt_dir, steps, {"params": params},
                            metadata={"arch": cfg.name})
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-scale) variant")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.scaled_down()
    _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir,
    )
    print(f"final ce {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
