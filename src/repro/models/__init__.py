from repro.models.config import MambaConfig, ModelConfig, MoEConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_model,
)

__all__ = [
    "MambaConfig", "ModelConfig", "MoEConfig",
    "decode_step", "forward", "init_decode_state", "init_model",
]
