"""Attention mixers: global ("A"), sliding-window ("L"), cross ("X").

Covers every attention variant in the assigned pool: GQA (all), QKV bias
(qwen1.5), qk-norm (qwen3), sliding window (gemma3 local layers and the
long_500k SWA variant of dense archs), cross-attention over projected
image patches (llama-3.2-vision), and logit soft-capping (gemma-style,
optional).

Forward (train/prefill) uses either the jnp reference attention or the
Pallas flash kernel (``use_flash``). Decode uses the ring-buffer
``LayerKVCache`` — O(S_cache) per token, GSPMD-shardable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.kvcache import LayerKVCache, cache_write, valid_mask
from repro.models.layers import apply_norm, dense_init, rope

Array = jax.Array


def init_attention(key: Array, cfg: ModelConfig, kind: str) -> dict:
    E, H, Kv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    kv_in = cfg.vision_dim if kind == "X" else E
    p = {
        "wq": dense_init(ks[0], (E, H, Dh), dtype, fan_in=E),
        "wk": dense_init(ks[1], (kv_in, Kv, Dh), dtype, fan_in=kv_in),
        "wv": dense_init(ks[2], (kv_in, Kv, Dh), dtype, fan_in=kv_in),
        "wo": dense_init(ks[3], (H, Dh, E), dtype, fan_in=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dtype)
        p["bk"] = jnp.zeros((Kv, Dh), dtype)
        p["bv"] = jnp.zeros((Kv, Dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((Dh,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((Dh,), dtype)}
    return p


def _project_qkv(params: dict, x: Array, kv_src: Array, cfg: ModelConfig):
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"])
    k = jnp.einsum("bse,ehd->bshd", kv_src, params["wk"])
    v = jnp.einsum("bse,ehd->bshd", kv_src, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = apply_norm(params["q_norm"], q, "rmsnorm")
        k = apply_norm(params["k_norm"], k, "rmsnorm")
    return q, k, v


def _softcap(logits: Array, cap: float) -> Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _ref_attention(q, k, v, *, causal: bool, window: Optional[int], softcap: float):
    """(B,S,H,D)x(B,Sk,Kv,D) GQA attention, fp32 softmax.

    Internal: callers outside this module go through :func:`attention`,
    the single owner of the flash/softcap/window dispatch.
    """
    B, S, H, D = q.shape
    Kv = k.shape[2]
    group = H // Kv
    kk = jnp.repeat(k, group, axis=2)
    vv = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (D ** -0.5)
    logits = _softcap(logits, softcap)
    Sk = k.shape[1]
    qpos = jnp.arange(S)[:, None] + (Sk - S)  # right-aligned (prefill: Sk == S)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    softcap: float = 0.0,
    use_flash: bool = False,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Array:
    """Projected-head attention, (B, S, H, D) × (B, Sk, Kv, D) → (B, S, H, D).

    The single owner of the flash/softcap/window dispatch (DESIGN.md
    §13): every score network and the LM blocks route their attention
    here, so the two implementations — the jnp reference and the Pallas
    flash kernel (``repro.kernels.flash_attention``) — stay behind one
    seam. With ``use_flash`` the online-softmax kernel runs with fp32
    softmax accumulators regardless of the operand dtype (bf16 under a
    precision policy, DESIGN.md §8); the reference path upcasts to fp32
    the same way, so the two agree to fp32-accumulation tolerance and
    ``use_flash=False`` is bit-identical to the historical reference
    path.

    ``softcap > 0`` (gemma-style logit soft-capping) has no kernel
    implementation and always takes the reference path — callers get
    the fallback from this one place instead of re-implementing the
    predicate. The flash path requires self-attention shapes
    (``q.shape[1] == k.shape[1]``); sequence lengths that are not a
    multiple of the q-block are zero-padded and sliced by the kernel
    wrapper (``kernels.flash_attention.ops``).
    """
    if use_flash and not softcap and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention import ops as fa

        kw = {}
        if block_q is not None:
            kw["block_q"] = block_q
        if block_k is not None:
            kw["block_k"] = block_k
        out = fa.attention(
            jnp.transpose(q, (0, 2, 1, 3)),
            jnp.transpose(k, (0, 2, 1, 3)),
            jnp.transpose(v, (0, 2, 1, 3)),
            causal=causal,
            window=window,
            interpret=interpret,
            **kw,
        )
        return jnp.transpose(out, (0, 2, 1, 3))
    return _ref_attention(q, k, v, causal=causal, window=window, softcap=softcap)


def attention_forward(
    params: dict,
    x: Array,
    cfg: ModelConfig,
    kind: str,
    positions: Array,
    *,
    cross_kv: Optional[Array] = None,
    use_flash: bool = False,
) -> Array:
    """Training / prefill attention. x: (B, S, E) → (B, S, E)."""
    if kind == "X":
        assert cross_kv is not None
        q, k, v = _project_qkv(params, x, cross_kv, cfg)
        out = attention(
            q, k, v, causal=False, window=None, softcap=cfg.attn_logit_softcap
        )
    else:
        q, k, v = _project_qkv(params, x, x, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cfg.attn_q_seq_shard:
            # 2-D sequence parallelism (§Perf): pin the query-position axis
            # to the model axis so the O(S²) score/PV matmuls divide by it
            # even when heads don't.
            from jax.sharding import PartitionSpec as P

            U = P.UNCONSTRAINED
            q = jax.lax.with_sharding_constraint(
                q, P(U, cfg.attn_q_seq_shard, U, U)
            )
        window = cfg.sliding_window if kind == "L" else None
        out = attention(
            q, k, v, causal=True, window=window,
            softcap=cfg.attn_logit_softcap, use_flash=use_flash,
        )
    return jnp.einsum("bshd,hde->bse", out, params["wo"])


def attention_decode(
    params: dict,
    x: Array,
    cfg: ModelConfig,
    kind: str,
    cache: Optional[LayerKVCache],
    *,
    cross_kv: Optional[Array] = None,
    start_pos: Optional[Array] = None,  # (B,) continuous-batching isolation
) -> Tuple[Array, Optional[LayerKVCache]]:
    """Single-token decode. x: (B, 1, E) → ((B, 1, E), cache')."""
    if kind == "X":
        # Cross-attention is stateless: the image KV is tiny vs. the text
        # cache; recompute (the projector output is shared across steps).
        y = attention_forward(params, x, cfg, kind, None, cross_kv=cross_kv)
        return y, cache

    assert cache is not None
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    pos_cur = cache.length  # scalar: position of this token
    q = rope(q, pos_cur[None, None].astype(jnp.int32) + jnp.zeros((x.shape[0], 1), jnp.int32), cfg.rope_theta)
    k_new = rope(k_new, pos_cur[None, None].astype(jnp.int32) + jnp.zeros((x.shape[0], 1), jnp.int32), cfg.rope_theta)

    window = cfg.sliding_window if kind == "L" else None

    if cfg.decode_flash_shard:
        from repro.models.kvcache import LayerKVCache
        from repro.parallel.collectives import flash_decode

        out, ck, cv, pos = flash_decode(
            q, k_new, v_new, cache.k, cache.v, cache.pos, cache.length,
            axis=cfg.decode_flash_shard, window=window,
            softcap=cfg.attn_logit_softcap,
        )
        cache = LayerKVCache(k=ck, v=cv, pos=pos, length=cache.length + 1)
        y = jnp.einsum("bshd,hde->bse", out, params["wo"])
        return y, cache

    cache = cache_write(cache, k_new, v_new)
    mask = valid_mask(cache, window, start_pos)  # (Sc,) or (B, Sc)

    B, _, H, D = q.shape
    Kv = cache.k.shape[2]
    group = H // Kv
    kk = jnp.repeat(cache.k, group, axis=2)  # (B, Sc, H, D)
    vv = jnp.repeat(cache.v, group, axis=2)
    logits = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (D ** -0.5)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    if mask.ndim == 2:  # per-sample (B, Sc)
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    else:
        logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vv.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"])
    return y, cache
