"""Model configuration covering every assigned architecture family.

A model is described by a *layer pattern*: the repeating unit of
(mixer, mlp) kinds. ``num_layers`` must be a multiple of the pattern
length; the stack is ``lax.scan``-ned over ``num_layers / len(pattern)``
super-blocks with weights stacked on a leading repeat axis (keeps HLO
size and compile time independent of depth — DESIGN.md §5).

Mixer kinds:  "A" global causal attention · "L" sliding-window attention
              · "X" cross-attention (VLM image layers) · "M" Mamba2 SSD
MLP kinds:    "D" dense MLP · "E" mixture-of-experts · "N" none
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ffn: int
    num_shared_experts: int = 0
    shared_ffn: int = 0  # hidden width of the fused shared-expert MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # §Perf lever: physical expert count padded up so the expert axis
    # divides the model mesh axis (e.g. granite 40 → 48 over 16 chips).
    # Padded experts get −inf router logits and are never selected; only
    # the weight tensors grow. 0 → no padding.
    padded_experts: int = 0

    @property
    def physical_experts(self) -> int:
        return self.padded_experts or self.num_experts


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        di = self.d_inner(d_model)
        assert di % self.head_dim == 0
        return di // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads

    # layer pattern (repeating unit)
    mixer_pattern: Tuple[str, ...] = ("A",)
    mlp_pattern: Tuple[str, ...] = ("D",)

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 4096  # used by "L" mixers
    attn_logit_softcap: float = 0.0

    # norms / activations
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_np (non-parametric)
    act: str = "silu"
    glu: bool = True

    moe: Optional[MoEConfig] = None
    moe_dispatch: str = "einsum"  # "einsum" (GShard baseline) | "gather" (§Perf)
    mamba: Optional[MambaConfig] = None
    # §Perf lever: mesh axis to shard attention *query positions* over when
    # the head count doesn't divide the model axis (e.g. granite's 24 heads
    # vs model=16, which otherwise replicates the O(S²) score compute).
    # None = let GSPMD decide. Requires an ambient mesh with this axis.
    attn_q_seq_shard: Optional[str] = None
    # §Perf lever: keep the residual stream sequence-sharded over this mesh
    # axis between blocks (full sequence parallelism) — converts the
    # tensor-parallel partial-sum all-reduces into reduce-scatters.
    residual_seq_shard: Optional[str] = None
    # §Perf lever: mesh axis for distributed flash-decode when the KV cache
    # is sequence-sharded (kv_heads don't divide "model"). Replaces GSPMD's
    # per-token full-cache all-gather with O(B·H·Dh) partial-softmax psums
    # (repro.parallel.collectives.flash_decode). Needs an ambient mesh.
    decode_flash_shard: Optional[str] = None

    # VLM (cross-attention) frontend stub
    vision_dim: int = 0
    num_patches: int = 0

    # audio (codebook) frontend stub
    num_codebooks: int = 1

    tie_embeddings: bool = False
    dtype: str = "float32"  # param/compute dtype ("bfloat16" for dry-run)

    # citation of the source model card / paper for this config
    source: str = ""

    def __post_init__(self):
        assert len(self.mixer_pattern) == len(self.mlp_pattern), self.name
        assert self.num_layers % len(self.mixer_pattern) == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"pattern length {len(self.mixer_pattern)}"
        )
        if self.head_dim == 0:
            assert self.num_heads > 0
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if any(m == "E" for m in self.mlp_pattern):
            assert self.moe is not None, self.name
        if any(m == "M" for m in self.mixer_pattern):
            assert self.mamba is not None, self.name
        if any(m == "X" for m in self.mixer_pattern):
            assert self.vision_dim > 0 and self.num_patches > 0, self.name

    @property
    def num_repeats(self) -> int:
        return self.num_layers // len(self.mixer_pattern)

    @property
    def uses_attention(self) -> bool:
        return any(m in ("A", "L", "X") for m in self.mixer_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no mixer needs an unbounded KV cache ("A"/"X" absent
        or bounded): SSM-only and local-attention-only stacks qualify."""
        return all(m in ("M", "L") for m in self.mixer_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def scaled_down(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests:
        pattern preserved, ≤2 pattern repeats, d_model ≤ 256, ≤4 experts."""
        period = len(self.mixer_pattern)
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        while num_heads % num_kv:
            num_kv -= 1
        head_dim = max(8, d_model // num_heads)
        kw = dict(
            num_layers=period,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16),
            dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_ffn=min(self.moe.expert_ffn, 64),
                shared_ffn=min(self.moe.shared_ffn, 64) if self.moe.shared_ffn else 0,
            )
        if self.mamba is not None:
            kw["mamba"] = dataclasses.replace(
                self.mamba, d_state=min(self.mamba.d_state, 32), head_dim=32
            )
        if self.vision_dim:
            kw["vision_dim"] = min(self.vision_dim, 64)
            kw["num_patches"] = min(self.num_patches, 16)
        return self.replace(**kw)
