"""Diffusion language modeling: the assigned transformer backbones as
score networks over token-embedding space (`--mode diffusion`).

This is the §Arch-applicability integration (DESIGN.md §4): the paper's
adaptive SDE solver accelerates *score-based generation*; autoregressive
decoding has no reverse diffusion to solve, but any backbone from the
zoo can instead denoise a whole sequence of continuous token embeddings
(Diffusion-LM, Li et al. 2022; SSD-LM; SEDD-style setups), and then the
paper's solver applies verbatim — per-sample adaptive step sizes
included.

Construction:
  * tokens → frozen-at-init embedding table E (V, D_e), unit-norm rows;
  * forward process: VP diffusion on the (B, S, D_e) embedding tensor;
  * score net: the configured backbone run NON-causally (pattern "A"
    mixers attend bidirectionally) with a time-conditioning vector added
    to every position, predicting the noise;
  * decoding: nearest-embedding rounding (argmax E·x̂₀).

The backbone reuses repro.models.transformer's blocks unchanged — what
changes is only the head (noise prediction instead of logits) and the
causal mask (off).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import _ref_attention, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp, apply_norm, dense_init, init_mlp, init_norm,
    timestep_embedding,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiffusionLMConfig:
    backbone: ModelConfig      # any dense-family zoo config (reduced or full)
    embed_dim: int = 64        # continuous token-embedding dimension
    t_dim: int = 128

    def __post_init__(self):
        assert all(m in ("A", "L") for m in self.backbone.mixer_pattern), (
            "diffusion-LM backbones use self-attention mixers (the solver "
            "is inapplicable to AR decode, not to the architecture)"
        )


def init_diffusion_lm(cfg: DiffusionLMConfig, key: Array) -> Dict[str, Any]:
    bb = cfg.backbone
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(bb.dtype)
    # frozen unit-norm token embedding (the "vocabulary geometry")
    emb = jax.random.normal(ks[0], (bb.vocab_size, cfg.embed_dim), jnp.float32)
    emb = emb / jnp.linalg.norm(emb, axis=1, keepdims=True)

    R = bb.num_repeats

    def init_layer(k):
        ka, km, kn = jax.random.split(k, 3)
        return {
            "attn": init_attention(ka, bb, "A"),
            "mlp": init_mlp(km, bb.d_model, bb.d_ff, bb.glu, dtype),
            "norm1": init_norm(kn, bb.d_model, bb.norm_type, dtype),
            "norm2": init_norm(kn, bb.d_model, bb.norm_type, dtype),
        }

    layers = jax.vmap(init_layer)(jax.random.split(ks[1], R))
    return {
        "token_embed": emb.astype(dtype),  # frozen (stop-gradient in loss)
        "in_proj": dense_init(ks[2], (cfg.embed_dim, bb.d_model), dtype),
        "t_w1": dense_init(ks[3], (cfg.t_dim, bb.d_model), dtype),
        "t_w2": dense_init(ks[4], (bb.d_model, bb.d_model), dtype),
        "layers": layers,
        "final_norm": init_norm(ks[5], bb.d_model, bb.norm_type, dtype),
        "out_proj": jnp.zeros((bb.d_model, cfg.embed_dim), dtype),
    }


def diffusion_lm_forward(params, x: Array, t: Array,
                         cfg: DiffusionLMConfig) -> Array:
    """x (B, S, D_e) noisy embeddings, t (B,) → noise prediction."""
    bb = cfg.backbone
    h = x @ params["in_proj"]
    temb = timestep_embedding(t, cfg.t_dim).astype(h.dtype)
    temb = jax.nn.silu(temb @ params["t_w1"]) @ params["t_w2"]
    h = h + temb[:, None, :]

    def layer(h, lp):
        hn = apply_norm(lp["norm1"], h, bb.norm_type)
        q = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wq"])
        k = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wk"])
        v = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wv"])
        att = _ref_attention(q, k, v, causal=False, window=None, softcap=0.0)
        h = h + jnp.einsum("bshd,hde->bse", att, lp["attn"]["wo"])
        hn = apply_norm(lp["norm2"], h, bb.norm_type)
        h = h + apply_mlp(lp["mlp"], hn, bb.act, bb.glu)
        return h, None

    h, _ = jax.lax.scan(layer, h, params["layers"])
    h = apply_norm(params["final_norm"], h, bb.norm_type)
    return h @ params["out_proj"]


def embed(params, tokens: Array) -> Array:
    return jnp.take(jax.lax.stop_gradient(params["token_embed"]), tokens, axis=0)


def round_to_tokens(params, x0_hat: Array) -> Array:
    """Nearest-embedding decoding: argmax over E · x̂₀."""
    sims = jnp.einsum("bsd,vd->bsv", x0_hat, params["token_embed"])
    return jnp.argmax(sims, axis=-1).astype(jnp.int32)


def make_score_fn(params, cfg: DiffusionLMConfig, sde):
    def score(x: Array, t: Array) -> Array:
        _, std = sde.marginal(t)
        return -diffusion_lm_forward(params, x, t, cfg) / std.reshape(-1, 1, 1)

    return score


def diffusion_lm_loss(params, cfg: DiffusionLMConfig, sde, tokens: Array,
                      key: Array) -> Array:
    """DSM on embeddings (paper Eq. 3 in the embedding space)."""
    x0 = embed(params, tokens)
    kt, kz = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.uniform(kt, (B,), minval=sde.t_eps, maxval=sde.T)
    z = jax.random.normal(kz, x0.shape, x0.dtype)
    xt = sde.perturb(x0, t, z)
    pred = diffusion_lm_forward(params, xt, t, cfg)
    return 0.5 * jnp.mean(jnp.sum((pred - z) ** 2, axis=-1))


def generate(params, cfg: DiffusionLMConfig, sde, batch: int, seq: int,
             key: Array, *, method: str = "adaptive", **solver_kw):
    """Sample token sequences via the paper's solver; returns
    (tokens (B, S), SolveResult)."""
    from repro.core.sampling import sample as _sample

    score = make_score_fn(params, cfg, sde)
    res = _sample(sde, score, (batch, seq, cfg.embed_dim), key,
                  method=method, **solver_kw)
    return round_to_tokens(params, res.x), res
