"""DiT — transformer score network over image patches (adaLN conditioning).

This is how the paper's technique becomes a first-class feature of the
LM framework (DESIGN.md §4): any dense ``ModelConfig`` doubles as the
backbone of a time-conditioned score network. Patchified image tokens
run through the same attention/MLP blocks (non-causal), modulated per
block by adaLN(t). ``score_apply`` exposes the s(x, t) signature every
solver in ``repro.core`` consumes.

Precision (DESIGN.md §8): pass ``policy=`` (a
``repro.core.precision.PrecisionPolicy``) to run activations — and the
weight copies the matmuls consume — in the policy's compute dtype. The
timestep-embedding MLP always computes in fp32 from the stored (master)
weights, and the norms upcast internally (``apply_norm``), so the
conditioning path keeps full precision while the O(L·D²) block math
runs reduced. ``make_score_fn(..., policy=...)`` additionally stores
weights at ``param_dtype`` and returns the score in ``state_dtype``
with the 1/std rescale done in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    dense_init,
    init_mlp,
    init_norm,
    rope,
    timestep_embedding,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    image_size: int = 32
    channels: int = 3
    patch: int = 4
    d_model: int = 256
    num_layers: int = 6
    num_heads: int = 8
    d_ff: int = 1024
    dtype: str = "float32"
    #: class-conditional mode (DESIGN.md §9): > 0 adds a label-embedding
    #: table with one extra null row (classifier-free training style);
    #: 0 (the default) leaves params and forward bit-identical to the
    #: unconditional net.
    num_classes: int = 0
    #: route the block attention through the Pallas flash kernel
    #: (DESIGN.md §13). ``False`` (the default) is bit-identical to the
    #: reference-attention stack; ``True`` agrees to fp32-accumulation
    #: tolerance per precision preset (gated by
    #: ``tests/test_score_hotpath.py``).
    use_flash: bool = False

    @property
    def tokens(self) -> int:
        return (self.image_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    def as_model_config(self) -> ModelConfig:
        return ModelConfig(
            name="dit-backbone",
            arch_type="dense",
            num_layers=self.num_layers,
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_heads,
            d_ff=self.d_ff,
            vocab_size=8,  # unused
            dtype=self.dtype,
        )


def init_dit(cfg: DiTConfig, key: Array) -> Dict[str, Any]:
    mcfg = cfg.as_model_config()
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    R = cfg.num_layers

    def init_layer(k):
        ka, km, kc = jax.random.split(k, 3)
        return {
            "attn": init_attention(ka, mcfg, "A"),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, True, dtype),
            "norm1": init_norm(kc, cfg.d_model, "layernorm_np", dtype),
            "norm2": init_norm(kc, cfg.d_model, "layernorm_np", dtype),
            # adaLN: 6 modulation vectors from the time embedding
            "ada": jnp.zeros((cfg.d_model, 6 * cfg.d_model), dtype),
            "ada_b": jnp.zeros((6 * cfg.d_model,), dtype),
        }

    layers = jax.vmap(init_layer)(jax.random.split(ks[0], R))
    extra = {}
    if cfg.num_classes > 0:
        # one embedding row per class + a trailing null row (index
        # num_classes) for the unconditional branch of CFG sampling
        extra["label_emb"] = 0.02 * jax.random.normal(
            ks[6], (cfg.num_classes + 1, cfg.d_model), jnp.float32
        ).astype(dtype)
    return {
        **extra,
        "patch_in": dense_init(ks[1], (cfg.patch_dim, cfg.d_model), dtype),
        "pos_emb": 0.02 * jax.random.normal(ks[2], (cfg.tokens, cfg.d_model), jnp.float32).astype(dtype),
        "t_mlp1": dense_init(ks[3], (256, cfg.d_model), dtype),
        "t_mlp2": dense_init(ks[4], (cfg.d_model, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": init_norm(ks[5], cfg.d_model, "layernorm_np", dtype),
        "final_ada": jnp.zeros((cfg.d_model, 2 * cfg.d_model), dtype),
        "final_ada_b": jnp.zeros((2 * cfg.d_model,), dtype),
        "patch_out": jnp.zeros((cfg.d_model, cfg.patch_dim), dtype),
    }


def _patchify(x: Array, cfg: DiTConfig) -> Array:
    B, H, W, C = x.shape
    p = cfg.patch
    x = x.reshape(B, H // p, p, W // p, p, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, cfg.tokens, cfg.patch_dim)


def _unpatchify(t: Array, cfg: DiTConfig) -> Array:
    B = t.shape[0]
    p = cfg.patch
    n = cfg.image_size // p
    t = t.reshape(B, n, n, p, p, cfg.channels)
    return t.transpose(0, 1, 3, 2, 4, 5).reshape(
        B, cfg.image_size, cfg.image_size, cfg.channels
    )


def dit_forward(params: Dict[str, Any], x: Array, t: Array, cfg: DiTConfig,
                policy=None, y: Array | None = None) -> Array:
    """x (B, H, W, C), t (B,) → same-shape output (raw network output).

    With ``policy`` the activations (and the weight copies the matmuls
    consume) run in ``policy.compute``; the timestep-embedding math is
    fp32 from the stored weights, and ``apply_norm`` upcasts internally,
    so only the block matmuls/attention run reduced. The output is in
    the compute dtype; ``make_score_fn`` handles the downstream cast.

    ``y`` (DESIGN.md §9): optional int32 (B,) class labels for a
    class-conditional net (``cfg.num_classes > 0``); negative labels
    select the trailing null row (the unconditional branch of CFG).
    The label embedding joins the conditioning path, so like the
    timestep embedding it is added in fp32 from the stored weights.
    """
    mcfg = cfg.as_model_config()
    # fp32 timestep-embedding math from the stored (master) weights,
    # before any compute-dtype cast touches the tree
    f32 = lambda w: w.astype(jnp.float32)
    temb = timestep_embedding(t, 256)  # fp32
    temb = jax.nn.silu(temb @ f32(params["t_mlp1"])) @ f32(params["t_mlp2"])
    if y is not None and cfg.num_classes > 0:
        idx = jnp.where(y < 0, cfg.num_classes, y).astype(jnp.int32)
        temb = temb + f32(params["label_emb"])[idx]

    if policy is not None:
        x = x.astype(policy.compute)
        params = policy.params_for_compute(params)
    h = _patchify(x, cfg) @ params["patch_in"] + params["pos_emb"]
    temb = temb.astype(h.dtype)  # (B, D)

    def layer(h, lp):
        mod = jax.nn.silu(temb) @ lp["ada"] + lp["ada_b"]  # (B, 6D)
        s1, b1, g1, s2, b2, g2 = jnp.split(mod[:, None, :], 6, axis=-1)
        hn = apply_norm(lp["norm1"], h, "layernorm_np") * (1 + s1) + b1
        q = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wq"])
        k = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wk"])
        v = jnp.einsum("bse,ehd->bshd", hn, lp["attn"]["wv"])
        att = attention(q, k, v, causal=False, window=None, softcap=0.0,
                        use_flash=cfg.use_flash)
        h = h + g1 * jnp.einsum("bshd,hde->bse", att, lp["attn"]["wo"])
        hn = apply_norm(lp["norm2"], h, "layernorm_np") * (1 + s2) + b2
        h = h + g2 * apply_mlp(lp["mlp"], hn, "silu", True)
        return h, None

    h, _ = jax.lax.scan(layer, h, params["layers"])
    mod = jax.nn.silu(temb) @ params["final_ada"] + params["final_ada_b"]
    s, b = jnp.split(mod[:, None, :], 2, axis=-1)
    h = apply_norm(params["final_norm"], h, "layernorm_np") * (1 + s) + b
    return _unpatchify(h @ params["patch_out"], cfg)


def make_score_fn(params, cfg: DiTConfig, sde, policy=None,
                  conditioner=None, cond=None):
    """Wrap the raw net into s(x,t) = net(x,t)/std(t) (noise-pred param.).

    With ``policy``: weights are stored at ``param_dtype``, x casts to
    ``compute_dtype`` on entry, the 1/std rescale runs in fp32 (std can
    be O(1e-2) for VE — dividing in bf16 would waste the score's
    mantissa), and the returned score is in ``state_dtype``.

    When ``cfg.num_classes > 0`` the returned score is label-aware —
    ``s(x, t, y)`` with ``y`` optional — which is the signature a
    ``ClassifierFree`` conditioner consumes (DESIGN.md §9).

    ``conditioner``/``cond`` (DESIGN.md §9) bake a *static* payload
    into the returned field (standalone/whole-batch use: fixed labels,
    one mask for the run). The solver/serving path instead threads the
    payload through ``SolverCarry.cond`` and wraps per-chunk — do not
    pass a conditioner here *and* in ``AdaptiveConfig``, that would
    apply the transform twice.
    """
    if policy is not None:
        params = policy.cast_params(params)

    def score(x: Array, t: Array, y: Array | None = None) -> Array:
        _, std = sde.marginal(t)
        if policy is not None:
            x = policy.to_compute(x)
        out = dit_forward(params, x, t, cfg, policy=policy, y=y)
        s = -out.astype(jnp.float32) / std.reshape((-1,) + (1,) * (x.ndim - 1))
        return s if policy is None else policy.to_state(s)

    if conditioner is not None:
        return conditioner.wrap_score(score, cond)
    return score
