"""KV cache and SSM state containers for single-token decode.

Caches are functional pytrees. Ring-buffer semantics support
sliding-window layers: slot = position mod cache_len, and a ``pos``
array records which absolute position each slot currently holds so the
attention mask is exact even after wrap-around. A full-length cache is
just the special case cache_len ≥ max positions (no wrap).

Batch elements decode in lockstep (one new token for all), so ``pos``
is shared across the batch: shape (cache_len,), −1 = empty.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayerKVCache:
    k: Array    # (B, S_cache, Kv, Dh)
    v: Array    # (B, S_cache, Kv, Dh)
    pos: Array  # (S_cache,) absolute position held by each slot, -1 empty
    length: Array  # () int32 — number of tokens seen so far


def init_kv_cache(batch: int, cache_len: int, kv_heads: int, head_dim: int, dtype) -> LayerKVCache:
    return LayerKVCache(
        k=jnp.zeros((batch, cache_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, cache_len, kv_heads, head_dim), dtype),
        pos=jnp.full((cache_len,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def cache_write(cache: LayerKVCache, k_new: Array, v_new: Array) -> LayerKVCache:
    """Write one token's k/v (B, 1, Kv, Dh) at slot = length mod cache_len."""
    S = cache.k.shape[1]
    slot = (cache.length % S).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache.pos, cache.length[None], (slot,))
    return LayerKVCache(k=k, v=v, pos=pos, length=cache.length + 1)


def valid_mask(cache: LayerKVCache, window: int | None,
               start_pos: Array | None = None) -> Array:
    """Visibility of cache slots to the current (just-written) token.

    Returns (S_cache,) bool, or (B, S_cache) when ``start_pos`` (B,) is
    given — continuous-batching isolation: each batch lane only sees
    positions ≥ its own request's start (repro.serving.scheduler)."""
    cur = cache.length - 1  # position of the newest token
    m = jnp.logical_and(cache.pos >= 0, cache.pos <= cur)
    if window is not None:
        m = jnp.logical_and(m, cache.pos > cur - window)
    if start_pos is not None:
        m = jnp.logical_and(m[None, :], cache.pos[None, :] >= start_pos[:, None])
    return m


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MambaState:
    conv: Array  # (B, conv_width-1, channels) rolling conv inputs
    ssm: Array   # (B, H, N, P) fp32 recurrent state


def init_mamba_state(batch: int, conv_width: int, channels: int, heads: int,
                     d_state: int, head_dim: int, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, conv_width - 1, channels), dtype),
        ssm=jnp.zeros((batch, heads, d_state, head_dim), jnp.float32),
    )
