"""Shared primitive layers: norms, rotary embeddings, MLPs, initializers.

Parameters are plain dict pytrees; every ``init_*`` returns a dict and
every ``apply_*`` is a pure function. Compute follows the config dtype;
norms and softmax always accumulate in fp32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key: Array, shape, dtype, *, fan_in: Optional[int] = None) -> Array:
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[0] default)."""
    fan = fan_in if fan_in is not None else shape[0]
    std = fan ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(key: Array, dim: int, norm_type: str, dtype) -> dict:
    del key
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if norm_type == "layernorm_np":  # non-parametric (OLMo)
        return {}
    raise ValueError(norm_type)


def apply_norm(params: dict, x: Array, norm_type: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    elif norm_type in ("layernorm", "layernorm_np"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if norm_type == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(norm_type)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """Apply rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (optionally gated)
# --------------------------------------------------------------------------

def init_mlp(key: Array, d_model: int, d_ff: int, glu: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype),
    }
    if glu:
        p["w_gate"] = dense_init(k3, (d_model, d_ff), dtype)
    return p


def _act(x: Array, name: str) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def apply_mlp(params: dict, x: Array, act: str, glu: bool) -> Array:
    h = x @ params["w_in"]
    if glu:
        h = _act(x @ params["w_gate"], act) * h
    else:
        h = _act(h, act)
    return h @ params["w_out"]


# --------------------------------------------------------------------------
# time embedding (diffusion score networks)
# --------------------------------------------------------------------------

def timestep_embedding(t: Array, dim: int, max_period: float = 10_000.0) -> Array:
    """Sinusoidal embedding of continuous t ∈ [0, 1]; shape (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :] * 1000.0
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb
