"""Mamba2 (SSD) mixer layer — used by mamba2-2.7b and jamba's "M" layers.

Follows arXiv:2405.21060 with one sharding-motivated deviation
(DESIGN.md §5): the fused ``in_proj`` is split into separate z/x/B/C/dt
projections so the tensor-parallel "model" axis can shard z and x on
head boundaries while the small B/C/dt projections stay replicated. The
math is identical to the fused projection.

Sequence mixing runs through the chunked SSD scan
(``repro.kernels.ssd``: Pallas on TPU, the same chunked math in pure jnp
otherwise), preceded by short causal depthwise convolutions on x, B, C.
Decode keeps a (conv, ssm) recurrent state — O(1) per token, which is
why the SSM archs run long_500k natively.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.kvcache import MambaState
from repro.models.layers import apply_norm, dense_init

Array = jax.Array


def init_mamba(key: Array, cfg: ModelConfig) -> dict:
    mc = cfg.mamba
    E = cfg.d_model
    di = mc.d_inner(E)
    H = mc.num_heads(E)
    G, N, W = mc.n_groups, mc.d_state, mc.conv_width
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default).
    u = jax.random.uniform(ks[0], (H,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_z": dense_init(ks[1], (E, di), dtype),
        "in_x": dense_init(ks[2], (E, di), dtype),
        "in_B": dense_init(ks[3], (E, G * N), dtype),
        "in_C": dense_init(ks[4], (E, G * N), dtype),
        "in_dt": dense_init(ks[5], (E, H), dtype),
        "conv_x": dense_init(ks[6], (W, di), dtype, fan_in=W),
        "conv_B": dense_init(ks[7], (W, G * N), dtype, fan_in=W),
        "conv_C": dense_init(ks[8], (W, G * N), dtype, fan_in=W),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out": dense_init(ks[9], (di, E), dtype),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv along sequence. x (B,S,C), w (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out


def _conv_step(state: Array, x_new: Array, w: Array) -> Tuple[Array, Array]:
    """Single-token conv. state (B, W-1, C), x_new (B, C)."""
    full = jnp.concatenate([state, x_new[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)
    return full[:, 1:, :], y


def _project(params: dict, x: Array, cfg: ModelConfig):
    mc = cfg.mamba
    H = mc.num_heads(cfg.d_model)
    z = x @ params["in_z"]
    xs = x @ params["in_x"]
    Bm = x @ params["in_B"]
    C = x @ params["in_C"]
    dt_raw = x @ params["in_dt"]  # (..., H)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    return z, xs, Bm, C, dt


def mamba_forward(
    params: dict, x: Array, cfg: ModelConfig, *, use_pallas: bool = False
) -> Array:
    """Training/prefill. x: (B, S, E) → (B, S, E)."""
    mc = cfg.mamba
    B, S, E = x.shape
    di = mc.d_inner(E)
    H, P, G, N = mc.num_heads(E), mc.head_dim, mc.n_groups, mc.d_state

    z, xs, Bm, C, dt = _project(params, x, cfg)
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, params["conv_B"]))
    C = jax.nn.silu(_causal_conv(C, params["conv_C"]))

    xh = xs.reshape(B, S, H, P)
    Bh = Bm.reshape(B, S, G, N)
    Ch = C.reshape(B, S, G, N)
    A = -jnp.exp(params["A_log"])  # (H,) < 0

    from repro.kernels.ssd import ops as ssd_ops
    from repro.kernels.ssd import ref as ssd_ref

    if use_pallas:
        y = ssd_ops.ssd_scan(xh, dt, A, Bh, Ch)
    else:
        # chunked jnp SSD: parallel over chunks + log-depth cross-chunk
        # scan — the production non-Pallas path (identical math).
        y = ssd_ref.ssd_chunked(xh, dt, A, Bh, Ch)

    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(x.dtype)  # D is fp32; restore compute dtype
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ params["out"]


def mamba_decode(
    params: dict, x: Array, cfg: ModelConfig, state: MambaState
) -> Tuple[Array, MambaState]:
    """Single-token decode. x: (B, 1, E) → ((B, 1, E), state')."""
    mc = cfg.mamba
    B, _, E = x.shape
    di = mc.d_inner(E)
    H, P, G, N = mc.num_heads(E), mc.head_dim, mc.n_groups, mc.d_state

    z, xs, Bm, C, dt = _project(params, x[:, 0, :], cfg)
    ch = jnp.concatenate([xs, Bm, C], axis=-1)  # (B, di + 2GN)
    conv_w = jnp.concatenate(
        [params["conv_x"], params["conv_B"], params["conv_C"]], axis=1
    )
    conv_state, conv_out = _conv_step(state.conv, ch, conv_w)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, C = jnp.split(conv_out, [di, di + G * N], axis=-1)

    xh = xs.reshape(B, H, P)
    Bh = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)  # (B, H, N)
    Ch = jnp.repeat(C.reshape(B, G, N), H // G, axis=1)
    A = -jnp.exp(params["A_log"])

    a = jnp.exp(dt * A)  # (B, H)
    ssm = state.ssm * a[..., None, None] + (
        (dt * 1.0)[..., None, None]
        * Bh[..., :, None].astype(jnp.float32)
        * xh[..., None, :].astype(jnp.float32)
    )  # (B, H, N, P)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), ssm)
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = (y @ params["out"])[:, None, :]
    return out, MambaState(conv=conv_state, ssm=ssm)


def init_mamba_decode_state(cfg: ModelConfig, batch: int) -> MambaState:
    mc = cfg.mamba
    E = cfg.d_model
    di = mc.d_inner(E)
    H, N, P = mc.num_heads(E), mc.d_state, mc.head_dim
    channels = di + 2 * mc.n_groups * N
    from repro.models.kvcache import init_mamba_state

    return init_mamba_state(
        batch, mc.conv_width, channels, H, N, P, jnp.dtype(cfg.dtype)
    )
