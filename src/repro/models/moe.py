"""Mixture-of-experts MLP with group-limited capacity dispatch (GShard-style).

Covers the assigned MoE archetypes:
  * deepseek-moe-16b — fine-grained: 64 routed experts (top-6) + 2 shared
    experts always active (fused into one wider MLP);
  * granite-moe-3b   — 40 routed experts (top-8), no shared;
  * jamba            — 16 routed experts (top-2) on alternating layers.

Dispatch: tokens are split into fixed groups of ``group_size`` (the
classic GShard/Switch trick that keeps the (tokens, experts, capacity)
dispatch tensor O(T·g) instead of O(T²)); within each group every token
scores every expert, top-k gates are renormalized, and tokens take slots
up to capacity C = ceil(k·g/X · capacity_factor). Overflow tokens fall
through to the shared path (or identity). Under expert sharding the
dispatch einsum lowers to an all-to-all — exactly the collective the
roofline analysis needs to see.

Router math is fp32; the Switch load-balance aux loss is returned for
the training loop.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import _act, dense_init

Array = jax.Array

DEFAULT_GROUP = 512


def init_moe(key: Array, cfg: ModelConfig) -> dict:
    mc = cfg.moe
    E, F, X = cfg.d_model, mc.expert_ffn, mc.physical_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (E, X), jnp.float32),
        "w_in": dense_init(ks[1], (X, E, F), dtype, fan_in=E),
        "w_gate": dense_init(ks[2], (X, E, F), dtype, fan_in=E),
        "w_out": dense_init(ks[3], (X, F, E), dtype, fan_in=F),
    }
    if mc.num_shared_experts:
        Fs = mc.shared_ffn or mc.num_shared_experts * F
        p["shared"] = {
            "w_in": dense_init(ks[4], (E, Fs), dtype),
            "w_gate": dense_init(ks[5], (E, Fs), dtype),
            "w_out": dense_init(ks[6], (Fs, E), dtype),
        }
    return p


def _capacity(group: int, mc: MoEConfig) -> int:
    return max(int(math.ceil(mc.top_k * group / mc.num_experts * mc.capacity_factor)), 1)


def _route_common(xg: Array, params: dict, cfg: ModelConfig, C: int):
    """Router + slot assignment shared by both dispatch backends."""
    mc = cfg.moe
    g = xg.shape[0]
    X, k = mc.num_experts, mc.top_k

    logits = xg.astype(jnp.float32) @ params["router"]  # (g, X_phys)
    if mc.physical_experts > X:
        # padded experts (sharding alignment) are never routable
        pad = jnp.full((g, mc.physical_experts - X), -1e9, jnp.float32)
        logits = jnp.concatenate([logits[:, :X], pad], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    Xp = mc.physical_experts
    onehot = jax.nn.one_hot(expert_idx, Xp, dtype=jnp.float32)  # (g, k, Xp)
    # fraction of routing decisions to each expert (normalized by k so a
    # perfectly balanced router scores exactly 1.0 before weighting)
    fraction = jnp.mean(jnp.sum(onehot, axis=1), axis=0)[:X] / k
    aux = X * jnp.sum(fraction * jnp.mean(probs[:, :X], axis=0))

    # Slot positions: rank-major priority (all rank-0 choices first).
    oh_flat = onehot.transpose(1, 0, 2).reshape(k * g, Xp)  # rank-major
    pos = jnp.sum(jnp.cumsum(oh_flat, axis=0) * oh_flat, axis=-1) - 1  # (kg,)
    pos = pos.astype(jnp.int32)
    keep = pos < C
    return gate_vals, expert_idx, onehot, pos, keep, aux


def _expert_ffn(expert_in: Array, params: dict, cfg: ModelConfig) -> Array:
    h = jnp.einsum("xce,xef->xcf", expert_in, params["w_in"])
    gt = jnp.einsum("xce,xef->xcf", expert_in, params["w_gate"])
    return jnp.einsum("xcf,xfe->xce", _act(gt, cfg.act) * h, params["w_out"])


def _route_group(xg: Array, params: dict, cfg: ModelConfig, C: int):
    """One group, one-hot einsum dispatch (GShard-faithful baseline).

    The (t, X, C) one-hot contractions cost 2·g·X·C·E FLOPs each — for
    fine-grained MoE (granite: X=40, C≈128) that is ~100× the expert FFN
    FLOPs. Kept as the baseline; see `_route_group_gather` (§Perf)."""
    gate_vals, expert_idx, onehot, pos, keep, aux = _route_common(
        xg, params, cfg, C
    )
    g = xg.shape[0]
    k = cfg.moe.top_k
    slot_oh = jax.nn.one_hot(pos, C, dtype=xg.dtype) * keep[:, None].astype(xg.dtype)
    slot_oh = slot_oh.reshape(k, g, C).transpose(1, 0, 2)  # (g, k, C)

    disp = jnp.einsum("tkx,tkc->txc", onehot.astype(xg.dtype), slot_oh)  # (g,X,C)
    combine = jnp.einsum("tkx,tkc,tk->txc", onehot.astype(xg.dtype), slot_oh,
                         gate_vals.astype(xg.dtype))

    expert_in = jnp.einsum("txc,te->xce", disp, xg)  # (X, C, E)
    expert_out = _expert_ffn(expert_in, params, cfg)  # (X, C, E)
    yg = jnp.einsum("txc,xce->te", combine, expert_out)  # (g, E)
    return yg, aux


def _route_group_gather(xg: Array, params: dict, cfg: ModelConfig, C: int):
    """One group, gather/scatter dispatch (beyond-paper, §Perf).

    Replaces the O(g·X·C·E) one-hot matmuls with zero-FLOP data movement:
    a scatter builds the (X, C) slot→token index table, a gather feeds
    the experts, and the combine gathers each token's k slot outputs.
    Identical numerics to `_route_group` (validated in tests)."""
    mc = cfg.moe
    g = xg.shape[0]
    X, k = mc.physical_experts, mc.top_k
    gate_vals, expert_idx, _, pos, keep, aux = _route_common(xg, params, cfg, C)

    flat_expert = expert_idx.transpose(1, 0).reshape(k * g)  # rank-major
    token_of = jnp.tile(jnp.arange(g, dtype=jnp.int32), k)
    pos_c = jnp.where(keep, pos, C)  # overflow rows land in a dump slot

    # slot → token table, scatter once per (expert, slot)
    table = jnp.full((X, C + 1), g, jnp.int32)  # g = "no token" sentinel
    table = table.at[flat_expert, pos_c].set(token_of, mode="drop")
    xg_pad = jnp.concatenate([xg, jnp.zeros((1, xg.shape[1]), xg.dtype)])
    expert_in = xg_pad[table[:, :C]]  # (X, C, E) gather — no FLOPs

    expert_out = _expert_ffn(expert_in, params, cfg)  # (X, C, E)

    # combine: token t, rank r reads expert_out[e_r(t), pos_r(t)]
    out_pad = jnp.concatenate(
        [expert_out.reshape(X * C, -1),
         jnp.zeros((1, expert_out.shape[-1]), expert_out.dtype)]
    )
    flat_slot = jnp.where(keep, flat_expert * C + pos_c, X * C)
    picked = out_pad[flat_slot].reshape(k, g, -1)  # (k, g, E)
    gates = gate_vals.transpose(1, 0)[..., None].astype(xg.dtype)  # (k, g, 1)
    yg = jnp.sum(picked * gates, axis=0)  # (g, E)
    return yg, aux


def apply_moe(
    params: dict, x: Array, cfg: ModelConfig, *, group_size: int = DEFAULT_GROUP,
    dispatch: str = "einsum",  # "einsum" (GShard baseline) | "gather" (§Perf)
) -> Tuple[Array, Array]:
    """x: (B, S, E) → (y, aux_loss)."""
    mc = cfg.moe
    B, S, E = x.shape
    T = B * S
    g = min(group_size, T)
    pad = (-T) % g
    xt = x.reshape(T, E)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    n_groups = xt.shape[0] // g
    xG = xt.reshape(n_groups, g, E)

    C = _capacity(g, mc)
    route = _route_group_gather if dispatch == "gather" else _route_group
    yG, aux = jax.vmap(lambda xg: route(xg, params, cfg, C))(xG)
    yt = yG.reshape(-1, E)[:T]

    if mc.num_shared_experts:
        sh = params["shared"]
        xt_true = xt[:T]
        hs = _act(xt_true @ sh["w_gate"], cfg.act) * (xt_true @ sh["w_in"])
        yt = yt + hs @ sh["w_out"]

    return yt.reshape(B, S, E), jnp.mean(aux) * mc.router_aux_weight
