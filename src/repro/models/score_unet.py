"""Score networks in the paper's own family: a compact NCSN++-style UNet
for images, and an MLP score net for low-dimensional benchmark problems.

The UNet keeps the structural ingredients of NCSN++ (time conditioning
through every residual block, down/up path with skip connections, GroupNorm
+ SiLU) at a scale trainable on CPU for the end-to-end examples. The
output is the noise prediction; ``make_score_fn`` rescales by −1/std(t),
matching the training loss in ``repro.core.losses``.

Precision (DESIGN.md §8): both forwards accept ``policy=`` to run
activations in the policy's compute dtype. The timestep-embedding MLP
computes in fp32 from the stored weights and GroupNorm upcasts
internally (``_groupnorm``), mirroring the DiT seams.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, timestep_embedding

Array = jax.Array


# --------------------------------------------------------------------------
# MLP score net (toy distributions; exact-solver validation)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPScoreConfig:
    dim: int = 2
    hidden: int = 128
    depth: int = 3
    t_dim: int = 64


def init_mlp_score(cfg: MLPScoreConfig, key: Array) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.depth + 2)
    sizes = [cfg.dim + cfg.t_dim] + [cfg.hidden] * cfg.depth + [cfg.dim]
    layers = []
    for i in range(len(sizes) - 1):
        layers.append({
            "w": dense_init(ks[i], (sizes[i], sizes[i + 1]), jnp.float32),
            "b": jnp.zeros((sizes[i + 1],), jnp.float32),
        })
    # zero-init last layer: initial score ≈ 0 (pure prior).
    layers[-1]["w"] = jnp.zeros_like(layers[-1]["w"])
    return {"layers": layers}


def mlp_score_forward(params, x: Array, t: Array, cfg: MLPScoreConfig,
                      policy=None) -> Array:
    temb = timestep_embedding(t, cfg.t_dim)  # fp32 embedding math
    if policy is not None:
        x = x.astype(policy.compute)
        params = policy.params_for_compute(params)
    h = jnp.concatenate([x, temb.astype(x.dtype)], axis=-1)
    for i, lp in enumerate(params["layers"]):
        h = h @ lp["w"] + lp["b"]
        if i < len(params["layers"]) - 1:
            h = jax.nn.silu(h)
    return h


# --------------------------------------------------------------------------
# UNet score net (images)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UNetConfig:
    image_size: int = 32
    channels: int = 3
    base: int = 32           # base feature width
    mults: tuple = (1, 2, 2)  # per-resolution channel multipliers
    t_dim: int = 128
    groups: int = 8


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan = kh * kw * cin
    return (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32)
            * fan ** -0.5).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _groupnorm(x: Array, scale: Array, bias: Array, groups: int) -> Array:
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-6)
    return (xg.reshape(B, H, W, C) * scale + bias).astype(x.dtype)


def _init_resblock(key, cin, cout, t_dim):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "gn1_s": jnp.ones((cin,)), "gn1_b": jnp.zeros((cin,)),
        "conv1": _conv_init(k1, 3, 3, cin, cout),
        "temb_w": dense_init(k2, (t_dim, cout), jnp.float32),
        "temb_b": jnp.zeros((cout,)),
        "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
        "conv2": jnp.zeros((3, 3, cout, cout)),  # zero-init second conv
    }
    if cin != cout:
        p["skip"] = _conv_init(k3, 1, 1, cin, cout)
    return p


def _resblock(p, x, temb, groups):
    h = jax.nn.silu(_groupnorm(x, p["gn1_s"], p["gn1_b"], groups))
    h = _conv(h, p["conv1"])
    h = h + (jax.nn.silu(temb) @ p["temb_w"] + p["temb_b"])[:, None, None, :]
    h = jax.nn.silu(_groupnorm(h, p["gn2_s"], p["gn2_b"], groups))
    h = _conv(h, p["conv2"])
    skip = _conv(x, p["skip"]) if "skip" in p else x
    return skip + h


def init_unet(cfg: UNetConfig, key: Array) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 64))
    widths = [cfg.base * m for m in cfg.mults]
    p: Dict[str, Any] = {
        "t_w1": dense_init(next(ks), (cfg.t_dim, cfg.t_dim), jnp.float32),
        "t_w2": dense_init(next(ks), (cfg.t_dim, cfg.t_dim), jnp.float32),
        "conv_in": _conv_init(next(ks), 3, 3, cfg.channels, widths[0]),
    }
    cin = widths[0]
    downs = []
    for w in widths:
        downs.append({
            "res": _init_resblock(next(ks), cin, w, cfg.t_dim),
            "down": _conv_init(next(ks), 3, 3, w, w),
        })
        cin = w
    p["downs"] = downs
    p["mid1"] = _init_resblock(next(ks), cin, cin, cfg.t_dim)
    p["mid2"] = _init_resblock(next(ks), cin, cin, cfg.t_dim)
    ups = []
    for w in reversed(widths):
        ups.append({
            "up": _conv_init(next(ks), 3, 3, cin, w),
            "res": _init_resblock(next(ks), 2 * w, w, cfg.t_dim),
        })
        cin = w
    p["ups"] = ups
    p["gn_out_s"] = jnp.ones((cin,))
    p["gn_out_b"] = jnp.zeros((cin,))
    p["conv_out"] = jnp.zeros((3, 3, cin, cfg.channels))
    return p


def unet_forward(params, x: Array, t: Array, cfg: UNetConfig,
                 policy=None) -> Array:
    # fp32 timestep-embedding math from the stored (master) weights
    f32 = lambda w: w.astype(jnp.float32)
    temb = timestep_embedding(t, cfg.t_dim)
    temb = jax.nn.silu(temb @ f32(params["t_w1"])) @ f32(params["t_w2"])

    if policy is not None:
        x = x.astype(policy.compute)
        params = policy.params_for_compute(params)
        temb = temb.astype(policy.compute)

    h = _conv(x, params["conv_in"])
    skips = []
    for d in params["downs"]:
        h = _resblock(d["res"], h, temb, cfg.groups)
        skips.append(h)
        h = _conv(h, d["down"], stride=2)
    h = _resblock(params["mid1"], h, temb, cfg.groups)
    h = _resblock(params["mid2"], h, temb, cfg.groups)
    for u in params["ups"]:
        B, H, W, C = h.shape
        h = jax.image.resize(h, (B, H * 2, W * 2, C), "nearest")
        h = _conv(h, u["up"])
        h = jnp.concatenate([h, skips.pop()], axis=-1)
        h = _resblock(u["res"], h, temb, cfg.groups)
    h = jax.nn.silu(_groupnorm(h, params["gn_out_s"], params["gn_out_b"], cfg.groups))
    return _conv(h, params["conv_out"])


def make_score_fn(forward_fn, params, cfg, sde, policy=None):
    """Noise-prediction net → score: s(x,t) = −net(x,t)/std(t).

    With ``policy``: weights stored at ``param_dtype``, x cast to the
    compute dtype on entry (``forward_fn`` must accept ``policy=`` —
    both forwards in this module do), fp32 1/std rescale, score returned
    in ``state_dtype``.
    """
    if policy is not None:
        params = policy.cast_params(params)

    def score(x: Array, t: Array) -> Array:
        _, std = sde.marginal(t)
        if policy is None:
            out = forward_fn(params, x, t, cfg)
        else:
            out = forward_fn(params, policy.to_compute(x), t, cfg,
                             policy=policy)
        s = -out.astype(jnp.float32) / std.reshape(
            (-1,) + (1,) * (x.ndim - 1)
        )
        return s if policy is None else policy.to_state(s)

    return score
