"""Temporal score network for trajectory diffusion (DESIGN.md §10).

A 1-D residual conv UNet over ``(B, H, D)`` trajectories — horizon H of
transitions, each a concatenated ``[observation, action]`` vector of
width D — in the decision-diffuser / Diffuser family: time conditioning
through every residual block, down/up path over the *horizon* axis with
skip connections, GroupNorm + SiLU, noise-prediction output. This is
the third score-network workload of the repo (images: ``score_unet`` /
``dit``; token sequences: ``diffusion_lm``) and exists to exercise the
paper's claim that the adaptive solver needs no step-size tuning across
data modalities and dimensionalities: every registered solver consumes
the ``make_score_fn`` adapter below unmodified.

Returns conditioning (DESIGN.md §10): ``returns_bins > 0`` adds a
discretized returns-to-go embedding table with one trailing null row —
the classifier-free training layout — so the net's score is label-aware
``s(x, t, y)`` and a ``ClassifierFree`` conditioner (DESIGN.md §9)
drives it directly. The null row is **zero-initialized**, which makes
the null-labeled forward bit-identical to the unconditional forward
(``y=None``) — the guardrail ``tests/test_planning.py`` asserts.

Precision (DESIGN.md §8): both the forward and the adapter accept
``policy=``. The timestep-embedding MLP and the returns embedding
compute in fp32 from the stored weights, GroupNorm upcasts internally,
and ``make_score_fn`` does the 1/std rescale in fp32 — the same seams
as the image nets.

Hot path (DESIGN.md §13): ``attention=True`` adds a bottleneck
self-attention block over the horizon axis (zero-init output
projection → bitwise-neutral when fresh; ``use_flash`` routes it
through the Pallas flash kernel), and ``use_fused_norm=True`` runs
every residual block's GroupNorm→SiLU through the fused Pallas kernel
(``repro.kernels.groupnorm_silu``). All three flags default off, and
the off-state is bit-identical to the pre-flag stack.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, timestep_embedding

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TemporalUNetConfig:
    """1-D UNet over (horizon, transition) trajectories.

    ``horizon`` must be divisible by ``2 ** (len(mults) - 1)`` (one
    stride-2 downsample per extra resolution level).
    """

    horizon: int = 16
    #: transition width D = obs_dim + act_dim
    transition_dim: int = 6
    base: int = 32            # base feature width
    mults: tuple = (1, 2)     # per-resolution channel multipliers
    t_dim: int = 64
    groups: int = 8
    kernel: int = 5           # conv kernel along the horizon axis
    #: > 0 → returns-conditioned score (DESIGN.md §10): a discretized
    #: returns-to-go embedding table with one trailing zero-init null
    #: row; 0 (the default) leaves params and forward identical to the
    #: unconditional net.
    returns_bins: int = 0
    #: add a self-attention block at the bottleneck (DESIGN.md §13):
    #: the horizon axis gets a global receptive field on top of the
    #: conv stack's ~kernel·depth one. The output projection is
    #: ZERO-INIT, so a freshly-added block is bitwise-neutral — and
    #: ``False`` (the default) keeps params and forward bit-identical
    #: to the conv-only net.
    attention: bool = False
    attn_heads: int = 4
    #: run the bottleneck attention through the Pallas flash kernel
    #: (via the public ``repro.models.attention.attention`` owner);
    #: ``False`` takes the jnp reference path bit-identically.
    use_flash: bool = False
    #: run each residual block's GroupNorm→SiLU through the fused
    #: Pallas kernel (``repro.kernels.groupnorm_silu``, DESIGN.md §13).
    #: ``False`` (the default) is the historical unfused jnp chain,
    #: bit-identical to the pre-kernel stack under fp32.
    use_fused_norm: bool = False

    def __post_init__(self):
        down = 2 ** (len(self.mults) - 1)
        if self.horizon % down:
            raise ValueError(
                f"horizon {self.horizon} must divide {down} "
                f"(one stride-2 downsample per extra mult)"
            )
        if self.attention:
            cmid = self.base * self.mults[-1]
            if cmid % self.attn_heads:
                raise ValueError(
                    f"bottleneck width {cmid} must divide attn_heads "
                    f"{self.attn_heads}"
                )


def _conv_init(key, k, cin, cout, dtype=jnp.float32):
    fan = k * cin
    return (jax.random.truncated_normal(key, -2, 2, (k, cin, cout), jnp.float32)
            * fan ** -0.5).astype(dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride,), "SAME", dimension_numbers=("NHC", "HIO", "NHC")
    )


def _groupnorm(x: Array, scale: Array, bias: Array, groups: int) -> Array:
    """GroupNorm over (sample, group) slabs, fp32 math, rounded ONCE.

    DESIGN.md §8 norm rule, audited for the bf16 presets: the input is
    upcast to fp32 *before* the mean/var reductions (group statistics
    in bf16 would lose the variance to cancellation at any nonzero
    offset — ``tests/test_score_hotpath.py`` pins this with a
    large-offset regression), the affine params are explicitly upcast
    (a precision policy hands this bf16 copies; fp32 promotion rules
    would hide the cast, the explicit form documents it), and the
    single rounding to x's dtype is the final ``astype``.
    """
    B, H, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-6)
    out = (xg.reshape(B, H, C) * scale.astype(jnp.float32)
           + bias.astype(jnp.float32))
    return out.astype(x.dtype)


def _gn_silu(x: Array, scale: Array, bias: Array, groups: int,
             fused: bool) -> Array:
    """GroupNorm→SiLU, fused (one HBM pass, one rounding) or the
    historical unfused jnp chain (DESIGN.md §13). ``fused=False`` is
    bit-identical to the pre-kernel stack."""
    if fused:
        from repro.kernels.groupnorm_silu import ops as gs

        return gs.groupnorm_silu(x, scale, bias, groups=groups)
    return jax.nn.silu(_groupnorm(x, scale, bias, groups))


def _init_resblock(key, k, cin, cout, t_dim):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "gn1_s": jnp.ones((cin,)), "gn1_b": jnp.zeros((cin,)),
        "conv1": _conv_init(k1, k, cin, cout),
        "temb_w": dense_init(k2, (t_dim, cout), jnp.float32),
        "temb_b": jnp.zeros((cout,)),
        "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
        "conv2": jnp.zeros((k, cout, cout)),  # zero-init second conv
    }
    if cin != cout:
        p["skip"] = _conv_init(k3, 1, cin, cout)
    return p


def _resblock(p, x, temb, groups, fused=False):
    h = _gn_silu(x, p["gn1_s"], p["gn1_b"], groups, fused)
    h = _conv(h, p["conv1"])
    h = h + (jax.nn.silu(temb) @ p["temb_w"] + p["temb_b"])[:, None, :]
    h = _gn_silu(h, p["gn2_s"], p["gn2_b"], groups, fused)
    h = _conv(h, p["conv2"])
    skip = _conv(x, p["skip"]) if "skip" in p else x
    return skip + h


def _attn_block(p, x, cfg):
    """Bottleneck self-attention over the horizon axis (DESIGN.md §13).

    Pre-norm GroupNorm (norm math fp32, §8), per-head qkv projection,
    non-causal attention through the public
    :func:`repro.models.attention.attention` owner (flash kernel when
    ``cfg.use_flash``), zero-init output projection — so a
    freshly-initialized block is the identity, bitwise, and the
    ``attention=False`` ↔ fresh-``attention=True`` guardrail holds.
    """
    from repro.models.attention import attention

    hn = _groupnorm(x, p["gn_s"], p["gn_b"], cfg.groups)
    q = jnp.einsum("bsc,chd->bshd", hn, p["wq"])
    k = jnp.einsum("bsc,chd->bshd", hn, p["wk"])
    v = jnp.einsum("bsc,chd->bshd", hn, p["wv"])
    att = attention(q, k, v, causal=False, window=None, softcap=0.0,
                    use_flash=cfg.use_flash)
    return x + jnp.einsum("bshd,hdc->bsc", att, p["wo"])


def init_temporal_unet(cfg: TemporalUNetConfig, key: Array) -> Dict[str, Any]:
    ks = iter(jax.random.split(key, 64))
    widths = [cfg.base * m for m in cfg.mults]
    p: Dict[str, Any] = {
        "t_w1": dense_init(next(ks), (cfg.t_dim, cfg.t_dim), jnp.float32),
        "t_w2": dense_init(next(ks), (cfg.t_dim, cfg.t_dim), jnp.float32),
        "conv_in": _conv_init(next(ks), cfg.kernel, cfg.transition_dim,
                              widths[0]),
    }
    if cfg.returns_bins > 0:
        # one embedding row per returns bin + a trailing null row; the
        # null row is zero-init so a null-labeled forward is
        # bit-identical to the unconditional (y=None) forward
        table = 0.02 * jax.random.normal(
            next(ks), (cfg.returns_bins + 1, cfg.t_dim), jnp.float32)
        p["ret_emb"] = table.at[cfg.returns_bins].set(0.0)
    cin = widths[0]
    downs = []
    for i, w in enumerate(widths):
        downs.append({
            "res": _init_resblock(next(ks), cfg.kernel, cin, w, cfg.t_dim),
            # every level but the last halves the horizon
            **({"down": _conv_init(next(ks), cfg.kernel, w, w)}
               if i < len(widths) - 1 else {}),
        })
        cin = w
    p["downs"] = downs
    p["mid1"] = _init_resblock(next(ks), cfg.kernel, cin, cin, cfg.t_dim)
    p["mid2"] = _init_resblock(next(ks), cfg.kernel, cin, cin, cfg.t_dim)
    ups = []
    for i, w in enumerate(reversed(widths)):
        ups.append({
            **({"up": _conv_init(next(ks), cfg.kernel, cin, w)} if i else {}),
            # i == 0 runs at the bottom resolution (no upsample/concat);
            # later levels see [upsampled w ; skip w] = 2w channels
            "res": _init_resblock(next(ks), cfg.kernel, 2 * w if i else cin,
                                  w, cfg.t_dim),
        })
        cin = w
    p["ups"] = ups
    p["gn_out_s"] = jnp.ones((cin,))
    p["gn_out_b"] = jnp.zeros((cin,))
    p["conv_out"] = jnp.zeros((cfg.kernel, cin, cfg.transition_dim))
    if cfg.attention:
        # appended LAST so the PRNG-key consumption of every
        # pre-existing parameter is unchanged: attention=False params
        # are bit-identical with or without this branch compiled in
        cmid = cfg.base * cfg.mults[-1]
        dh = cmid // cfg.attn_heads
        p["attn"] = {
            "gn_s": jnp.ones((cmid,)), "gn_b": jnp.zeros((cmid,)),
            "wq": dense_init(next(ks), (cmid, cfg.attn_heads, dh),
                             jnp.float32, fan_in=cmid),
            "wk": dense_init(next(ks), (cmid, cfg.attn_heads, dh),
                             jnp.float32, fan_in=cmid),
            "wv": dense_init(next(ks), (cmid, cfg.attn_heads, dh),
                             jnp.float32, fan_in=cmid),
            # zero-init output projection: the fresh block is the
            # identity, so adding it to a net (or flipping
            # cfg.attention on) leaves the forward bitwise unchanged
            "wo": jnp.zeros((cfg.attn_heads, dh, cmid)),
        }
    return p


def temporal_unet_forward(params, x: Array, t: Array,
                          cfg: TemporalUNetConfig, policy=None,
                          y: Array | None = None) -> Array:
    """x (B, H, D), t (B,) → same-shape noise prediction.

    ``y`` (DESIGN.md §10): optional int32 (B,) returns-bin labels for a
    returns-conditioned net (``cfg.returns_bins > 0``); negative labels
    select the trailing null row. Like the timestep embedding, the
    returns embedding joins the conditioning path in fp32 from the
    stored weights — and the null row is zero, so the null branch is
    bit-identical to ``y=None``.
    """
    # fp32 timestep-embedding math from the stored (master) weights
    f32 = lambda w: w.astype(jnp.float32)
    temb = timestep_embedding(t, cfg.t_dim)
    temb = jax.nn.silu(temb @ f32(params["t_w1"])) @ f32(params["t_w2"])
    if y is not None and cfg.returns_bins > 0:
        idx = jnp.where(y < 0, cfg.returns_bins, y).astype(jnp.int32)
        temb = temb + f32(params["ret_emb"])[idx]

    if policy is not None:
        x = x.astype(policy.compute)
        params = policy.params_for_compute(params)
        temb = temb.astype(policy.compute)

    fused = cfg.use_fused_norm
    h = _conv(x, params["conv_in"])
    skips = []
    for d in params["downs"]:
        h = _resblock(d["res"], h, temb, cfg.groups, fused)
        if "down" in d:
            skips.append(h)
            h = _conv(h, d["down"], stride=2)
    h = _resblock(params["mid1"], h, temb, cfg.groups, fused)
    if cfg.attention:
        h = _attn_block(params["attn"], h, cfg)
    h = _resblock(params["mid2"], h, temb, cfg.groups, fused)
    for u in params["ups"]:
        if "up" in u:
            B, H, C = h.shape
            h = jax.image.resize(h, (B, H * 2, C), "nearest")
            h = _conv(h, u["up"])
            h = jnp.concatenate([h, skips.pop()], axis=-1)
        h = _resblock(u["res"], h, temb, cfg.groups, fused)
    h = _gn_silu(h, params["gn_out_s"], params["gn_out_b"], cfg.groups,
                 fused)
    return _conv(h, params["conv_out"])


def make_score_fn(params, cfg: TemporalUNetConfig, sde, policy=None):
    """Noise-prediction net → score: s(x,t[,y]) = −net(x,t[,y])/std(t)
    (DESIGN.md §10) — the adapter that makes every registered solver
    work on trajectories unmodified: the returned field has the plain
    ``s(x, t)`` signature (``y`` optional, consumed by a
    ``ClassifierFree``/``PlanConditioner`` wrap per DESIGN.md §9).

    With ``policy`` (DESIGN.md §8): weights stored at ``param_dtype``,
    x cast to the compute dtype on entry, fp32 1/std rescale, score
    returned in ``state_dtype`` — the same contract as the image nets.
    """
    if policy is not None:
        params = policy.cast_params(params)

    def score(x: Array, t: Array, y: Array | None = None) -> Array:
        _, std = sde.marginal(t)
        xin = x if policy is None else policy.to_compute(x)
        out = temporal_unet_forward(params, xin, t, cfg, policy=policy, y=y)
        s = -out.astype(jnp.float32) / std.reshape(
            (-1,) + (1,) * (x.ndim - 1)
        )
        return s if policy is None else policy.to_state(s)

    return score
