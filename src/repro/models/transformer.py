"""Decoder assembly: embeds → scanned super-blocks → norm → LM head.

The layer stack is grouped into ``num_repeats`` identical super-blocks
(one period of the config's mixer/mlp pattern). Weights are stacked on a
leading repeat axis and the stack is a single ``lax.scan``, so HLO size
is O(pattern) not O(depth). Non-uniform stacks (jamba 7:1 mamba:attn,
gemma3 5:1 local:global, VLM every-5th cross-attn) are uniform at the
super-block level by construction.

Decode threads per-layer recurrent state (KV caches / SSM states),
stacked the same way, through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention_decode, attention_forward, init_attention
from repro.models.config import ModelConfig
from repro.models.kvcache import LayerKVCache, init_kv_cache
from repro.models.layers import apply_mlp, apply_norm, dense_init, init_mlp, init_norm
from repro.models.mamba2 import (
    init_mamba,
    init_mamba_decode_state,
    mamba_decode,
    mamba_forward,
)
from repro.models.moe import apply_moe, init_moe

Array = jax.Array
Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_block_position(key: Array, cfg: ModelConfig, pos: int) -> Params:
    mix, mlp = cfg.mixer_pattern[pos], cfg.mlp_pattern[pos]
    kmix, kmlp, kn = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    p: Params = {"norm1": init_norm(kn, cfg.d_model, cfg.norm_type, dtype)}
    if mix in ("A", "L", "X"):
        p["mixer"] = init_attention(kmix, cfg, mix)
    elif mix == "M":
        p["mixer"] = init_mamba(kmix, cfg)
    else:
        raise ValueError(mix)
    if mlp != "N":
        p["norm2"] = init_norm(kn, cfg.d_model, cfg.norm_type, dtype)
        if mlp == "D":
            p["mlp"] = init_mlp(kmlp, cfg.d_model, cfg.d_ff, cfg.glu, dtype)
        elif mlp == "E":
            p["mlp"] = init_moe(kmlp, cfg)
        else:
            raise ValueError(mlp)
    return p


def init_model(cfg: ModelConfig, key: Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4 + len(cfg.mixer_pattern))
    R = cfg.num_repeats

    if cfg.num_codebooks > 1:
        embed = dense_init(
            ks[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), dtype,
            fan_in=cfg.d_model,
        )
    else:
        embed = dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                           fan_in=cfg.d_model)

    blocks = []
    for pos in range(len(cfg.mixer_pattern)):
        kp = jax.random.split(ks[2 + pos], R)
        stacked = jax.vmap(lambda k: _init_block_position(k, cfg, pos))(kp)
        blocks.append(stacked)

    params: Params = {
        "embed": embed,
        "blocks": {f"p{i}": b for i, b in enumerate(blocks)},
        "final_norm": init_norm(ks[1], cfg.d_model, cfg.norm_type, dtype),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["lm_head"] = dense_init(
                ks[-1], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), dtype
            )
        else:
            params["lm_head"] = dense_init(
                ks[-1], (cfg.d_model, cfg.vocab_size), dtype
            )
    return params


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def embed_tokens(params: Params, tokens: Array, cfg: ModelConfig) -> Array:
    if cfg.num_codebooks > 1:
        # tokens (B, S, K): sum of per-codebook embeddings (MusicGen).
        parts = [
            jnp.take(params["embed"][k], tokens[..., k], axis=0)
            for k in range(cfg.num_codebooks)
        ]
        return functools.reduce(jnp.add, parts)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params: Params, x: Array, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            return jnp.einsum("bse,kve->bskv", x, params["embed"])
        return jnp.einsum("bse,ve->bsv", x, params["embed"])
    if cfg.num_codebooks > 1:
        return jnp.einsum("bse,kev->bskv", x, params["lm_head"])
    return jnp.einsum("bse,ev->bsv", x, params["lm_head"])


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _block_forward(
    bp: Params,
    x: Array,
    aux: Array,
    cfg: ModelConfig,
    pos_idx: int,
    positions: Array,
    cross_embeds: Optional[Array],
    use_flash: bool,
    use_pallas_ssd: bool,
) -> Tuple[Array, Array]:
    mix, mlp = cfg.mixer_pattern[pos_idx], cfg.mlp_pattern[pos_idx]
    h = apply_norm(bp["norm1"], x, cfg.norm_type)
    if mix == "M":
        y = mamba_forward(bp["mixer"], h, cfg, use_pallas=use_pallas_ssd)
    else:
        y = attention_forward(
            bp["mixer"], h, cfg, mix, positions,
            cross_kv=cross_embeds if mix == "X" else None,
            use_flash=use_flash,
        )
    x = x + y
    if mlp != "N":
        h = apply_norm(bp["norm2"], x, cfg.norm_type)
        if mlp == "D":
            y = apply_mlp(bp["mlp"], h, cfg.act, cfg.glu)
        else:
            y, a = apply_moe(bp["mlp"], h, cfg, dispatch=cfg.moe_dispatch)
            aux = aux + a
        x = x + y
    if cfg.residual_seq_shard:
        from jax.sharding import PartitionSpec as P

        U = P.UNCONSTRAINED
        x = jax.lax.with_sharding_constraint(
            x, P(U, cfg.residual_seq_shard, U)
        )
    return x, aux


def forward(
    params: Params,
    tokens: Array,
    cfg: ModelConfig,
    *,
    cross_embeds: Optional[Array] = None,
    use_flash: bool = False,
    use_pallas_ssd: bool = False,
    remat: str = "none",  # none | full | dots
    unroll: bool = False,  # unroll the repeat scan (dry-run: exact HLO flops)
    last_logits_only: bool = False,  # prefill: head only on the final position
) -> Tuple[Array, Array]:
    """tokens (B, S[, K]) → (logits, moe_aux_loss)."""
    x = embed_tokens(params, tokens, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]

    def superblock(carry, bparams):
        x, aux = carry
        for i in range(len(cfg.mixer_pattern)):
            x, aux = _block_forward(
                bparams[f"p{i}"], x, aux, cfg, i, positions,
                cross_embeds, use_flash, use_pallas_ssd,
            )
        return (x, aux), None

    if remat == "full":
        superblock = jax.checkpoint(superblock)
    elif remat == "dots":
        superblock = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    elif remat != "none":
        raise ValueError(remat)

    (x, aux), _ = jax.lax.scan(
        superblock,
        (x, jnp.zeros((), jnp.float32)),
        params["blocks"],
        unroll=cfg.num_repeats if unroll else 1,
    )
    if last_logits_only:
        # Serving prefill needs only the next-token logits: slicing BEFORE
        # the head avoids a (B, S, V) matmul of dead compute — for a 32k
        # prefill with a 49k vocab that dead matmul is ~20× the rest of
        # the model (§Perf, granite hillclimb).
        x = x[:, -1:]
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    return lm_logits(params, x, cfg), aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    """Per-pattern-position recurrent state, stacked over repeats."""
    R = cfg.num_repeats
    dtype = jnp.dtype(cfg.dtype)
    state: Dict[str, Any] = {}
    for i, mix in enumerate(cfg.mixer_pattern):
        if mix in ("A", "L"):
            # Sliding-window layers only need a window-sized ring buffer.
            eff = cache_len if mix == "A" else min(cache_len, cfg.sliding_window)
            one = init_kv_cache(batch, eff, cfg.num_kv_heads, cfg.head_dim, dtype)
        elif mix == "M":
            one = init_mamba_decode_state(cfg, batch)
        else:  # "X" — stateless (image KV recomputed)
            state[f"p{i}"] = {}
            continue
        state[f"p{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (R,) + a.shape), one
        )
    return state


def decode_step(
    params: Params,
    tokens: Array,
    state: Dict[str, Any],
    cfg: ModelConfig,
    *,
    cross_embeds: Optional[Array] = None,
    start_pos: Optional[Array] = None,  # (B,) continuous-batching isolation
    unroll: bool = False,
) -> Tuple[Array, Dict[str, Any]]:
    """One decode step. tokens (B, 1[, K]) → (logits (B, 1[, K], V), state')."""
    x = embed_tokens(params, tokens, cfg)

    def superblock(x, inputs):
        bparams, st = inputs
        new_st = {}
        for i, (mix, mlp) in enumerate(zip(cfg.mixer_pattern, cfg.mlp_pattern)):
            bp = bparams[f"p{i}"]
            h = apply_norm(bp["norm1"], x, cfg.norm_type)
            if mix == "M":
                y, s_new = mamba_decode(bp["mixer"], h, cfg, st[f"p{i}"])
            elif mix == "X":
                y, _ = attention_decode(
                    bp["mixer"], h, cfg, mix, None, cross_kv=cross_embeds
                )
                s_new = {}
            else:
                y, s_new = attention_decode(bp["mixer"], h, cfg, mix,
                                            st[f"p{i}"], start_pos=start_pos)
            new_st[f"p{i}"] = s_new
            x = x + y
            if mlp != "N":
                h = apply_norm(bp["norm2"], x, cfg.norm_type)
                if mlp == "D":
                    y = apply_mlp(bp["mlp"], h, cfg.act, cfg.glu)
                else:
                    y, _ = apply_moe(bp["mlp"], h, cfg, dispatch=cfg.moe_dispatch)
                x = x + y
        return x, new_st

    x, new_state = jax.lax.scan(
        superblock, x, (params["blocks"], state),
        unroll=cfg.num_repeats if unroll else 1,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    return lm_logits(params, x, cfg), new_state
