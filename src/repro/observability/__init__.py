"""Observability layer (DESIGN.md §15): on-device solver telemetry,
serve-loop span tracing, a metrics registry with JSON/Prometheus export,
and quality-proxy gauges.

Everything here is off by default and structurally invisible when off:
the telemetry ring rides ``SolverCarry.telemetry`` as a None-by-default
pytree field (telemetry-off carries keep their exact pre-§15 treedef and
trace bitwise-identical programs), the tracer defaults to a no-op
singleton, and the metrics registry only generalizes counters the serve
loop already kept.
"""

from repro.observability.metrics import MetricsRegistry
from repro.observability.quality import (
    dynamics_consistency,
    env_step_mean,
    feature_moments,
    frechet_from_moments,
    proxy_fid,
    random_feature_extractor,
)
from repro.observability.telemetry import (
    StepTelemetry,
    init_telemetry,
    record_step,
    telemetry_history,
)
from repro.observability.tracing import (
    NULL_TRACER,
    NullTracer,
    StageTracer,
    profiler_annotation,
)

__all__ = [
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "StageTracer",
    "StepTelemetry",
    "dynamics_consistency",
    "env_step_mean",
    "feature_moments",
    "frechet_from_moments",
    "init_telemetry",
    "profiler_annotation",
    "proxy_fid",
    "random_feature_extractor",
    "record_step",
    "telemetry_history",
]
