"""Metrics registry (DESIGN.md §15): counters / gauges / histograms
with JSON and Prometheus text-format export, pure stdlib.

This generalizes the serve loop's scattered integer attributes
(total_iterations, useful_nfe, host_transfers, ...) and PR 9's
``TierAccounting`` into one registry at the existing ``_d2h``
accounting seam: every number the loop used to keep in an ad-hoc
attribute becomes a named (optionally labeled) counter, so the
host-driven and device-resident paths — which fold their device
counters at *different* seams — flow into the same ledger and can be
asserted equal against the device-side counters in one place.

Naming follows Prometheus conventions (``*_total`` for counters,
``_seconds``/``_fraction`` units in the name); labels are plain
keyword arguments: ``registry.counter("serve_delivered_total",
tier="draft").inc()``.
"""

from __future__ import annotations

import bisect
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.observability.tracing import LATENCY_BUCKETS_S

#: (name, sorted label items) — one series per unique pair
_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def _series(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone; inc({n})")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` semantics)."""

    __slots__ = ("bounds", "buckets", "total", "count")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS_S):
        self.bounds = tuple(bounds)
        self.buckets = [0] * (len(self.bounds) + 1)  # final = +Inf
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[bisect.bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1


class MetricsRegistry:
    """Get-or-create registry of named, labeled metric series."""

    def __init__(self):
        self._counters: Dict[_Key, Counter] = {}
        self._gauges: Dict[_Key, Gauge] = {}
        self._hists: Dict[_Key, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        return self._gauges.setdefault(_key(name, labels), Gauge())

    def histogram(self, name: str, bounds: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        k = _key(name, labels)
        if k not in self._hists:
            self._hists[k] = (Histogram() if bounds is None
                              else Histogram(bounds))
        return self._hists[k]

    def value(self, name: str, **labels) -> float:
        """Read one series (counter or gauge) by exact name + labels."""
        k = _key(name, labels)
        if k in self._counters:
            return self._counters[k].value
        if k in self._gauges:
            return self._gauges[k].value
        raise KeyError(f"no metric series {_series(k)}")

    def total(self, name: str) -> float:
        """Sum a counter across all its label sets (e.g. per-tier
        delivered counts → overall delivered)."""
        return sum(c.value for (n, _), c in self._counters.items()
                   if n == name)

    # -- export ---------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "counters": {_series(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_series(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {
                _series(k): {
                    "bounds": list(h.bounds),
                    "buckets": list(h.buckets),
                    "count": h.count,
                    "sum": h.total,
                }
                for k, h in sorted(self._hists.items())
            },
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4): one ``# TYPE``
        line per metric name, cumulative ``le`` buckets + ``_sum`` /
        ``_count`` for histograms."""
        lines = []
        typed = set()

        def type_line(name, kind):
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for k, c in sorted(self._counters.items()):
            type_line(k[0], "counter")
            lines.append(f"{_series(k)} {c.value}")
        for k, g in sorted(self._gauges.items()):
            type_line(k[0], "gauge")
            lines.append(f"{_series(k)} {g.value}")
        for (name, labels), h in sorted(self._hists.items()):
            type_line(name, "histogram")
            cum = 0
            for bound, n in zip(h.bounds, h.buckets):
                cum += n
                lk = labels + (("le", repr(float(bound))),)
                lines.append(f"{_series((name + '_bucket', lk))} {cum}")
            lk = labels + (("le", "+Inf"),)
            lines.append(f"{_series((name + '_bucket', lk))} {h.count}")
            lines.append(f"{_series((name + '_sum', labels))} {h.total}")
            lines.append(f"{_series((name + '_count', labels))} {h.count}")
        return "\n".join(lines) + "\n"
