"""Quality-proxy gauges (DESIGN.md §15): weight-free stand-ins for the
paper's FID/quality axis so kernel, solver, and precision regressions
surface as *quality numbers* in the bench suite, not only as timing or
W2 moments.

  * **proxy-FID** — the Fréchet distance between feature moments of two
    sample sets under a *fixed random-projection* extractor (Gaussian
    projection + tanh nonlinearity, seeded — no external weights, no
    downloads). Like real FID it is a moment distance in a nonlinear
    feature space, so it responds to distributional drift a pixel-MSE
    misses; unlike real FID the features are not perceptual, so its
    *absolute* value is meaningless across shapes/extractors — it is a
    regression gauge (same extractor, same reference set, tracked over
    PRs), not a paper-comparable score. Limits vs real FID are spelled
    out in DESIGN.md §15.
  * **dynamics-consistency error** — for planning workloads: the RMS
    env-step residual along sampled trajectories, i.e. how far each
    plan's next-state rows sit from the environment's mean transition
    applied to the previous row. A plan sampled from the right
    trajectory distribution keeps this near the env's noise floor;
    solver/precision regressions push it up.
"""

from __future__ import annotations

from typing import Callable, Tuple

import numpy as np


def random_feature_extractor(sample_shape, dim: int = 32,
                             seed: int = 0) -> Callable[[np.ndarray], np.ndarray]:
    """A fixed (seeded) random-projection feature map for samples of
    ``sample_shape``: ``x → [z, tanh(z)]`` with ``z = x_flat @ W + b``,
    W ~ N(0, 1/flat). Deterministic in (shape, dim, seed), so two runs
    gauge against identical features — the property that makes the
    proxy comparable across PRs."""
    flat = int(np.prod(sample_shape))
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((flat, dim)) / np.sqrt(flat)).astype(np.float64)
    b = rng.uniform(-1.0, 1.0, size=(dim,))

    def feats(x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64).reshape(x.shape[0], -1)
        if x.shape[1] != flat:
            raise ValueError(
                f"sample shape {x.shape[1:]} does not flatten to {flat}")
        z = x @ w + b
        return np.concatenate([z, np.tanh(z)], axis=-1)

    return feats


def feature_moments(feats: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(mean, covariance) of a (N, F) feature matrix (N ≥ 2)."""
    f = np.asarray(feats, np.float64)
    if f.ndim != 2 or f.shape[0] < 2:
        raise ValueError(f"need (N>=2, F) features, got {f.shape}")
    return f.mean(axis=0), np.cov(f, rowvar=False)


def _sqrtm_psd(m: np.ndarray) -> np.ndarray:
    """Symmetric PSD square root via eigh (negative eigenvalues from
    roundoff are clamped to 0)."""
    vals, vecs = np.linalg.eigh((m + m.T) / 2.0)
    vals = np.clip(vals, 0.0, None)
    return (vecs * np.sqrt(vals)) @ vecs.T


def frechet_from_moments(mu1, cov1, mu2, cov2) -> float:
    """Fréchet (2-Wasserstein²) distance between Gaussians fitted to two
    feature sets: |μ1−μ2|² + tr(C1 + C2 − 2·(C1^{1/2} C2 C1^{1/2})^{1/2})
    — the symmetric-PSD form, numerically safe for rank-deficient
    covariances (small sample counts)."""
    mu1, mu2 = np.asarray(mu1, np.float64), np.asarray(mu2, np.float64)
    cov1, cov2 = np.asarray(cov1, np.float64), np.asarray(cov2, np.float64)
    s1 = _sqrtm_psd(cov1)
    inner = _sqrtm_psd(s1 @ cov2 @ s1)
    d2 = float(np.sum((mu1 - mu2) ** 2)
               + np.trace(cov1) + np.trace(cov2) - 2.0 * np.trace(inner))
    return max(d2, 0.0)


def proxy_fid(x_ref, x_gen, *, dim: int = 32, seed: int = 0) -> float:
    """Cached-activation proxy-FID between a reference and a generated
    sample set (leading dim = samples; shapes must match past it). The
    extractor is a fixed random projection, so this needs no external
    weights — see module docstring for what that does and does not
    buy."""
    x_ref = np.asarray(x_ref)
    x_gen = np.asarray(x_gen)
    if x_ref.shape[1:] != x_gen.shape[1:]:
        raise ValueError(
            f"sample shapes differ: {x_ref.shape[1:]} vs {x_gen.shape[1:]}")
    feats = random_feature_extractor(x_ref.shape[1:], dim=dim, seed=seed)
    mu1, c1 = feature_moments(feats(x_ref))
    mu2, c2 = feature_moments(feats(x_gen))
    return frechet_from_moments(mu1, c1, mu2, c2)


def env_step_mean(env) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    """The environment's *mean* transition s' = E[step(s, a)] as a
    vectorized numpy function over (..., obs_dim) states and (...,
    act_dim) actions, duck-typed over the analytic envs (DESIGN.md §10):

      * OU family (has ``theta``): s + dt·(−θ·s + a) — the closed-form
        mean of the σ√dt-noised step;
      * double integrator (has ``vel_cost``): [pos + dt·vel,
        vel + dt·a] — deterministic, so mean == step.
    """
    if hasattr(env, "theta"):
        dt, theta = float(env.dt), float(env.theta)
        return lambda s, a: s + dt * (-theta * s + a)
    if hasattr(env, "vel_cost"):
        dt, dim = float(env.dt), int(env.dim)

        def mean(s, a):
            pos, vel = s[..., :dim], s[..., dim:]
            return np.concatenate([pos + dt * vel, vel + dt * a], axis=-1)

        return mean
    raise TypeError(f"no mean-transition rule for {type(env).__name__}")


def dynamics_consistency(env, trajs, *, obs_dim: int, act_dim: int) -> float:
    """RMS env-step residual along sampled plans (DESIGN.md §15).

    ``trajs`` is (B, H, D) or (H, D) with rows ``[s_h, a_h]`` and
    ``D >= obs_dim + act_dim``; the gauge is the RMS over all (sample,
    transition, coordinate) of ``s_{h+1} − mean_step(s_h, a_h)``. For a
    stochastic env the floor is its noise scale (σ√dt for OU); for a
    deterministic env a perfect rollout scores 0.
    """
    x = np.asarray(trajs, np.float64)
    if x.ndim == 2:
        x = x[None]
    if x.ndim != 3 or x.shape[1] < 2:
        raise ValueError(f"need (B, H>=2, D) trajectories, got {x.shape}")
    s = x[:, :, :obs_dim]
    a = x[:, :, obs_dim:obs_dim + act_dim]
    pred = env_step_mean(env)(s[:, :-1], a[:, :-1])
    resid = s[:, 1:] - pred
    return float(np.sqrt(np.mean(resid ** 2)))
