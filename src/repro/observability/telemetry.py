"""On-device solver step telemetry: a fixed-size ring buffer riding
``SolverCarry.telemetry`` (DESIGN.md §15).

The paper's contribution is a *dynamic* quantity — per-sample step
sizes, accept/reject decisions, the scaled error norm that drives them —
but end-of-solve counters (nfe/accepted/rejected) only show the
integral. The ring records the trajectory: at every Algorithm-1 body
iteration, one column write captures each slot's (t, h, err, accept)
snapshot, entirely device-side, with zero extra host syncs — the host
decodes the buffers whenever it next pulls the carry.

Design rules (DESIGN.md §15):

  * **None-ness is treedef structure.** ``SolverCarry.telemetry`` is
    None by default, so telemetry-off carries keep the exact pre-§15
    pytree structure and the loop body's ``is None`` check happens at
    trace time — telemetry-off programs are bitwise identical to the
    pre-telemetry stack on both the host-driven and device-resident
    serving paths.
  * **The head cursor is monotone.** ``head`` counts every body
    iteration since the ring was created and is *never* reset — unlike
    ``SolverCarry.iterations``, which the serve loop folds-and-resets at
    every host visit. Writes land at column ``head % capacity``, so the
    ring always holds the most recent ``capacity`` iterations and
    ``head`` doubles as the all-time iteration count (the reconciliation
    invariant the observability tests pin against the serve loop's
    folded counter).
  * **Rows travel with their sample.** Under slot compaction the (B,
    cap) buffers permute along axis 0 exactly like x and the per-slot
    keys, so a row's recent records follow the sample that produced
    them. Admission does **not** clear a row: records are globally
    iteration-stamped (one column per body iteration across all slots)
    and age out by ring wrap, which keeps aggregate statistics — accept
    counts, step-size-vs-t curves — exact over every occupant a slot
    ever hosted. Idle-slot records carry ``t <= t_eps`` and are filtered
    host-side.
  * **Recording never feeds back.** The ring is written from values the
    body already computed (entry t, the clamped attempted h, the fp32
    scaled error, the accept bit); no solver quantity reads it, and the
    PRNG stream is untouched — which is what makes the telemetry-on
    solve's *solution* path bit-identical to telemetry-off.

This module imports only jax/numpy so the solver core can depend on it
without cycles.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StepTelemetry:
    """Per-slot step-telemetry ring (DESIGN.md §15).

    Attributes:
      t: (B, cap) fp32 — each slot's time at iteration entry.
      h: (B, cap) fp32 — the attempted step size (0 for frozen slots,
         matching the body's active-clamp).
      err: (B, cap) fp32 — the scaled error norm; accept ⇔ err ≤ 1 for
         active slots.
      accept: (B, cap) bool — the accept decision.
      head: scalar int32 — monotone write cursor == total iterations
         recorded since creation (never reset; see module docstring).
    """

    t: Array
    h: Array
    err: Array
    accept: Array
    head: Array

    @property
    def batch(self) -> int:
        return self.t.shape[0]

    @property
    def capacity(self) -> int:
        return self.t.shape[1]


def init_telemetry(batch: int, capacity: int) -> StepTelemetry:
    """Fresh all-zero ring for ``batch`` slots × ``capacity`` records."""
    cap = int(capacity)
    if cap <= 0:
        raise ValueError(f"telemetry capacity must be positive, got {cap}")
    shape = (int(batch), cap)
    return StepTelemetry(
        t=jnp.zeros(shape, jnp.float32),
        h=jnp.zeros(shape, jnp.float32),
        err=jnp.zeros(shape, jnp.float32),
        accept=jnp.zeros(shape, bool),
        head=jnp.asarray(0, jnp.int32),
    )


def record_step(tel: StepTelemetry, *, t: Array, h: Array, err: Array,
                accept: Array, constrain=None) -> StepTelemetry:
    """One iteration's column write at ``head % capacity`` (trace-safe).

    ``constrain`` optionally re-applies the (B, cap) sharding constraint
    after the dynamic-slice update so GSPMD keeps the buffers batch-
    sharded through the while loop (DESIGN.md §3).
    """
    idx = jnp.mod(tel.head, tel.capacity)
    c = constrain if constrain is not None else (lambda a: a)

    def put(buf, v):
        return c(jax.lax.dynamic_update_index_in_dim(
            buf, v.astype(buf.dtype), idx, axis=1))

    return StepTelemetry(
        t=put(tel.t, t),
        h=put(tel.h, h),
        err=put(tel.err, err),
        accept=put(tel.accept, accept),
        head=tel.head + 1,
    )


def telemetry_history(tel: StepTelemetry) -> dict:
    """Host-side chronological decode of a (pulled) ring.

    Returns ``{"t", "h", "err", "accept"}`` as (B, n) numpy arrays in
    iteration order — the last ``n = min(head, capacity)`` records,
    oldest first — plus ``"iterations"`` (the all-time head count) and
    ``"records"`` (n). With ``head <= capacity`` nothing has wrapped and
    the decode is the full, exact iteration history.
    """
    head = int(np.asarray(tel.head))
    cap = int(np.asarray(tel.t).shape[1])
    n = min(head, cap)
    cols = np.arange(head - n, head) % cap if n else np.zeros(0, np.int64)
    out = {
        name: np.asarray(getattr(tel, name))[:, cols]
        for name in ("t", "h", "err", "accept")
    }
    out["iterations"] = head
    out["records"] = n
    return out
