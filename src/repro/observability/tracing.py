"""Serve-loop span tracing (DESIGN.md §15): monotonic-clock spans over
the batcher's admission/solve/delivery stages and planner rounds, plus
``jax.profiler`` annotation hooks around the jitted device programs.

The tracer is deliberately minimal — a list of ``{name, start, end,
duration_s, attrs}`` dicts on an injectable monotonic clock — because
the interesting structure (request-id propagation through compaction,
per-stage latency distributions) lives in the *attrs* the serve loop
attaches, not in the recording machinery. ``NULL_TRACER`` is the
default no-op: its ``span`` yields without recording, so an untraced
batcher does no clock reads and allocates nothing per stage.
"""

from __future__ import annotations

import bisect
import contextlib
import time
from typing import Any, Callable, Dict, List, Optional

#: log-spaced latency bucket upper bounds (seconds) for the per-stage
#: histograms; the final implicit bucket is +Inf
LATENCY_BUCKETS_S = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0,
)


class StageTracer:
    """Span recorder: ``with tracer.span("serve/solve", window=3): ...``.

    Spans nest freely (the record is a flat list ordered by end time);
    attrs must be JSON-serializable — the serve loop passes request
    uids, slot indices, and per-request NFE lists so a trace reconciles
    against the device-side counters (DESIGN.md §15).
    """

    #: False only on the null tracer — the serve loop keys optional
    #: extras (profiler annotations, attr assembly) on this flag
    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock if clock is not None else time.monotonic
        self.spans: List[Dict[str, Any]] = []

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        rec: Dict[str, Any] = {"name": name, "start": self.clock(),
                               "attrs": attrs}
        try:
            yield rec
        finally:
            rec["end"] = self.clock()
            rec["duration_s"] = rec["end"] - rec["start"]
            self.spans.append(rec)

    def stage_histograms(self) -> Dict[str, Dict[str, Any]]:
        """Per-stage latency histograms over the recorded spans:
        count / total / mean / max plus log-spaced bucket counts
        (``LATENCY_BUCKETS_S`` bounds, final bucket +Inf)."""
        out: Dict[str, Dict[str, Any]] = {}
        for s in self.spans:
            h = out.setdefault(s["name"], {
                "count": 0, "total_s": 0.0, "max_s": 0.0,
                "buckets": [0] * (len(LATENCY_BUCKETS_S) + 1),
            })
            d = float(s["duration_s"])
            h["count"] += 1
            h["total_s"] += d
            h["max_s"] = max(h["max_s"], d)
            h["buckets"][bisect.bisect_left(LATENCY_BUCKETS_S, d)] += 1
        for h in out.values():
            h["mean_s"] = h["total_s"] / h["count"]
        return out

    def to_json(self) -> Dict[str, Any]:
        """The structured trace: every span plus the per-stage latency
        histograms (bucket bounds included so the record is
        self-describing)."""
        return {
            "spans": list(self.spans),
            "stage_histograms": self.stage_histograms(),
            "bucket_bounds_s": list(LATENCY_BUCKETS_S),
        }


class NullTracer(StageTracer):
    """The no-op default: ``span`` records nothing and reads no clock —
    an untraced serve loop pays one ``is not None``-grade check per
    stage and keeps its pre-§15 behaviour exactly."""

    enabled = False

    def __init__(self):
        super().__init__(clock=lambda: 0.0)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        yield {"name": name, "attrs": attrs}


#: shared no-op instance (stateless — safe to share across batchers)
NULL_TRACER = NullTracer()


def profiler_annotation(name: str, step: Optional[int] = None):
    """A ``jax.profiler`` trace-annotation context for the given stage:
    ``StepTraceAnnotation`` when a step number is given (so profiler
    UIs group the donated driver's windows), ``TraceAnnotation``
    otherwise. Both are cheap no-ops without an active profiler; falls
    back to a null context if the profiler API is unavailable."""
    try:
        import jax

        if step is not None:
            return jax.profiler.StepTraceAnnotation(name, step_num=int(step))
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()
