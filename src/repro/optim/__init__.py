from .adamw import AdamW, AdamWState, global_norm
from .ema import ema_init, ema_params, ema_update
from .schedules import constant, warmup_cosine, warmup_linear

__all__ = [
    "AdamW", "AdamWState", "global_norm",
    "ema_init", "ema_params", "ema_update",
    "constant", "warmup_cosine", "warmup_linear",
]
