"""AdamW with decoupled weight decay and global-norm clipping (pure JAX).

State and update are pytree-parallel; moments are kept in fp32 even for
bf16 params (master-quality moments, MaxText convention).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step: Array) -> Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
