"""Exponential moving average of parameters — required by diffusion
training (the paper samples from the EMA weights of the score net)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ema_init(params):
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def ema_update(ema, params, decay: float = 0.999):
    return jax.tree.map(
        lambda e, p: decay * e + (1.0 - decay) * p.astype(jnp.float32), ema, params
    )


def ema_params(ema, like):
    """Cast the fp32 EMA back to the training dtype structure."""
    return jax.tree.map(lambda e, p: e.astype(p.dtype), ema, like)
