from .sharding import (
    MODEL_AXIS,
    batch_sharding,
    data_axes,
    kv_cache_sharding,
    param_shardings,
    replicated,
    sample_state_shardings,
    solver_carry_shardings,
)

__all__ = [
    "MODEL_AXIS", "batch_sharding", "data_axes", "kv_cache_sharding",
    "param_shardings", "replicated", "sample_state_shardings",
    "solver_carry_shardings",
]
