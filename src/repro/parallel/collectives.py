"""shard_map collectives: distributed flash-decode attention (§Perf).

When GQA kv-heads don't divide the model axis, decode caches shard over
the *sequence* (sharding.kv_cache_spec). Plain GSPMD then all-gathers
the whole KV per token (measured 37.9 GiB/step for gemma3 decode_32k).
This module does what GSPMD can't derive: each shard writes its slice of
the cache locally, computes a *partial* softmax over its keys, and the
shards combine with O(B·H·Dh) psums — flash-decode across chips.

Exact: the combine uses the standard online-softmax correction
(global max → rescale partial sums), identical numerics to full-cache
attention (validated in tests against the jnp reference).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import compat as _compat

Array = jax.Array


def scaled_error_l2_psum(sq_sum: Array, n_local, axis) -> Array:
    """Cross-device combine for the solver's scaled ℓ2 error (DESIGN.md §3).

    Each shard contributes its per-sample sum of squared scaled residuals
    ``sq_sum`` (B_local,) over ``n_local`` locally-held elements; the
    global dimension-normalized error is

        E₂ = sqrt( psum(sq_sum) / psum(n) )

    with O(B) traffic per shard — the distributed form of
    ``repro.core.tolerance.scaled_error_l2``. Must be called inside a
    ``shard_map`` whose mesh carries ``axis``.
    """
    total = jax.lax.psum(sq_sum, axis)
    n = jax.lax.psum(jnp.asarray(n_local, sq_sum.dtype), axis)
    return jnp.sqrt(total / n)


def _local_write_and_attend(
    q, k_new, v_new, ck, cv, pos_l, length,
    *, axis, window: Optional[int], softcap: float, group: int,
):
    """Per-shard body. ck/cv (B, Scl, Kv, Dh); pos_l (Scl,); q (B,1,H,Dh).
    ``axis`` is a tuple of mesh axis names the sequence dim shards over
    (major-to-minor, matching PartitionSpec tuple semantics)."""
    B, Scl, Kv, Dh = ck.shape
    n = 1
    my_index = jnp.zeros((), jnp.int32)
    for a in axis:
        sz = _compat.axis_size(a)
        my_index = my_index * sz + jax.lax.axis_index(a).astype(jnp.int32)
        n = n * sz
    Sc = Scl * n
    slot = (length % Sc).astype(jnp.int32)
    my_start = my_index * Scl
    local_slot = jnp.clip(slot - my_start, 0, Scl - 1)
    owns = jnp.logical_and(slot >= my_start, slot < my_start + Scl)

    ck_w = jax.lax.dynamic_update_slice(ck, k_new, (0, local_slot, 0, 0))
    cv_w = jax.lax.dynamic_update_slice(cv, v_new, (0, local_slot, 0, 0))
    pos_w = jax.lax.dynamic_update_slice(
        pos_l, length[None].astype(jnp.int32), (local_slot,)
    )
    ck = jnp.where(owns, ck_w, ck)
    cv = jnp.where(owns, cv_w, cv)
    pos_l = jnp.where(owns, pos_w, pos_l)

    # visibility of local slots to the (just-written) current token
    cur = length  # position of the new token
    valid = jnp.logical_and(pos_l >= 0, pos_l <= cur)
    if window is not None:
        valid = jnp.logical_and(valid, pos_l > cur - window)

    kk = jnp.repeat(ck, group, axis=2)  # (B, Scl, H, Dh)
    vv = jnp.repeat(cv, group, axis=2)
    logits = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (Dh ** -0.5)  # (B, H, 1, Scl)
    if softcap and softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)

    m_loc = jnp.max(logits, axis=-1)  # (B, H, 1)
    p = jnp.exp(logits - m_loc[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    s_loc = jnp.sum(p, axis=-1)  # (B, H, 1)
    o_loc = jnp.einsum("bhst,bthd->bshd", p, vv.astype(jnp.float32))  # (B,1,H,Dh)

    # cross-shard online-softmax combine: O(B·H·Dh) traffic
    m_glob = jax.lax.pmax(m_loc, axis)  # axis tuple OK
    corr = jnp.exp(m_loc - m_glob)  # (B, H, 1)
    s_glob = jax.lax.psum(s_loc * corr, axis)
    o = jax.lax.psum(o_loc * corr.transpose(0, 2, 1)[..., None], axis)
    o = o / jnp.maximum(s_glob, 1e-30).transpose(0, 2, 1)[..., None]
    return o.astype(q.dtype), ck, cv, pos_l


def flash_decode(
    q: Array,        # (B, 1, H, Dh)
    k_new: Array,    # (B, 1, Kv, Dh)
    v_new: Array,    # (B, 1, Kv, Dh)
    cache_k: Array,  # (B, Sc, Kv, Dh) — seq dim sharded over `axis`
    cache_v: Array,
    pos: Array,      # (Sc,) absolute positions, −1 empty
    length: Array,   # () tokens seen before this one
    *,
    axis="model",  # mesh axis name, or comma-joined / tuple of names
    window: Optional[int] = None,
    softcap: float = 0.0,
) -> Tuple[Array, Array, Array, Array]:
    """Write one token and attend, with the cache sequence-sharded over
    ``axis``. Returns (out (B,1,H,Dh), cache_k', cache_v', pos')."""
    if isinstance(axis, str):
        axis = tuple(axis.split(","))
    else:
        axis = tuple(axis)
    group = q.shape[2] // cache_k.shape[2]
    body = functools.partial(
        _local_write_and_attend,
        axis=axis, window=window, softcap=softcap, group=group,
    )
    # Resolve the ambient mesh: the launchers use the legacy `with mesh:`
    # context, which jax.shard_map's context-mesh lookup doesn't see.
    mesh = _compat.ambient_mesh()
    fn = _compat.shard_map(
        body,
        in_specs=(
            P(), P(), P(),                       # q, k_new, v_new replicated over axis
            P(None, axis, None, None),           # cache_k
            P(None, axis, None, None),           # cache_v
            P(axis),                             # pos
            P(),                                 # length
        ),
        out_specs=(P(), P(None, axis, None, None),
                   P(None, axis, None, None), P(axis)),
        axis_names=set(axis),
        mesh=mesh,
    )
    return fn(q, k_new, v_new, cache_k, cache_v, pos, length)
