"""Version compatibility for shard_map across JAX releases.

Newer JAX exposes ``jax.shard_map`` (with ``axis_names``) and
``jax.sharding.get_abstract_mesh``; 0.4.x has neither — shard_map lives
in ``jax.experimental.shard_map`` and the ambient mesh only exists as
the legacy ``with mesh:`` thread resource. These two helpers paper over
the difference so ``collectives``/``pipeline`` run on both.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_rep: bool | None = None):
    """Dispatch to ``jax.shard_map`` when present, else the experimental one.

    ``axis_names`` keeps new-JAX semantics on the fallback too: unlisted
    mesh axes stay *automatic* (GSPMD-partitioned), which the
    experimental API expresses as the ``auto=`` complement. Without that
    mapping a (data, model) mesh would treat the body as manual over
    every axis and the in_specs would force all-gathers of the data-
    sharded operands.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_rep is not None:
            kw["check_rep"] = check_rep
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
        except TypeError:
            # newest releases renamed check_rep → check_vma
            if "check_rep" in kw:
                kw["check_vma"] = kw.pop("check_rep")
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    base_kw = {"check_rep": check_rep} if check_rep is not None else {}
    auto = frozenset()
    if axis_names is not None and mesh is not None:
        # size-1 axes are semantically irrelevant (replicated == auto)
        auto = frozenset(
            a for a in mesh.axis_names
            if a not in set(axis_names) and dict(mesh.shape).get(a, 1) > 1
        )
    if not auto:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **base_kw)

    # replication checking is rejected alongside auto axes
    auto_kw = dict(base_kw, auto=auto, check_rep=False)
    fn_auto = _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **auto_kw)
    fn_manual = _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **base_kw)

    def call(*args):
        # 0.4.x partial-auto support is incomplete; keep the unlisted axes
        # GSPMD-automatic when possible, else fall back to fully-manual
        # (correct, possibly paying replication of the unlisted axes).
        try:
            return fn_auto(*args)
        except NotImplementedError:
            return fn_manual(*args)

    return call


def axis_size(name):
    """``jax.lax.axis_size`` fallback: psum(1) over the named axis."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def pvary(x, axis_names):
    """``jax.lax.pvary`` fallback: a no-op where replication tracking
    doesn't exist (old shard_map has no varying-axis type system)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def ambient_mesh():
    """The mesh of the enclosing ``with mesh:`` / use-mesh context, or None.

    The launchers use the legacy ``with mesh:`` context, which newer
    shard_map's context-mesh lookup doesn't see — and older JAX has no
    ``get_abstract_mesh`` at all.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is not None and not mesh.empty:
            return mesh
    from jax._src import mesh as _mesh_lib

    phys = _mesh_lib.thread_resources.env.physical_mesh
    return phys if not phys.empty else None
