"""GPipe-style pipeline parallelism over a mesh axis (shard_map).

The layer stack's repeat axis shards over the pipeline axis (each stage
holds R/n_stages super-blocks); microbatches flow through stages with
``ppermute`` at the boundaries. Total ticks = M + n_stages − 1; the
bubble fraction is (n−1)/(M+n−1).

Scope: forward/inference pipelining (the diffusion sampler's score-net
forward is the motivating workload — one Algorithm-1 iteration is two
pipelined forwards). The machinery is generic over any
``body(stage_params, x) → x`` with x-shaped carry.

Degenerate single-stage (axis size 1) is exactly a scan — that is the
CPU-testable path; multi-stage correctness is compile-proven by the
dry-run variant and structurally by construction (each microbatch
visits every stage once, in order).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import compat as _compat

Array = jax.Array


def _pipeline_local(params_local, x_mb: Array, *, body: Callable,
                    axis: str, num_microbatches: int):
    """Per-stage body (inside shard_map).

    params_local: stage's slice of the stacked weights (R_local, ...).
    x_mb: (M, mb, ...) microbatches — input on stage 0, ignored elsewhere.
    Returns (M, mb, ...) outputs — valid on the LAST stage.
    """
    n = _compat.axis_size(axis)
    stage = jax.lax.axis_index(axis)
    M = num_microbatches
    ticks = M + n - 1

    mb_shape = x_mb.shape[1:]
    zeros = jnp.zeros(mb_shape, x_mb.dtype)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def tick_fn(carry, t):
        in_buf, outputs = carry
        # stage 0 feeds microbatch t (while available); others take the
        # activation handed over by the previous stage last tick.
        mb_idx = jnp.clip(t, 0, M - 1)
        feed = jax.lax.dynamic_index_in_dim(x_mb, mb_idx, keepdims=False)
        x_in = jnp.where(stage == 0, feed, in_buf)

        y = body(params_local, x_in)

        # hand over to the next stage (ring; stage n-1 → 0 is ignored)
        in_buf_next = jax.lax.ppermute(y, axis, perm)

        # last stage emits microbatch (t - (n-1)) at tick t
        out_idx = jnp.clip(t - (n - 1), 0, M - 1)
        is_valid = jnp.logical_and(stage == n - 1, t >= n - 1)
        updated = jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx,
                                                      axis=0)
        outputs = jnp.where(is_valid, updated, outputs)
        return (in_buf_next, outputs), None

    init = (
        _compat.pvary(zeros, (axis,)),
        _compat.pvary(jnp.zeros_like(x_mb), (axis,)),
    )
    (_, outputs), _ = jax.lax.scan(tick_fn, init, jnp.arange(ticks))
    # broadcast the last stage's outputs to every stage (tiny psum trick:
    # zero elsewhere, sum over the axis)
    outputs = jnp.where(stage == n - 1, outputs, jnp.zeros_like(outputs))
    return jax.lax.psum(outputs, axis)


def pipeline_forward(
    params_stacked,       # pytree, leaves (R, ...) — R % axis_size == 0
    x: Array,             # (B, ...) global batch
    body: Callable,       # (stage_params, x) → x, applied per super-block
    *,
    axis: str = "pod",
    num_microbatches: int = 4,
    mesh=None,
) -> Array:
    """Run ``body`` over the full stacked depth, pipelined over ``axis``.

    The weights' repeat axis is sharded over ``axis`` (stage-local
    scan inside ``body`` handles the R_local super-blocks); activations
    stream through stages in microbatches.
    """
    B = x.shape[0]
    assert B % num_microbatches == 0, (B, num_microbatches)
    x_mb = x.reshape((num_microbatches, B // num_microbatches) + x.shape[1:])

    if mesh is None:
        mesh = _compat.ambient_mesh()

    fn = _compat.shard_map(
        functools.partial(
            _pipeline_local, body=body, axis=axis,
            num_microbatches=num_microbatches,
        ),
        in_specs=(P(axis), P()),   # weights stage-sharded; x replicated
        out_specs=P(),             # outputs replicated (psum-broadcast)
        axis_names={axis},
        mesh=mesh,
    )
    out = fn(params_stacked, x_mb)
    return out.reshape((B,) + out.shape[2:])
