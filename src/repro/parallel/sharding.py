"""Sharding rules: parameter-path → PartitionSpec, divisibility-aware.

Tensor parallelism lives on the "model" mesh axis; batch parallelism on
("pod", "data") (the pod axis is an outer data axis whose gradient
all-reduce crosses the DCN — DESIGN.md §5). Rules are matched by path
substring, most-specific first, and each candidate axis is only sharded
when its size divides the mesh axis — otherwise the next candidate in
the rule is tried, falling back to replication. That single mechanism
resolves every divisibility wrinkle in the assigned pool (kv=8 heads vs
model=16 → replicate KV projections; granite's 40 experts vs 16 →
shard each expert's FFN dim instead; etc.).
"""

from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

MODEL_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch axes present in this mesh: ("pod","data") or ("data",)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# Each rule: (path regex, per-dimension candidate axes). For dimension i
# the spec tries candidates[i] in order; None means replicate. The
# leading repeat axis of scanned blocks is handled automatically (see
# _spec_for). Candidates are tuples because some dims have fallbacks:
# e.g. MoE w_in (X, E, F): shard X if divisible else F.
_RULES: Sequence[Tuple[str, Sequence[Sequence[Optional[str]]]]] = (
    # --- MoE experts: prefer expert sharding, fall back to ffn dim -----
    (r"mlp/(w_in|w_gate)$", [["expert_or_none"], [None], ["model_if_expert_failed"]]),
    (r"mlp/w_out$", [["expert_or_none"], ["model_if_expert_failed"], [None]]),
    (r"mlp/router$", [[None], [None]]),
    (r"shared/(w_in|w_gate)$", [[None], [MODEL_AXIS]]),
    (r"shared/w_out$", [[MODEL_AXIS], [None]]),
    # --- attention ------------------------------------------------------
    (r"mixer/wq$", [[None], [MODEL_AXIS], [None]]),
    (r"mixer/w[kv]$", [[None], [MODEL_AXIS], [None]]),
    (r"mixer/wo$", [[MODEL_AXIS], [None], [None]]),
    (r"mixer/b[qkv]$", [[MODEL_AXIS], [None]]),
    # --- mamba ------------------------------------------------------------
    (r"mixer/in_[zx]$", [[None], [MODEL_AXIS]]),
    (r"mixer/in_(B|C|dt)$", [[None], [None]]),
    (r"mixer/conv_x$", [[None], [MODEL_AXIS]]),
    (r"mixer/conv_[BC]$", [[None], [None]]),
    (r"mixer/(A_log|D|dt_bias)$", [[MODEL_AXIS]]),
    (r"mixer/out$", [[MODEL_AXIS], [None]]),
    # --- dense MLP ---------------------------------------------------------
    (r"mlp/(w_in|w_gate)$", [[None], [MODEL_AXIS]]),
    (r"mlp/w_out$", [[MODEL_AXIS], [None]]),
    # --- norms & everything small -----------------------------------------
    (r"norm", [[None]] * 4),
)


def _embed_spec(path: str, shape, msize: int) -> Optional[P]:
    """Vocab-sharded embedding / head specs, ndim-aware (audio adds a
    leading/trailing codebook dim)."""
    def vm(d):
        return MODEL_AXIS if shape[d] % msize == 0 else None

    if re.search(r"(^|/)embed$", path):
        if len(shape) == 2:   # (V, E)
            return P(vm(0), None)
        if len(shape) == 3:   # (K, V, E)
            return P(None, vm(1), None)
    if re.search(r"(^|/)lm_head$", path):
        if len(shape) == 2:   # (E, V)
            return P(None, vm(1))
        if len(shape) == 3:   # (K, E, V)
            return P(None, None, vm(2))
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh,
              num_experts: Optional[int]) -> P:
    msize = mesh.shape.get(MODEL_AXIS, 1)

    es = _embed_spec(path, shape, msize)
    if es is not None:
        return es

    for pat, dims in _RULES:
        if re.search(pat, path):
            # Scanned block params carry a leading repeat dim — pad rule.
            offset = len(shape) - len(dims)
            if offset < 0:
                dims = dims[-len(shape):]
                offset = 0
            spec: list = [None] * len(shape)
            expert_sharded = False
            for i, cands in enumerate(dims):
                dim = offset + i
                for cand in cands:
                    if cand is None:
                        break
                    if cand == "expert_or_none":
                        if num_experts and shape[dim] == num_experts and shape[dim] % msize == 0:
                            spec[dim] = MODEL_AXIS
                            expert_sharded = True
                        break
                    if cand == "model_if_expert_failed":
                        if not expert_sharded and shape[dim] % msize == 0:
                            spec[dim] = MODEL_AXIS
                        break
                    if cand == "vocab_model":
                        if shape[dim] % msize == 0:
                            spec[dim] = MODEL_AXIS
                        break
                    if shape[dim] % mesh.shape.get(cand, 1) == 0:
                        spec[dim] = cand
                        break
            return P(*spec)
    return P()  # replicate by default


def param_shardings(params_shapes, mesh: Mesh, num_experts: Optional[int] = None,
                    *, fsdp: bool = False):
    """Tree of NamedSharding matching a tree of ShapeDtypeStruct/arrays.

    ``fsdp=True`` (§Perf lever, ZeRO-3-style): after tensor-parallel
    assignment, the largest remaining unsharded dim of every ≥2-dim
    parameter additionally shards over the data axes — GSPMD then
    all-gathers weights at use and reduce-scatters gradients, trading a
    little collective volume for an O(data)× cut in parameter/optimizer
    memory per device.
    """
    axes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def fn(path, leaf):
        spec = _spec_for(_path_str(path), tuple(leaf.shape), mesh, num_experts)
        if fsdp and leaf.ndim >= 2 and dsize > 1:
            parts = list(spec) + [None] * (leaf.ndim - len(spec))
            # skip dim 0 when it's a stacked-repeat axis (heuristic: the
            # rules never shard dim 0 of block params; embed handled fine)
            cands = sorted(
                (i for i in range(leaf.ndim)
                 if parts[i] is None and leaf.shape[i] % dsize == 0
                 and leaf.shape[i] >= dsize),
                key=lambda i: -leaf.shape[i],
            )
            if cands:
                parts[cands[0]] = axes if len(axes) > 1 else axes[0]
                spec = P(*parts)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(fn, params_shapes)


def batch_sharding(mesh: Mesh, batch: int, ndim: int) -> NamedSharding:
    """Shard the leading batch dim over ("pod","data") when divisible."""
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % total == 0:
        return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))
    return NamedSharding(mesh, P(*([None] * ndim)))


def kv_cache_spec(axis_sizes: dict, axes: Tuple[str, ...], batch: int,
                  cache_len: int, kv_heads: int) -> P:
    """Spec for (B, S_cache, Kv, Dh) decode caches (pure logic, testable).

    Policy: batch over data axes when divisible; KV heads over "model"
    when divisible. When the batch cannot shard (e.g. the batch=1
    long-context shape) the cache *sequence* shards over the data axes
    instead — distributed flash-decode (DESIGN.md §5).
    """
    total = int(np.prod([axis_sizes[a] for a in axes])) if axes else 1
    msize = axis_sizes.get(MODEL_AXIS, 1)
    if kv_heads % msize == 0:
        head_ax, seq_model = MODEL_AXIS, None
    else:
        # GQA kv-heads don't divide the model axis (kv=8 vs 16): shard the
        # cache *sequence* over "model" instead (distributed flash-decode;
        # replicating the KV over model blows past HBM — measured 46 GiB/dev
        # for qwen3 decode_32k before this rule).
        head_ax, seq_model = None, MODEL_AXIS if cache_len % msize == 0 else None
    if axes and total > 1 and batch % total == 0:
        return P(axes, seq_model, head_ax, None)
    if axes and total > 1 and cache_len % total == 0:
        # batch cannot shard (long-context B=1): sequence takes both axes
        seq_ax = (axes + (MODEL_AXIS,)) if seq_model else axes
        return P(None, seq_ax, head_ax, None)
    return P(None, seq_model, head_ax, None)


def kv_cache_sharding(mesh: Mesh, batch: int, cache_len: int, kv_heads: int):
    spec = kv_cache_spec(dict(mesh.shape), data_axes(mesh), batch,
                         cache_len, kv_heads)
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def sample_state_shardings(mesh: Mesh, batch: int, state_ndim: int):
    """Shardings for the adaptive-sampling carry (DESIGN.md §3).

    Returns ``(array, vector, replicated)`` NamedShardings: ``array`` for
    (B, ...) state tensors (x, x'_prev, noise), ``vector`` for per-sample
    (B,) scalars (t, h, nfe, accept/reject counters), ``replicated`` for
    the PRNG key and loop counters. The batch axis shards over the mesh's
    data axes when divisible; otherwise everything replicates, so the
    caller never has to special-case indivisible batches.
    """
    arr = batch_sharding(mesh, batch, state_ndim)
    vec = NamedSharding(mesh, P(arr.spec[0] if len(arr.spec) else None))
    return arr, vec, replicated(mesh)


def solver_carry_shardings(mesh: Mesh, batch: int, state_ndim: int,
                           *, per_slot_keys: bool = False, cond=None,
                           tolerances: bool = False,
                           telemetry: bool = False):
    """A ``SolverCarry``-shaped pytree of NamedShardings (DESIGN.md §7).

    ``state_ndim`` is the ndim of the (B, ...) state arrays. With
    ``per_slot_keys`` the (B, 2) key array shards over the batch axis
    alongside the state — each device owns its slots' noise streams, so
    shard-local slot compaction never touches another device's PRNG —
    otherwise the single (2,) key replicates.

    ``cond`` (DESIGN.md §9) is an abstract condition-payload pytree
    (arrays or ShapeDtypeStructs, every leaf leading with the batch
    dim, e.g. ``Conditioner.cond_struct(batch, shape)``); each leaf
    gets a batch-axis sharding of its own ndim, so condition payloads
    live on the device that owns their slot — the shard-local
    compaction rule extends to conditioning unchanged.

    ``tolerances`` (DESIGN.md §14) gives the per-slot ``atol``/``rtol``
    leaves the same (B,) vector sharding as t/h — tolerance classes are
    per-sample control state and live with their slot; False (the
    default) matches a carry with no tolerance leaves (the None pytree
    structure of the static-config path).

    ``telemetry`` (DESIGN.md §15) shards the step-telemetry ring's
    (B, cap) buffers over the batch axis — a slot's records live on the
    device that owns the slot, so shard-local compaction extends to
    telemetry rows unchanged — with the scalar head cursor replicated;
    False matches a telemetry-free carry (the None default).
    """
    from repro.core.solvers.adaptive import SolverCarry
    from repro.observability.telemetry import StepTelemetry

    arr, vec, rep = sample_state_shardings(mesh, batch, state_ndim)
    key_s = batch_sharding(mesh, batch, 2) if per_slot_keys else rep
    cond_s = jax.tree_util.tree_map(
        lambda l: batch_sharding(mesh, batch, l.ndim), cond,
    ) if cond is not None else None
    tol_s = vec if tolerances else None
    tel_s = None
    if telemetry:
        ring = batch_sharding(mesh, batch, 2)
        tel_s = StepTelemetry(t=ring, h=ring, err=ring, accept=ring,
                              head=rep)
    return SolverCarry(
        x=arr, x_prev=arr, t=vec, h=vec, key=key_s,
        nfe=vec, accepted=vec, rejected=vec, done=vec, iterations=rep,
        cond=cond_s, atol=tol_s, rtol=tol_s, telemetry=tel_s,
    )


def serving_loop_shardings(mesh: Mesh, batch: int, state_ndim: int,
                           *, per_slot_keys: bool = True, cond=None,
                           tolerances: bool = False,
                           telemetry: bool = False):
    """Donation-safe sharding pair for the device-resident serve loop
    (DESIGN.md §12): ``(carry_shardings, scalar_sharding)``.

    XLA only elides a donated buffer when the donated input and the
    matching output share one sharding, so the device-resident driver
    and event update must pin ``out_shardings`` to the *same*
    ``solver_carry_shardings`` tree the carry was placed with — a
    mismatched (e.g. inferred) output sharding would silently turn
    donation into a copy plus a resharding collective. The scalar
    sharding (replicated) covers the driver's event flag and any other
    per-call scalar riding next to the carry.
    """
    carry = solver_carry_shardings(
        mesh, batch, state_ndim, per_slot_keys=per_slot_keys, cond=cond,
        tolerances=tolerances, telemetry=telemetry,
    )
    return carry, replicated(mesh)
