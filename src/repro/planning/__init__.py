"""Trajectory-diffusion planning subsystem (DESIGN.md §10).

Temporal score networks over ``(B, H, D)`` trajectories
(``repro.models.temporal_unet``), returns/state-conditioned plan
generation built on the §9 conditioning seam, analytic environments,
and the receding-horizon closed loop served through the §7
``DiffusionBatcher``.
"""

from repro.planning.envs import ENVS, OUEnv, PointMassEnv, get_env
from repro.planning.planner import (
    NULL_RETURN,
    PlanConditioner,
    PlannerConfig,
    PlanRequest,
    RecedingHorizonPlanner,
    first_action,
    plan,
    plan_conditioner,
    returns_to_bin,
    state_pin,
)

__all__ = [
    "ENVS", "OUEnv", "PointMassEnv", "get_env",
    "NULL_RETURN", "PlanConditioner", "PlannerConfig", "PlanRequest",
    "RecedingHorizonPlanner", "first_action", "plan", "plan_conditioner",
    "returns_to_bin", "state_pin",
]
