"""Analytic environments for the receding-horizon planner (DESIGN.md §10).

No external simulator dependencies: both environments are a few lines
of jnp with closed-form dynamics, which is what lets the planner's
closed loop run inside tier-1 tests and CPU-only benchmarks. Both are
pure-functional: ``reset(key) -> obs`` and ``step(obs, action, key) ->
(obs, reward)``; state *is* the observation.

  * :class:`OUEnv` — controlled Ornstein–Uhlenbeck process: the action
    adds to the mean-reverting drift, noise is Brownian. Its stationary
    distribution is the Gaussian the analytic trajectory prior
    (``repro.core.analytic.gaussian_score``) models, so the planner's
    plans are draws from the right family even without a trained net.
  * :class:`PointMassEnv` — deterministic 2-D double integrator
    (position/velocity state, acceleration action) steering to a goal.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OUEnv:
    """Controlled OU process: ds = (−θ·s + a)·dt + σ·√dt·z.

    Reward is the negative quadratic state/action cost — the planner
    should hold the state near 0 with small actions.
    """

    obs_dim: int = 2
    theta: float = 1.0
    sigma: float = 0.2
    dt: float = 0.1
    act_cost: float = 0.1

    @property
    def act_dim(self) -> int:
        return self.obs_dim  # one actuator per state coordinate

    def reset(self, key: Array) -> Array:
        return self.sigma * jax.random.normal(key, (self.obs_dim,))

    def step(self, obs: Array, action: Array, key: Array):
        z = jax.random.normal(key, (self.obs_dim,))
        nxt = (obs + self.dt * (-self.theta * obs + action)
               + self.sigma * jnp.sqrt(self.dt) * z)
        reward = -(jnp.sum(nxt * nxt)
                   + self.act_cost * jnp.sum(action * action))
        return nxt, float(reward)


@dataclasses.dataclass(frozen=True)
class PointMassEnv:
    """Deterministic double integrator: obs = [pos, vel], action = accel.

    Reward is the negative squared distance to ``goal`` (plus a small
    velocity penalty so the optimum is to park there).
    """

    dim: int = 2
    dt: float = 0.1
    #: None → the origin in ``dim`` dimensions
    goal: tuple = None
    vel_cost: float = 0.05

    @property
    def obs_dim(self) -> int:
        return 2 * self.dim

    @property
    def act_dim(self) -> int:
        return self.dim

    def reset(self, key: Array) -> Array:
        pos = jax.random.normal(key, (self.dim,))
        return jnp.concatenate([pos, jnp.zeros((self.dim,))])

    def step(self, obs: Array, action: Array, key: Array = None):
        del key  # deterministic
        pos, vel = obs[: self.dim], obs[self.dim:]
        pos = pos + self.dt * vel
        vel = vel + self.dt * action
        goal = (jnp.zeros((self.dim,)) if self.goal is None
                else jnp.asarray(self.goal))
        err = pos - goal
        reward = -(jnp.sum(err * err) + self.vel_cost * jnp.sum(vel * vel))
        return jnp.concatenate([pos, vel]), float(reward)


ENVS = {"ou": OUEnv, "pointmass": PointMassEnv}


def get_env(name: str, **kw):
    name = name.lower()
    if name not in ENVS:
        raise ValueError(f"unknown env {name!r}; have {sorted(ENVS)}")
    return ENVS[name](**kw)
