"""Receding-horizon trajectory planning on the adaptive solver
(DESIGN.md §10).

Decision-diffuser-style planning is controlled generation over
``(B, H, D)`` trajectories (horizon H, transition width D = obs + act),
and this module is deliberately *thin*: every mechanism it needs
already exists in the conditioning seam (DESIGN.md §9) and the serving
stack (DESIGN.md §7). Song et al. (2021, App. I) reduce conditional
generation to a modified score field; here

  * **current-state conditioning** is inpainting along the horizon
    axis — the first ``context`` rows' observation coordinates are
    observed data, projected after every accepted step and pinned
    exactly at delivery;
  * **returns conditioning** is classifier-free guidance over
    discretized returns-to-go bins — ``ClassifierFree`` consuming the
    label payload of a returns-aware score (``temporal_unet`` with
    ``returns_bins > 0``, or the analytic class score);
  * :class:`PlanConditioner` composes the two (one static conditioner,
    one merged payload), and :func:`plan_conditioner` builds the
    (conditioner, payload) pair from an observation/returns pair —
    returning ``(None, None)`` when there is nothing to condition on,
    the bit-identical unconditional path.

:func:`plan` is the single-shot form (one adaptive solve per call);
:class:`RecedingHorizonPlanner` is the closed loop: plans are requests
in a ``DiffusionBatcher`` (DESIGN.md §7), each env executes the first
action of its delivered plan, and the *re-conditioned* request — same
request machinery, new pinned state — is re-admitted into a freed slot.
Per-slot keys and the carry-payload compaction rule are what make the
loop correct: a plan's trajectory depends only on its (seed, payload),
never on which slot it lands in or which envs share the batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveConfig, sample
from repro.core.guidance import ClassifierFree, Inpaint, cond_batch
from repro.core.solvers import SolveResult
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

Array = jax.Array

#: planning requests are ordinary batcher requests — same queue, same
#: slots, same compaction (DESIGN.md §10)
PlanRequest = ImageRequest

#: sentinel returns-bin meaning "unconditional" (the null CFG branch)
NULL_RETURN = -1


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Trajectory layout + conditioning knobs (DESIGN.md §10).

    A trajectory row h is ``[s_h, a_h]``: ``transition_dim = obs_dim +
    act_dim``. The first ``context`` rows' observation coordinates are
    the pinned (inpainted) current state; the executed action is row
    ``context - 1``'s action — the action taken *from* the newest
    pinned state.
    """

    horizon: int = 8
    obs_dim: int = 2
    act_dim: int = 2
    context: int = 1
    #: returns-CFG scale (0 = evaluate the null branch — bit-identical
    #: to unconditional for the zero-null-row nets, DESIGN.md §10)
    guidance_scale: float = 0.0
    null_label: int = NULL_RETURN

    @property
    def transition_dim(self) -> int:
        return self.obs_dim + self.act_dim

    @property
    def sample_shape(self) -> Tuple[int, int]:
        return (self.horizon, self.transition_dim)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class PlanConditioner(ClassifierFree):
    """Returns-CFG × current-state pinning, one conditioner
    (DESIGN.md §10).

    The score-field half is inherited from :class:`ClassifierFree`
    (``wrap_score`` consumes ``cond["label"]``; ``scale == 0`` is the
    single null-labeled forward). The projection half is verbatim
    :class:`Inpaint` — post-accept, at each slot's own new t, fp32
    under every precision preset, exact pin at delivery (DESIGN.md §9's
    project-after-accept rationale applies unchanged: the mask just
    happens to select horizon rows instead of pixels). The payload
    merges both: ``{"label": (B,), "mask"/"observed": (B, H, D)}``.
    """

    has_projection = True

    # the projection half is Inpaint's, bit for bit — these hooks only
    # read cond["mask"] / cond["observed"], which the merged payload has
    project = Inpaint.project
    finalize_project = Inpaint.finalize_project

    def cond_struct(self, batch: int, sample_shape) -> Any:
        shp = (batch,) + tuple(sample_shape)
        sds = jax.ShapeDtypeStruct(shp, jnp.float32)
        return {
            "label": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "mask": sds,
            "observed": sds,
        }

    def neutral_cond(self, batch: int, sample_shape) -> Any:
        """Null label (unconditional branch) + zero mask (identity
        projection) — the idle-slot payload (DESIGN.md §9)."""
        shp = (batch,) + tuple(sample_shape)
        return {
            "label": jnp.full((batch,), self.null_label, jnp.int32),
            "mask": jnp.zeros(shp, jnp.float32),
            "observed": jnp.zeros(shp, jnp.float32),
        }


def state_pin(pcfg: PlannerConfig, state) -> Dict[str, Array]:
    """Inpainting payload pinning the current state along the horizon
    axis (DESIGN.md §10): mask = 1 on the observation coordinates of
    the first ``context`` rows, ``observed`` carrying the state there.

    ``state`` is ``(B, obs_dim)`` (context = 1) or
    ``(B, context, obs_dim)``.
    """
    s = jnp.asarray(state, jnp.float32)
    if s.ndim == 2:
        s = s[:, None, :]
    b, ctx, od = s.shape
    if ctx != pcfg.context or od != pcfg.obs_dim:
        raise ValueError(
            f"state {s.shape[1:]} != (context, obs_dim) "
            f"({pcfg.context}, {pcfg.obs_dim})"
        )
    shp = (b,) + pcfg.sample_shape
    mask = jnp.zeros(shp, jnp.float32).at[:, :ctx, :od].set(1.0)
    observed = jnp.zeros(shp, jnp.float32).at[:, :ctx, :od].set(s)
    return {"mask": mask, "observed": observed}


def plan_conditioner(pcfg: PlannerConfig, *, state=None, returns=None):
    """(conditioner, payload) for a planning solve (DESIGN.md §10).

    ``state`` pins the current observation(s) via inpainting over the
    horizon axis; ``returns`` is an int ``(B,)`` vector of returns-to-go
    bin labels for classifier-free guidance at
    ``pcfg.guidance_scale``. Either may be None:

      * both None → ``(None, None)``: the bit-identical unconditional
        path (no conditioner object at all);
      * state only → plain :class:`Inpaint`;
      * returns only → plain :class:`ClassifierFree`;
      * both → :class:`PlanConditioner` with the merged payload.
    """
    if state is None and returns is None:
        return None, None
    if returns is None:
        return Inpaint(), state_pin(pcfg, state)
    labels = jnp.asarray(returns, jnp.int32)
    if state is None:
        return (
            ClassifierFree(scale=float(pcfg.guidance_scale),
                           null_label=pcfg.null_label),
            {"label": labels},
        )
    return (
        PlanConditioner(scale=float(pcfg.guidance_scale),
                        null_label=pcfg.null_label),
        {"label": labels, **state_pin(pcfg, state)},
    )


def returns_to_bin(returns, lo: float, hi: float, bins: int) -> Array:
    """Discretize returns-to-go into the embedding-table bins of a
    returns-aware score net (``TemporalUNetConfig.returns_bins``)."""
    r = jnp.asarray(returns, jnp.float32)
    idx = jnp.floor((r - lo) / (hi - lo) * bins)
    return jnp.clip(idx, 0, bins - 1).astype(jnp.int32)


def plan(
    sde,
    score_fn,
    obs,
    key: Array,
    *,
    pcfg: PlannerConfig,
    returns=None,
    config: AdaptiveConfig | None = None,
    mesh=None,
    batch: int | None = None,
    **overrides,
) -> SolveResult:
    """One planning solve: sample ``(B, H, D)`` trajectories with the
    adaptive solver, conditioned on the current observation(s) ``obs``
    (``(B, obs_dim)``; None → unconditional prior plans) and optional
    returns-to-go bin labels (DESIGN.md §10).

    The delivered trajectories have the pinned coordinates equal to
    ``obs`` exactly (``finalize_project``); read the executed action
    with :func:`first_action`. The score must be label-aware
    (``s(x, t, y)``) whenever ``returns`` is given.
    """
    conditioner, cond = plan_conditioner(pcfg, state=obs, returns=returns)
    cfg = config or AdaptiveConfig(eps_rel=0.05)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if conditioner is not None:
        cfg = dataclasses.replace(cfg, conditioner=conditioner)
    if cond is not None:
        payload_batch = cond_batch(cond)
        if batch is not None and batch != payload_batch:
            raise ValueError(
                f"batch={batch} disagrees with the condition payload's "
                f"batch dim {payload_batch}")
        batch = payload_batch
    elif batch is None:
        raise ValueError("unconditional plan() needs an explicit batch=")
    return sample(sde, score_fn, (batch,) + pcfg.sample_shape, key,
                  method="adaptive", config=cfg, cond=cond, mesh=mesh)


def first_action(x, pcfg: PlannerConfig):
    """Executed action of a delivered plan: row ``context − 1``'s action
    coordinates — the action taken from the newest pinned state.
    Accepts ``(H, D)`` or ``(B, H, D)``."""
    row = pcfg.context - 1
    return x[..., row, pcfg.obs_dim: pcfg.obs_dim + pcfg.act_dim]


class RecedingHorizonPlanner:
    """Closed-loop planner serving on the diffusion batcher
    (DESIGN.md §10).

    Each environment's plan is an ordinary :class:`PlanRequest` in a
    :class:`DiffusionBatcher` whose conditioner is a
    :class:`PlanConditioner` (or plain :class:`Inpaint` when returns
    guidance is off). One control round:

      1. every env submits a request whose payload pins its *current*
         observation (and carries its returns bin);
      2. the batcher drains — converged plans retire at sync horizons,
         survivors compact shard-locally, queued requests admit into
         freed slots (envs > slots exercises real queueing);
      3. each env executes :func:`first_action` of its delivered plan
         against the analytic environment and the *re-conditioned*
         request (new pinned state, fresh uid/seed) re-enters the queue
         next round.

    Per-slot keys + the §9 payload-compaction rule make every delivered
    plan bit-identical to a standalone ``adaptive()`` solve of the same
    (seed, payload) — re-admission can never perturb a neighbour —
    which ``tests/test_planning.py`` asserts along with exact
    per-request NFE accounting.
    """

    def __init__(
        self,
        sde,
        forward_fn,
        params,
        pcfg: PlannerConfig,
        env,
        *,
        cfg: AdaptiveConfig | None = None,
        slots: int = 4,
        sync_horizon: int = 4,
        compaction: bool = True,
        mesh=None,
        tracer=None,
    ):
        from repro.launch.sample import make_sample_step

        self.pcfg = pcfg
        self.env = env
        if env.obs_dim != pcfg.obs_dim or env.act_dim != pcfg.act_dim:
            raise ValueError(
                f"env dims ({env.obs_dim}, {env.act_dim}) != planner "
                f"({pcfg.obs_dim}, {pcfg.act_dim})"
            )
        base = cfg or AdaptiveConfig(eps_rel=0.05)
        if base.conditioner is None:
            base = dataclasses.replace(
                base,
                conditioner=PlanConditioner(
                    scale=float(pcfg.guidance_scale),
                    null_label=pcfg.null_label,
                ),
            )
        self.cfg = base
        # the device step is built HERE, from the same final cfg the
        # batcher gets — a step compiled without the conditioner would
        # silently skip the in-loop projection while delivery still
        # pinned, exactly the kind of mismatch one constructor prevents.
        # ``forward_fn(params, x, t, y=None)`` is noise-prediction
        # (score = −out/std), label-aware when returns guidance is on.
        # precision threads the same way: the batcher derives its slot
        # dtype from this cfg's policy, so pass AdaptiveConfig(precision=
        # ...) rather than a separate policy that could diverge
        sample_step = make_sample_step(None, sde, base, forward_fn=forward_fn)
        self.batcher = DiffusionBatcher(
            sde, sample_step, params, pcfg.sample_shape,
            slots=slots, cfg=base, mesh=mesh,
            sync_horizon=sync_horizon, compaction=compaction,
            # one tracer through planner rounds AND the batcher's
            # admission/solve/delivery stages (DESIGN.md §15), so a
            # plan/round span brackets the serve spans it caused
            tracer=tracer,
        )
        self._uid = 0

    def request_cond(self, obs, returns_label: Optional[int] = None):
        """Unbatched per-request payload rows (DESIGN.md §9), shaped by
        the server conditioner's own ``cond_struct``: the pin mask /
        observation for this env's current state and/or its returns bin
        (None → the null label) — so Inpaint-only and CFG-only
        conditioners get exactly the keys they declare."""
        struct = self.cfg.conditioner.cond_struct(1, self.pcfg.sample_shape)
        if returns_label is not None and "label" not in struct:
            raise ValueError(
                f"returns_label={returns_label} given but the server "
                f"conditioner {type(self.cfg.conditioner).__name__} carries "
                f"no label payload — the guidance would be silently dropped")
        pin = state_pin(self.pcfg, jnp.asarray(obs)[None])
        label = (self.pcfg.null_label if returns_label is None
                 else int(returns_label))
        rows = {"label": jnp.int32(label), **{k: v[0] for k, v in pin.items()}}
        unknown = set(struct) - set(rows)
        if unknown:
            raise ValueError(
                f"server conditioner declares payload keys {sorted(unknown)} "
                f"the planner cannot fill (have {sorted(rows)})")
        return {k: rows[k] for k in struct}

    def rollout(
        self,
        key: Array,
        *,
        n_envs: int,
        n_steps: int,
        returns_label: Optional[int] = None,
        seed0: int = 0,
    ) -> Dict[str, Any]:
        """Run ``n_envs`` environments for ``n_steps`` control rounds
        through the shared batcher; returns rewards, per-request NFE,
        and the batcher's waste accounting (DESIGN.md §10)."""
        keys = jax.random.split(key, n_envs + 1)
        obs = [self.env.reset(keys[i + 1]) for i in range(n_envs)]
        step_key = keys[0]
        rewards = np.zeros((n_steps, n_envs))
        nfes = np.zeros((n_steps, n_envs), np.int64)
        for round_i in range(n_steps):
            with self.batcher.tracer.span(
                "plan/round", round=round_i, envs=n_envs
            ) as sp:
                uids = []
                for i in range(n_envs):
                    uid = seed0 + self._uid
                    self._uid += 1
                    self.batcher.submit(PlanRequest(
                        uid=uid, seed=uid,
                        cond=self.request_cond(obs[i], returns_label),
                    ))
                    uids.append(uid)
                sp["attrs"]["uids"] = list(uids)
                done = self.batcher.run_to_completion()
                for i, uid in enumerate(uids):
                    req = done[uid]
                    a = np.asarray(first_action(req.result, self.pcfg))
                    step_key, k = jax.random.split(step_key)
                    obs[i], r = self.env.step(obs[i], jnp.asarray(a), k)
                    rewards[round_i, i] = r
                    nfes[round_i, i] = req.nfe
        b = self.batcher
        return {
            "rewards": rewards,
            "nfe": nfes,
            "finished": b.finished,
            "total_iterations": b.total_iterations,
            "wasted_nfe_fraction": b.wasted_nfe_fraction,
            "passenger_nfe_fraction": b.passenger_nfe_fraction,
            "refills_per_device": list(b.refills_per_device),
        }
