"""Continuous-batching diffusion sampling server.

The paper's per-sample step sizes (Sec. 3.1.5) mean each sample in a
batch finishes its reverse diffusion at its own NFE. In a serving
context that is exactly the continuous-batching opportunity: run a fixed
slot batch of Algorithm-1 state, and whenever a slot's t reaches t_eps,
deliver the image and refill the slot with a fresh prior draw for the
next request — no request ever waits for the batch's slowest sample.

Horizon-chunked solve (DESIGN.md §7): the device step is the solver's
own ``solve_chunk`` over a ``SolverCarry`` with *per-slot* PRNG keys —
``sync_horizon`` Algorithm-1 iterations run device-side per host
round-trip, then the host retires converged slots, compacts survivors,
and admits queued requests into the freed slots (fresh prior draw at
t = T under the request's own key). Because every slot owns its noise
stream, a sample's trajectory is invariant to which slot it occupies
and to what its seatmates do — compaction and admission never perturb
in-flight samples.

Throughput math (DESIGN.md §4): naive batched sampling costs max_i NFE_i
per batch of requests; slot refill costs ~mean_i NFE_i — the gap grows
with the per-sample NFE spread the paper's adaptivity creates. The
``wasted_nfe_fraction`` property measures the residual waste: the share
of issued score-net evaluations that served idle or already-converged
slots.

Mesh scale-out (DESIGN.md §3): pass ``mesh=`` to shard the slot batch
over the mesh's data axes. Each device then owns a contiguous block of
``slots / device_count`` slots and compaction is *shard-local*: slots
are only ever permuted within their device's block, so no sample (or
its PRNG key) ever crosses a shard boundary. ``refills_per_device``
records the per-device admission counts.

Device-resident hot path (DESIGN.md §12): with ``device_resident=True``
the per-horizon polling loop itself moves on-device. A jitted driver
(``solve_horizons``-shaped ``lax.while_loop`` with the slot carry
*donated*) chains sync-horizon chunks until a serving event — a pending
delivery — fires, and the host reads back exactly one scalar
``events_pending`` flag per driver call. Only when the flag is set does
the host pull the (B,) bookkeeping + retired rows, compute the
compaction permutation and admissions, and apply them through a second
jitted, donated event update (gather by permutation, masked admission
scatter, on-device prior draws from per-request keys). Host↔device
traffic is O(delivered requests), not O(sync horizons); delivered
samples are bit-identical to the host-driven loop because per-slot keys
make trajectories invariant to slot placement and sync timing.

Device step = repro.launch.sample.make_sample_step (the same
``solve_chunk`` unit the production-mesh dry-run lowers); the host loop
only watches t and swaps slots.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveConfig
from repro.core.precision import resolve_policy
from repro.core.sde import SDE
from repro.core.solvers import solver_nfe_per_iteration
from repro.core.solvers.adaptive import SolverCarry, events_pending
from repro.observability.metrics import MetricsRegistry
from repro.observability.telemetry import (
    StepTelemetry, init_telemetry, telemetry_history,
)
from repro.observability.tracing import NULL_TRACER, profiler_annotation
from repro.serving.scheduler import (
    AdmissionPolicy, FifoAdmission, TierAccounting, tier_name,
)

Array = jax.Array


@dataclasses.dataclass
class ImageRequest:
    """One sampling request (DESIGN.md §4/§9/§14): a seed, optionally a
    per-request condition payload for the server's conditioner, and —
    on a tiered server — a tolerance class, deadline, and priority."""

    uid: int
    seed: int
    #: per-request condition payload (DESIGN.md §9): the *unbatched*
    #: pytree this request's slot row should carry (e.g. ``{"mask":
    #: (H, W, C), "observed": (H, W, C)}`` or ``{"label": ()}``). None
    #: (with a conditioner configured) means the neutral payload —
    #: zero mask / label 0, i.e. effectively unconditional.
    cond: Any = None
    #: tolerance class (DESIGN.md §14): a preset name from
    #: ``repro.configs.diffusion.TOLERANCE_CLASSES`` (or the server's
    #: own registry) or a ``ToleranceClass``. None = the server's
    #: static-config tolerance (the pre-tier behaviour).
    tier: Any = None
    #: latency budget in milliseconds from submission; None = no
    #: deadline (a tier's own ``deadline_ms`` applies if set)
    deadline_ms: Optional[float] = None
    #: admission band, lower = more urgent; None defers to the tier's
    #: ``priority`` (0 for untiered requests)
    priority: Optional[int] = None
    result: Optional[np.ndarray] = None
    nfe: int = 0
    done: bool = False
    #: set at delivery: did this request outlive its deadline?
    deadline_missed: bool = False
    #: device iterations spent occupying a slot (admission → retirement);
    #: nfe_per_iter·resident_iters − nfe is this request's
    #: frozen-passenger waste
    resident_iters: int = 0
    #: per-request accept/reject counts (DESIGN.md §15), pulled with the
    #: NFE at retirement from the same carry bookkeeping — for the
    #: Algorithm-1 families nfe == nfe_per_iter·(accepted + rejected),
    #: the identity the telemetry reconciliation test pins
    accepted: int = 0
    rejected: int = 0
    #: absolute deadline on the server's clock, stamped at submit()
    deadline_at: Optional[float] = dataclasses.field(default=None, repr=False)
    _admit_iters: int = dataclasses.field(default=0, repr=False)
    _submit_t: float = dataclasses.field(default=0.0, repr=False)
    _seat_t: float = dataclasses.field(default=0.0, repr=False)


class DiffusionBatcher:
    """Slot-compacting sampler around a pjit-able ``solve_chunk`` step.

    ``sync_horizon`` sets how many Algorithm-1 iterations run device-side
    between host syncs (1 = the classic per-step loop; larger horizons
    amortize host round-trips at the cost of up to horizon-1 iterations
    of retirement latency per converged slot).

    ``compaction=True`` (default) retires converged slots and admits
    queued requests at every sync horizon. ``compaction=False`` is the
    monolithic-wave baseline: the batch only turns over once *every*
    occupied slot has converged — exactly the "wait for all images"
    semantics of the paper's batched loop, kept for A/B measurement
    (benchmarks/bench_compaction.py).

    ``policy`` (DESIGN.md §8) sets the slot carry's state dtype; it
    defaults to ``cfg.precision`` so the carry matches what the
    ``sample_step`` built from the same cfg expects. Retirement,
    compaction, and admission are dtype-agnostic — admitted priors are
    cast to the carry's dtype, and the host only ever reads the fp32
    control fields plus the retired rows.

    Conditioning (DESIGN.md §9): when ``cfg.conditioner`` is set, the
    carry grows a per-slot condition payload (``SolverCarry.cond``).
    Idle slots hold the conditioner's neutral payload; at admission a
    request's own ``ImageRequest.cond`` is written into its slot's
    rows, and compaction moves condition leaves with their samples —
    shard-locally, exactly like the per-slot PRNG keys — so a
    request's conditioning follows it through any slot permutation.

    Tolerance tiers (DESIGN.md §14): ``tolerance_classes`` turns on
    per-request quality tiers — the carry grows per-slot ``atol``/
    ``rtol`` leaves so every seated request solves at its own class's
    tolerance inside one fused device step; ``admission`` picks which
    queued requests take free slots (FIFO default; EDF within priority
    bands via ``scheduler.EdfPriorityAdmission``) and ``delivery``
    accumulates per-class NFE + deadline-miss counters at the ``_d2h``
    accounting seam (``class_stats``). Left off, the carry keeps the
    exact pre-tier pytree structure and the serve loop is bitwise
    identical to the static-config stack.

    ``device_resident=True`` (DESIGN.md §12) replaces the per-horizon
    host round-trip with the on-device multi-horizon driver: up to
    ``max_horizons`` sync-horizon chunks run per host visit, the carry
    buffers are donated to both the driver and the event update, and
    the host reads one scalar event flag per driver call (see module
    docstring). ``host_transfers`` counts every device→host read the
    serve loop issues — the metric bench_device_serving.py reports.

    ``solver``/``solver_kwargs`` name the solver family the
    ``sample_step`` runs so waste accounting can convert loop
    iterations to issued score-net evaluations via the registry's
    ``solver_nfe_per_iteration`` (hardcoding the adaptive family's 2
    made ``wasted_nfe_fraction`` negative for e.g. ``pc_hmc``, which
    issues ``1 + corrector_steps·hmc_leapfrog`` per iteration).
    """

    def __init__(
        self,
        sde: SDE,
        sample_step: Callable,  # (params, carry, max_sync_iters=N) -> carry
        params,
        sample_shape,           # per-sample shape, e.g. (16, 16, 3)
        *,
        slots: int = 8,
        cfg: AdaptiveConfig | None = None,
        mesh=None,
        sync_horizon: int = 1,
        compaction: bool = True,
        policy=None,
        device_resident: bool = False,
        max_horizons: int = 32,
        solver: str = "adaptive",
        solver_kwargs: Optional[dict] = None,
        tolerance_classes=None,
        admission: Optional[AdmissionPolicy] = None,
        delivery=None,
        clock: Optional[Callable[[], float]] = None,
        telemetry: int = 0,
        tracer=None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.sde = sde
        self.cfg = cfg or AdaptiveConfig()
        self.policy = resolve_policy(
            policy if policy is not None else self.cfg.precision
        )
        self.params = params
        self.n = slots
        self.shape = tuple(sample_shape)
        self.mesh = mesh
        self.sync_horizon = int(sync_horizon)
        self.compaction = bool(compaction)
        self.device_resident = bool(device_resident)
        self.max_horizons = int(max_horizons)
        self.solver = solver
        #: score-net evaluations one device loop iteration issues over
        #: the full slot batch, from the solver registry (DESIGN.md §7)
        self.nfe_per_iter = solver_nfe_per_iteration(
            solver, **(solver_kwargs or {})
        )
        #: tiered serving (DESIGN.md §14): truthy grows the carry per-slot
        #: ``atol``/``rtol`` leaves so each seated request solves at its
        #: own tolerance; a dict is this server's name → ToleranceClass
        #: registry (default: the ``configs.diffusion`` presets). False
        #: keeps the exact pre-tier carry structure — the static-config
        #: path stays bitwise identical.
        self.tiered = bool(tolerance_classes)
        self.tolerance_classes = (
            tolerance_classes if isinstance(tolerance_classes, dict) else None
        )
        #: admission stage (DESIGN.md §14): which queued requests take
        #: free slots. FIFO = the pre-policy behaviour, exactly.
        self.admission = admission if admission is not None else FifoAdmission()
        #: delivery stage: per-class NFE + deadline accounting at the
        #: ``_d2h`` seam (anything with ``on_deliver(req, now)``)
        self.delivery = delivery if delivery is not None else TierAccounting()
        self._clock = clock if clock is not None else time.monotonic
        #: step-telemetry ring capacity per slot (DESIGN.md §15): > 0
        #: grows the carry a ``StepTelemetry`` ring so the device loop
        #: records every iteration's (t, h, err, accept) per slot; 0
        #: (the default) keeps the exact pre-telemetry carry treedef and
        #: serve loop, bit for bit
        self.telemetry_capacity = int(telemetry)
        #: stage tracer (DESIGN.md §15): spans around the admission /
        #: solve / delivery stages with request-id attrs; the default
        #: NULL_TRACER records nothing and reads no clock
        self.tracer = tracer if tracer is not None else NULL_TRACER
        #: metrics registry (DESIGN.md §15): every serve-loop counter —
        #: iterations, useful/resident NFE, host transfers, accept /
        #: reject totals, the delivery stage's per-tier series — lives
        #: here; the legacy attribute names below read through to it
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_iters = self.metrics.counter("serve_iterations_total")
        self._c_useful = self.metrics.counter("serve_nfe_useful_total")
        self._c_resident = self.metrics.counter("serve_nfe_resident_total")
        self._c_transfers = self.metrics.counter("serve_host_transfers_total")
        self._c_accept = self.metrics.counter("serve_accepted_total")
        self._c_reject = self.metrics.counter("serve_rejected_total")
        if hasattr(self.delivery, "bind"):
            # seam unification (DESIGN.md §15): the delivery stage's
            # per-tier books and the fold-and-reset waste books write
            # one shared registry, so they can be asserted consistent
            self.delivery.bind(self.metrics)
        #: the static-config tolerance a tier-less request rides — same
        #: resolution rule as ``solve_chunk`` (sde-calibrated eps_abs
        #: unless the config pins one)
        self._default_atol = float(
            sde.abs_tolerance if self.cfg.eps_abs is None else self.cfg.eps_abs
        )
        self._default_rtol = float(self.cfg.eps_rel)
        self._default_h0 = min(float(self.cfg.h_init), sde.T - sde.t_eps)
        self.conditioner = self.cfg.conditioner
        cond_struct = (
            None if self.conditioner is None
            else self.conditioner.cond_struct(slots, self.shape)
        )
        if mesh is not None:
            from repro.parallel.sharding import (
                data_axes, solver_carry_shardings,
            )

            axes = data_axes(mesh)
            self.n_devices = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            if slots % self.n_devices != 0:
                raise ValueError(
                    f"slots={slots} must divide across {self.n_devices} devices"
                )
            self._carry_shardings = solver_carry_shardings(
                mesh, slots, 1 + len(self.shape), per_slot_keys=True,
                cond=cond_struct, tolerances=self.tiered,
                telemetry=self.telemetry_capacity > 0,
            )
            self.step_fn = jax.jit(
                lambda p, c: sample_step(p, c, max_sync_iters=self.sync_horizon),
                out_shardings=self._carry_shardings,
            )
        else:
            self.n_devices = 1
            self._carry_shardings = None
            self.step_fn = jax.jit(
                lambda p, c: sample_step(p, c, max_sync_iters=self.sync_horizon)
            )
        self.slots_per_device = slots // self.n_devices
        #: per-device count of queue→slot assignments (includes the
        #: initial fill); shows admission proceeding independently per device
        self.refills_per_device: List[int] = [0] * self.n_devices
        self.queue: Deque[ImageRequest] = deque()
        self.finished: Dict[int, ImageRequest] = {}
        self._slot_req: List[Optional[ImageRequest]] = [None] * slots
        #: driver calls (device-resident) / step() chunks (host-driven)
        self.horizon_windows = 0
        #: host mirror of the carry's device iteration counter, so the
        #: host-driven step() needs one read per chunk, not two
        self._host_iters = 0
        B = slots
        zi = jnp.zeros((B,), jnp.int32)
        self._carry = SolverCarry(
            x=jnp.zeros((B,) + self.shape, self.policy.state),
            x_prev=jnp.zeros((B,) + self.shape, self.policy.state),
            t=jnp.zeros((B,), jnp.float32),    # 0 = idle/converged
            h=jnp.full((B,), self.cfg.h_init, jnp.float32),
            key=jnp.zeros((B, 2), jnp.uint32),  # per-slot noise streams
            nfe=zi, accepted=zi, rejected=zi,
            done=jnp.ones((B,), bool),
            iterations=jnp.asarray(0, jnp.int32),
            # idle slots carry the neutral payload (zero mask / label 0)
            cond=(None if self.conditioner is None
                  else self.conditioner.neutral_cond(B, self.shape)),
            # tiered: idle slots hold the default-class tolerance; the
            # admission scatter overwrites admitted rows (DESIGN.md §14)
            atol=(jnp.full((B,), self._default_atol, jnp.float32)
                  if self.tiered else None),
            rtol=(jnp.full((B,), self._default_rtol, jnp.float32)
                  if self.tiered else None),
            # telemetry ring (DESIGN.md §15): capacity 0 keeps the exact
            # pre-telemetry treedef, so the off path retraces nothing
            telemetry=(init_telemetry(B, self.telemetry_capacity)
                       if self.telemetry_capacity > 0 else None),
        )
        self._carry = self._shard_carry(self._carry)
        self._occupied = None
        self._driver_fn = None
        self._event_fn = None
        if self.device_resident:
            # donation demands distinct buffers per leaf: the fresh carry
            # aliases its zero-init leaves (and jnp.zeros constant-caches),
            # which XLA rejects as donating the same buffer twice
            self._carry = jax.tree_util.tree_map(
                lambda a: jnp.array(a, copy=True), self._carry
            )
            self._build_device_loop(sample_step)
            self._set_occupied()

    # ------------------------------------------------------------------
    def _d2h(self, tree):
        """The serve loop's single device→host seam: every read crosses
        here (counted), so transfer accounting — and the regression test
        pinning the device-resident path to O(events) — sees all of
        them. One call = one logical sync, however many leaves ride in
        the pytree."""
        self._c_transfers.inc()
        return jax.device_get(tree)

    def _h2d_vec(self, arr):
        """Upload a (B,)-ish host array with the carry's vector
        sharding (no-op placement without a mesh)."""
        arr = jnp.asarray(arr)
        if self._carry_shardings is not None:
            arr = jax.device_put(arr, self._carry_shardings.done)
        return arr

    def _set_occupied(self) -> None:
        """Mirror host slot occupancy into the device-side (B,) mask the
        driver's ``events_pending`` consults (idle slots ride with
        done=True, so the device cannot derive occupancy from the carry)."""
        self._occupied = self._h2d_vec(
            np.array([r is not None for r in self._slot_req])
        )

    def _build_device_loop(self, sample_step: Callable) -> None:
        """Jit the two device-resident stages (DESIGN.md §12).

        The *driver* chains sync-horizon chunks in a ``lax.while_loop``
        until an event is pending (or ``max_horizons`` chunks ran, so a
        straggler-bound wave still returns control), and returns the
        carry plus the scalar event flag — the sole per-call read. The
        *event update* applies one host decision batch entirely
        on-device: gather every carry leaf by the compaction
        permutation, then overwrite admitted rows with fresh prior draws
        (vmapped over the admitted requests' own prior keys — bit-
        identical to the host's per-key draws), reset their control
        fields, and install their noise keys. Both donate the carry, so
        the (B, ...) state buffers are reused in place rather than
        copied per call. The admission inputs are fixed-shape full-B
        buffers (mask + key rows) to keep a single trace; only the
        *condition payload* rows are scattered host-side afterwards —
        admission payloads stay per-request (ragged pytrees, not worth a
        trace per admission-count), see DESIGN.md §12.
        """
        wait_all = not self.compaction

        def driver(params, carry, occupied):
            def cond(state):
                c, n = state
                running = jnp.any(
                    jnp.logical_and(occupied, jnp.logical_not(c.done))
                )
                no_event = jnp.logical_not(
                    events_pending(c, occupied, wait_all=wait_all)
                )
                return running & no_event & (n < self.max_horizons)

            def body(state):
                c, n = state
                c = sample_step(params, c, max_sync_iters=self.sync_horizon)
                return c, n + 1

            carry, _ = jax.lax.while_loop(
                cond, body, (carry, jnp.asarray(0, jnp.int32))
            )
            return carry, events_pending(carry, occupied, wait_all=wait_all)

        def event_update(carry, perm, admit_mask, prior_keys, noise_keys,
                         admit_atol=None, admit_rtol=None, admit_h=None):
            # the three trailing (B,) fp32 buffers are the tiered
            # admission's per-request tolerance/step rows (DESIGN.md
            # §14); the untiered server never passes them, so its trace
            # and donation layout are unchanged
            def upd(leaf, admit):
                leaf = jnp.take(leaf, perm, axis=0)
                m = admit_mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
                return jnp.where(m, admit, leaf)

            priors = jax.vmap(
                lambda k: self.sde.prior_sample(k, self.shape)
            )(prior_keys).astype(carry.x.dtype)
            h0 = min(self.cfg.h_init, self.sde.T - self.sde.t_eps)
            return SolverCarry(
                x=upd(carry.x, priors),
                x_prev=upd(carry.x_prev, priors),
                t=upd(carry.t, jnp.float32(self.sde.T)),
                h=upd(carry.h,
                      jnp.float32(h0) if admit_h is None else admit_h),
                key=upd(carry.key, noise_keys),
                nfe=upd(carry.nfe, 0),
                accepted=upd(carry.accepted, 0),
                rejected=upd(carry.rejected, 0),
                done=upd(carry.done, False),
                # fold-and-reset: the host adds the pulled counter to
                # total_iterations at every event, so the device counter
                # restarts (and cfg.max_iters never trips on a
                # long-lived server)
                iterations=jnp.asarray(0, jnp.int32),
                cond=(None if carry.cond is None else
                      jax.tree_util.tree_map(
                          lambda l: jnp.take(l, perm, axis=0), carry.cond
                      )),
                atol=(None if carry.atol is None
                      else upd(carry.atol, admit_atol)),
                rtol=(None if carry.rtol is None
                      else upd(carry.rtol, admit_rtol)),
                # telemetry rows travel with their sample, permute-only
                # (DESIGN.md §15): admission does NOT clear rows —
                # records are globally iteration-stamped and age out by
                # ring wrap, keeping the ring's aggregate accept/reject
                # sums exactly reconcilable with delivered requests
                telemetry=(None if carry.telemetry is None else
                           StepTelemetry(
                               t=jnp.take(carry.telemetry.t, perm, axis=0),
                               h=jnp.take(carry.telemetry.h, perm, axis=0),
                               err=jnp.take(carry.telemetry.err, perm, axis=0),
                               accept=jnp.take(
                                   carry.telemetry.accept, perm, axis=0),
                               head=carry.telemetry.head,
                           )),
            )

        if self._carry_shardings is not None:
            from repro.parallel.sharding import serving_loop_shardings

            cond_struct = (None if self.conditioner is None else
                           self.conditioner.cond_struct(self.n, self.shape))
            carry_s, flag_s = serving_loop_shardings(
                self.mesh, self.n, 1 + len(self.shape),
                per_slot_keys=True, cond=cond_struct,
                tolerances=self.tiered,
                telemetry=self.telemetry_capacity > 0,
            )
            self._driver_fn = jax.jit(
                driver, donate_argnums=(1,),
                out_shardings=(carry_s, flag_s),
            )
            self._event_fn = jax.jit(
                event_update, donate_argnums=(0,),
                out_shardings=carry_s,
            )
        else:
            self._driver_fn = jax.jit(driver, donate_argnums=(1,))
            self._event_fn = jax.jit(event_update, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _shard_carry(self, carry: SolverCarry) -> SolverCarry:
        if self._carry_shardings is None:
            return jax.tree_util.tree_map(jnp.asarray, carry)
        return jax.tree_util.tree_map(
            lambda a, s: jax.device_put(jnp.asarray(a), s),
            carry, self._carry_shardings,
        )

    def slot_device(self, slot: int) -> int:
        """Mesh data-axis index owning ``slot`` (contiguous block
        layout, DESIGN.md §3)."""
        return slot // self.slots_per_device

    def _request_cond(self, req: ImageRequest):
        """An admitted request's per-sample condition rows: its own
        ``cond`` (leaves shaped like ``cond_struct`` minus the batch
        dim; scalars allowed for (B,) leaves) coerced to the payload
        dtypes, or the conditioner's *neutral* payload (DESIGN.md §9 —
        e.g. the null label for CFG, never class 0)."""
        if req.cond is None:
            return jax.tree_util.tree_map(
                lambda l: l[0], self.conditioner.neutral_cond(1, self.shape)
            )
        struct = self.conditioner.cond_struct(1, self.shape)
        return jax.tree_util.tree_map(
            lambda s, l: jnp.asarray(l, s.dtype).reshape(s.shape[1:]),
            struct, req.cond,
        )

    def _resolve_tier(self, tier):
        """Tier name / ToleranceClass → ToleranceClass, against this
        server's registry (or the ``configs.diffusion`` presets)."""
        from repro.configs.diffusion import ToleranceClass, resolve_tier

        if isinstance(tier, ToleranceClass):
            return tier
        if self.tolerance_classes is not None:
            if tier in self.tolerance_classes:
                return self.tolerance_classes[tier]
            raise KeyError(
                f"unknown tolerance class {tier!r}; this server registers "
                f"{sorted(self.tolerance_classes)}"
            )
        return resolve_tier(tier)

    def _request_tol(self, req: ImageRequest):
        """An admitted request's (atol, rtol, h0) floats (DESIGN.md §14):
        its tolerance class with None fields deferring to the serving
        config / SDE defaults — a tier-less request rides exactly the
        static-config values."""
        if req.tier is None:
            return self._default_atol, self._default_rtol, self._default_h0
        tier = self._resolve_tier(req.tier)
        atol = self._default_atol if tier.eps_abs is None else float(tier.eps_abs)
        h = self.cfg.h_init if tier.h_init is None else tier.h_init
        return atol, float(tier.eps_rel), min(
            float(h), self.sde.T - self.sde.t_eps
        )

    def submit(self, req: ImageRequest) -> None:
        """Queue a request; it enters a slot at the next sync horizon
        with a free slot (DESIGN.md §7). Stamps the submission clock and
        resolves the request's deadline/priority from its tolerance
        class (DESIGN.md §14) so the admission policy orders on settled
        values."""
        if req.tier is not None and not self.tiered:
            raise ValueError(
                f"request {req.uid} carries tier {req.tier!r} but this "
                "server was built without tolerance_classes — its carry "
                "has no per-slot tolerance leaves to honour it"
            )
        now = self._clock()
        req._submit_t = now
        tier = None if req.tier is None else self._resolve_tier(req.tier)
        if req.priority is None:
            req.priority = 0 if tier is None else int(tier.priority)
        deadline_ms = req.deadline_ms
        if deadline_ms is None and tier is not None:
            deadline_ms = tier.deadline_ms
        req.deadline_at = (
            None if deadline_ms is None else now + deadline_ms / 1000.0
        )
        self.queue.append(req)

    # -- serve-loop counters (DESIGN.md §15): the books live in the
    # metrics registry; these legacy names read through to it ----------
    @property
    def total_iterations(self) -> int:
        """Total device loop iterations executed (each costs nfe_per_iter
        score-net forwards over the full slot batch, busy or not)."""
        return int(self._c_iters.value)

    @property
    def useful_nfe(self) -> int:
        """Σ per-request NFE actually delivered — the useful fraction of
        nfe_per_iter · slots · total_iterations issued evaluations."""
        return int(self._c_useful.value)

    @property
    def resident_nfe(self) -> int:
        """Σ nfe_per_iter·resident_iters over delivered requests:
        evaluations issued to *occupied* slots (excludes never-occupied
        idle capacity)."""
        return int(self._c_resident.value)

    @property
    def host_transfers(self) -> int:
        """Device→host reads the serve loop issued (every one goes
        through ``_d2h``); the device-resident path keeps this
        O(delivered requests) instead of O(sync horizons)."""
        return int(self._c_transfers.value)

    @property
    def class_stats(self) -> Dict[str, Any]:
        """Per-tolerance-class delivery counters (DESIGN.md §14) as
        plain dicts — mean NFE, deadline misses, queue wait — from the
        delivery stage's accounting at the ``_d2h`` seam."""
        return {name: s.as_dict() for name, s in self.delivery.stats.items()}

    @property
    def wasted_nfe_fraction(self) -> float:
        """Fraction of issued score-net evaluations spent on idle or
        already-converged slots so far (0 when nothing ran yet) —
        DESIGN.md §7 waste accounting. Issued evaluations are
        ``nfe_per_iter · slots · total_iterations``, with the
        per-iteration factor taken from the solver registry for the
        family this batcher runs (a hardcoded 2 is only right for the
        Algorithm-1 families and e.g. went *negative* for ``pc_hmc``,
        whose iterations each issue ``1 + corrector_steps·L``)."""
        issued = self.nfe_per_iter * self.n * self.total_iterations
        if issued == 0:
            return 0.0
        return 1.0 - min(self.useful_nfe, issued) / issued

    @property
    def passenger_nfe_fraction(self) -> float:
        """Fraction of evaluations issued to *occupied* slots whose sample
        had already converged — the paper's frozen-passenger waste, the
        part of ``wasted_nfe_fraction`` that only compaction (not capacity
        provisioning) can remove (DESIGN.md §7). 0 when nothing was
        delivered yet."""
        if self.resident_nfe == 0:
            return 0.0
        return 1.0 - min(self.useful_nfe, self.resident_nfe) / self.resident_nfe

    # ------------------------------------------------------------------
    def _retire(self, rows, nfe, acc, rej, conv_idx) -> None:
        """Deliver the already-transferred retired rows: fill in each
        request, move it to ``finished``, free its slot, and charge the
        waste accounting (shared by the host-driven and device-resident
        paths)."""
        now = self._clock()
        with self.tracer.span(
            "serve/delivery",
            uids=[self._slot_req[i].uid for i in conv_idx],
            slots=list(conv_idx),
            nfe=[int(nfe[i]) for i in conv_idx],
        ):
            for row, i in zip(rows, conv_idx):
                req = self._slot_req[i]
                req.result = row
                req.nfe = int(nfe[i])
                req.accepted = int(acc[i])
                req.rejected = int(rej[i])
                req.done = True
                req.resident_iters = self.total_iterations - req._admit_iters
                self.finished[req.uid] = req
                self._c_useful.inc(int(nfe[i]))
                self._c_resident.inc(self.nfe_per_iter * req.resident_iters)
                self._c_accept.inc(int(acc[i]))
                self._c_reject.inc(int(rej[i]))
                self._slot_req[i] = None
                # delivery stage (DESIGN.md §14): per-class NFE + deadline
                # accounting rides the rows already pulled through _d2h
                self.delivery.on_deliver(req, now)

    def _admit_from_queue(self):
        """Seat queued requests in free slots (host bookkeeping only —
        the slot-state writes are the caller's, per path). The admission
        stage picks *which* queued requests go (FIFO by default, EDF-
        within-priority-bands via ``EdfPriorityAdmission``); the chosen
        are seated lowest-free-slot-first. Returns the admitted (slot
        index, request) lists."""
        free = [i for i in range(self.n) if self._slot_req[i] is None]
        if not free or not self.queue:
            return [], []
        now = self._clock()
        with self.tracer.span(
            "serve/admission", free=len(free), queued=len(self.queue)
        ) as sp:
            reqs = self.admission.select(self.queue, len(free), now)
            admit_pos = free[: len(reqs)]
            for i, req in zip(admit_pos, reqs):
                self._slot_req[i] = req
                req._admit_iters = self.total_iterations
                req._seat_t = now
                self.refills_per_device[self.slot_device(i)] += 1
            # request-id propagation (DESIGN.md §15): the admission span
            # names exactly the uids seated and the slots they took
            sp["attrs"]["uids"] = [r.uid for r in reqs]
            sp["attrs"]["slots"] = list(admit_pos)
        return admit_pos, reqs

    def _compaction_perm(self) -> np.ndarray:
        """Shard-local compaction permutation: within each device's
        contiguous slot block, pack the surviving in-flight samples to
        the front (slots never cross a block = shard boundary). Also
        reorders ``_slot_req`` to match. Identity when compaction is
        off."""
        perm = np.arange(self.n)
        if self.compaction:
            for d in range(self.n_devices):
                lo = d * self.slots_per_device
                hi = lo + self.slots_per_device
                block = list(range(lo, hi))
                live = [i for i in block if self._slot_req[i] is not None]
                free = [i for i in block if self._slot_req[i] is None]
                perm[lo:hi] = live + free
            self._slot_req = [self._slot_req[j] for j in perm]
        return perm

    def _sync(self) -> None:
        """Host sync: retire converged slots, compact, admit from queue.

        Only (B,)-sized bookkeeping and the *retired rows* of x cross the
        device↔host boundary; the compaction permutation and slot
        admissions are applied device-side (gather + row scatters), so
        the big (B, ...) state never round-trips through the host.
        """
        c = self._carry
        # the device's own convergence mask — using anything else (e.g. a
        # host-side t threshold) can disagree with the loop's active mask
        # and make retirement depend on the sync horizon
        done = self._d2h(c.done)
        occupied = [r is not None for r in self._slot_req]
        conv = [occupied[i] and bool(done[i]) for i in range(self.n)]
        if not self.compaction and occupied != conv and any(occupied):
            # monolithic-wave baseline: the batch only turns over once
            # every occupied slot has converged
            return
        if not any(conv) and not (self.queue and not all(occupied)):
            return

        # 1. deliver converged slots: transfer only those rows. Samples
        #    are delivered at the t_eps state, pre-Tweedie-denoise — the
        #    batcher holds only the fused sample_step, not a standalone
        #    score_fn, so the paper's +1-NFE denoise epilogue is the
        #    caller's (cf. sample()/finalize(denoise=True))
        conv_idx = [i for i in range(self.n) if conv[i]]
        if conv_idx:
            # delivery is always fp32 regardless of the state dtype
            rows_j = c.x[jnp.asarray(conv_idx)].astype(jnp.float32)
            if self.conditioner is not None:
                # exact, noise-free constraint replacement on delivery
                # (DESIGN.md §9): e.g. inpainting pins observed pixels
                # to the observation, matching the finalize() contract
                cond_rows = jax.tree_util.tree_map(
                    lambda l: l[jnp.asarray(conv_idx)], c.cond
                )
                rows_j = self.conditioner.finalize_project(rows_j, cond_rows)
            rows, nfe, acc, rej = self._d2h(
                (rows_j, c.nfe, c.accepted, c.rejected)
            )
            self._retire(rows, nfe, acc, rej, conv_idx)

        # 2. shard-local compaction: each sample's per-slot key moves
        #    with it, so trajectories are unchanged by the permutation.
        perm = self._compaction_perm()
        permute = not np.array_equal(perm, np.arange(self.n))

        # 3. admit queued requests into freed slots: fresh prior draw at
        #    t = T under the request's own key — per-slot keys mean the
        #    admission cannot perturb any in-flight trajectory. The
        #    request's condition payload (or the neutral one) is written
        #    into the same rows (DESIGN.md §9).
        admit_pos, reqs = self._admit_from_queue()
        priors, noise_keys, conds = [], [], []
        for req in reqs:
            k_prior, k_noise = jax.random.split(jax.random.PRNGKey(req.seed))
            priors.append(self.sde.prior_sample(k_prior, self.shape))
            noise_keys.append(k_noise)
            if self.conditioner is not None:
                conds.append(self._request_cond(req))

        # a retired-but-unrefilled slot needs no explicit marking: the
        # device loop already left it at t ≤ t_eps with done=True, which
        # is exactly the chunk predicate's idle state
        def update(leaf, admit_val=None):
            if permute:
                leaf = jnp.take(leaf, jnp.asarray(perm), axis=0)
            if admit_pos and admit_val is not None:
                leaf = leaf.at[jnp.asarray(admit_pos)].set(admit_val)
            return leaf

        x_admit = jnp.stack(priors).astype(c.x.dtype) if admit_pos else None
        h0 = min(self.cfg.h_init, self.sde.T - self.sde.t_eps)
        # tiered admission (DESIGN.md §14): each admitted request's
        # tolerance-class (atol, rtol, h0) rows scatter into the same
        # positions as its prior/key rows; untiered servers keep the
        # scalar h0 write below, bit for bit
        tol_a = tol_r = tol_h = None
        if self.tiered and admit_pos:
            tols = [self._request_tol(r) for r in reqs]
            tol_a = jnp.asarray([t[0] for t in tols], jnp.float32)
            tol_r = jnp.asarray([t[1] for t in tols], jnp.float32)
            tol_h = jnp.asarray([t[2] for t in tols], jnp.float32)
        # condition leaves move with their samples (permute + row scatter
        # like every other per-slot leaf — the DESIGN.md §9 compaction
        # rule: payloads travel shard-locally, like keys)
        cond_new = c.cond
        if c.cond is not None:
            if admit_pos:
                cond_admit = jax.tree_util.tree_map(
                    lambda *rows: jnp.stack(rows), conds[0], *conds[1:]
                )
                cond_new = jax.tree_util.tree_map(
                    lambda leaf, av: update(leaf, admit_val=av.astype(leaf.dtype)),
                    c.cond, cond_admit,
                )
            else:
                cond_new = jax.tree_util.tree_map(update, c.cond)
        self._carry = self._shard_carry(SolverCarry(
            x=update(c.x, admit_val=x_admit),
            x_prev=update(c.x_prev, admit_val=x_admit),
            t=update(c.t, admit_val=jnp.float32(self.sde.T)),
            h=update(c.h,
                     admit_val=jnp.float32(h0) if tol_h is None else tol_h),
            key=update(c.key,
                       admit_val=jnp.stack(noise_keys) if admit_pos else None),
            nfe=update(c.nfe, admit_val=jnp.int32(0)),
            accepted=update(c.accepted, admit_val=jnp.int32(0)),
            rejected=update(c.rejected, admit_val=jnp.int32(0)),
            done=update(c.done, admit_val=False),
            # the carry's iteration counter is per-chunk in serving: fold
            # it into the host total and reset so cfg.max_iters never
            # trips on a long-lived server
            iterations=jnp.asarray(0, jnp.int32),
            cond=cond_new,
            atol=(update(c.atol, admit_val=tol_a) if self.tiered else None),
            rtol=(update(c.rtol, admit_val=tol_r) if self.tiered else None),
            # telemetry rows permute with their sample and are never
            # cleared at admission (DESIGN.md §15) — see event_update
            telemetry=(None if c.telemetry is None else StepTelemetry(
                t=update(c.telemetry.t), h=update(c.telemetry.h),
                err=update(c.telemetry.err),
                accept=update(c.telemetry.accept),
                head=c.telemetry.head,
            )),
        ))
        self._host_iters = 0

    # ------------------------------------------------------------------
    def _process_events(self, deliver: bool = True) -> None:
        """Device-resident event handler (DESIGN.md §12): one host visit
        that retires, compacts, and admits in a single donated device
        update.

        ``deliver=False`` is the admission-only form (new submissions
        into already-free slots — no delivery pending, so the (B,)
        convergence bookkeeping is not pulled; only the iteration
        counter is folded). All device→host reads go through ``_d2h``:
        one bookkeeping pull, plus one retired-rows pull when something
        converged — O(events), never O(horizons).
        """
        c = self._carry
        if deliver:
            done, nfe, acc, rej, iters = self._d2h(
                (c.done, c.nfe, c.accepted, c.rejected, c.iterations)
            )
        else:
            iters = self._d2h(c.iterations)
            done = np.zeros(self.n, bool)
            acc = rej = None
        # fold-and-reset (cf. event_update): the device counter restarts
        # at every host visit, so add it exactly once here
        self._c_iters.inc(int(iters))
        self._host_iters = 0
        occupied = [r is not None for r in self._slot_req]
        conv_idx = [i for i in range(self.n) if occupied[i] and bool(done[i])]
        if conv_idx:
            rows_j = c.x[jnp.asarray(conv_idx)].astype(jnp.float32)
            if self.conditioner is not None:
                cond_rows = jax.tree_util.tree_map(
                    lambda l: l[jnp.asarray(conv_idx)], c.cond
                )
                rows_j = self.conditioner.finalize_project(rows_j, cond_rows)
            self._retire(self._d2h(rows_j), nfe, acc, rej, conv_idx)

        perm = self._compaction_perm()
        permute = not np.array_equal(perm, np.arange(self.n))
        can_admit = self.compaction or not any(
            r is not None for r in self._slot_req
        )
        admit_pos, reqs = self._admit_from_queue() if can_admit else ([], [])
        if permute or admit_pos:
            admit_mask = np.zeros(self.n, bool)
            admit_mask[admit_pos] = True
            keys = [jax.random.split(jax.random.PRNGKey(r.seed)) for r in reqs]
            kbuf = lambda rows: (
                jnp.zeros((self.n, 2), jnp.uint32)
                .at[jnp.asarray(admit_pos, jnp.int32)]
                .set(jnp.stack(rows)) if admit_pos
                else jnp.zeros((self.n, 2), jnp.uint32)
            )
            ops = [
                self._carry,
                self._h2d_vec(perm.astype(np.int32)),
                self._h2d_vec(admit_mask),
                kbuf([k[0] for k in keys]),  # prior keys → on-device draws
                kbuf([k[1] for k in keys]),  # per-slot noise streams
            ]
            if self.tiered:
                # per-request tolerance rows ride the same fixed-shape
                # full-B buffer pattern as the key rows (DESIGN.md §14)
                tols = [self._request_tol(r) for r in reqs]

                def fbuf(vals):
                    buf = np.zeros(self.n, np.float32)
                    if admit_pos:
                        buf[admit_pos] = vals
                    return self._h2d_vec(buf)

                ops += [fbuf([t[0] for t in tols]),
                        fbuf([t[1] for t in tols]),
                        fbuf([t[2] for t in tols])]
            self._carry = self._event_fn(*ops)
            if self.conditioner is not None and admit_pos:
                # admission payloads stay per-request: the ragged cond
                # rows are scattered outside the fixed-shape event jit
                # (DESIGN.md §12)
                rows = [self._request_cond(r) for r in reqs]
                cond_admit = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), rows[0], *rows[1:]
                )
                idx = jnp.asarray(admit_pos, jnp.int32)
                self._carry = dataclasses.replace(
                    self._carry,
                    cond=jax.tree_util.tree_map(
                        lambda leaf, av: leaf.at[idx].set(av.astype(leaf.dtype)),
                        self._carry.cond, cond_admit,
                    ),
                )
        elif int(iters):
            # nothing moved, but the pulled counter was folded above —
            # restart the device counter so it is never double-counted
            self._carry = dataclasses.replace(
                c, iterations=jnp.asarray(0, jnp.int32)
            )
        self._set_occupied()

    def _device_step(self) -> int:
        """One device-resident window: ≤ max_horizons · sync_horizon
        iterations per host visit, one scalar event-flag read."""
        occupied = [r is not None for r in self._slot_req]
        if self.queue and not all(occupied) and (
                self.compaction or not any(occupied)):
            # admission is host knowledge (queue + occupancy): seat the
            # newcomers before launching the driver — no slot frees up
            # mid-driver, so there is nothing to poll for
            self._process_events(deliver=False)
        busy = sum(1 for r in self._slot_req if r is not None)
        if busy == 0:
            return 0
        ann = (profiler_annotation("serve/solve", step=self.horizon_windows)
               if self.tracer.enabled else contextlib.nullcontext())
        with self.tracer.span(
            "serve/solve", window=self.horizon_windows, busy=busy
        ), ann:
            self._carry, ev = self._driver_fn(
                self.params, self._carry, self._occupied
            )
            ev = bool(self._d2h(ev))
        self.horizon_windows += 1
        if ev:
            self._process_events()
        return busy

    def step(self) -> int:
        """One serve-loop turn; returns the number of busy slots
        entering the device work. Host-driven: one sync-horizon chunk
        (≤ sync_horizon device iterations, DESIGN.md §7).
        Device-resident: one driver window (DESIGN.md §12)."""
        if self.device_resident:
            return self._device_step()
        self._sync()
        busy = sum(1 for r in self._slot_req if r is not None)
        if busy == 0:
            return 0
        ann = (profiler_annotation("serve/solve", step=self.horizon_windows)
               if self.tracer.enabled else contextlib.nullcontext())
        with self.tracer.span(
            "serve/solve", window=self.horizon_windows, busy=busy
        ), ann:
            self._carry = self.step_fn(self.params, self._carry)
            cur = int(self._d2h(self._carry.iterations))
        self.horizon_windows += 1
        self._c_iters.inc(cur - self._host_iters)
        self._host_iters = cur
        return busy

    def run_to_completion(self, max_steps: int = 100_000) -> Dict[int, ImageRequest]:
        """Drain the queue: step until every submitted request is
        delivered (DESIGN.md §4/§7 serving loop)."""
        steps = 0
        while (self.queue or any(r is not None for r in self._slot_req)) \
                and steps < max_steps:
            if self.step() == 0 and not self.queue:
                break
            steps += 1
        # deliver stragglers
        if self.device_resident:
            self._process_events()
        else:
            self._sync()
        return self.finished

    # ------------------------------------------------------------------
    def metrics_snapshot(self) -> MetricsRegistry:
        """Refresh the point-in-time serve gauges (queue depth,
        occupancy, waste fractions, acceptance rate — DESIGN.md §15)
        and return the registry; the counters are already live."""
        m = self.metrics
        m.gauge("serve_queue_depth").set(float(len(self.queue)))
        m.gauge("serve_slots_occupied").set(
            float(sum(1 for r in self._slot_req if r is not None))
        )
        m.gauge("serve_slots_total").set(float(self.n))
        m.gauge("serve_wasted_nfe_fraction").set(self.wasted_nfe_fraction)
        m.gauge("serve_passenger_nfe_fraction").set(
            self.passenger_nfe_fraction
        )
        acc = self._c_accept.value
        rej = self._c_reject.value
        m.gauge("serve_acceptance_rate").set(
            acc / (acc + rej) if (acc + rej) else 0.0
        )
        m.gauge("serve_horizon_windows").set(float(self.horizon_windows))
        return m

    def trace_record(self) -> Dict[str, Any]:
        """One JSON-ready record of everything this server observed
        (DESIGN.md §15): delivered requests with their per-request NFE /
        accept / reject books, the metrics registry, the tracer's spans
        and per-stage latency histograms, the per-class delivery stats,
        and — when the telemetry ring is on — the drained chronological
        step history (``repro.analysis.telemetry`` renders this record
        as the markdown report)."""
        self.metrics_snapshot()
        requests = [
            {
                "uid": r.uid,
                "tier": tier_name(r),
                "nfe": r.nfe,
                "accepted": r.accepted,
                "rejected": r.rejected,
                "resident_iters": r.resident_iters,
                "deadline_missed": bool(r.deadline_missed),
            }
            for r in sorted(self.finished.values(), key=lambda r: r.uid)
        ]
        rec: Dict[str, Any] = {
            "requests": requests,
            "metrics": self.metrics.to_json(),
            "trace": self.tracer.to_json(),
            "class_stats": self.class_stats,
        }
        if self._carry.telemetry is not None:
            hist = telemetry_history(self._d2h(self._carry.telemetry))
            rec["telemetry"] = {
                "t": hist["t"].tolist(),
                "h": hist["h"].tolist(),
                "err": hist["err"].tolist(),
                "accept": hist["accept"].astype(int).tolist(),
                "iterations": int(hist["iterations"]),
                "records": int(hist["records"]),
                "t_eps": float(self.sde.t_eps),
            }
        return rec
