"""Continuous-batching diffusion sampling server.

The paper's per-sample step sizes (Sec. 3.1.5) mean each sample in a
batch finishes its reverse diffusion at its own NFE. In a serving
context that is exactly the continuous-batching opportunity: run a fixed
slot batch of Algorithm-1 state, and whenever a slot's t reaches t_eps,
deliver the image and refill the slot with a fresh prior draw for the
next request — no request ever waits for the batch's slowest sample.

Throughput math (DESIGN.md §4): naive batched sampling costs max_i NFE_i
per batch of requests; slot refill costs ~mean_i NFE_i — the gap grows
with the per-sample NFE spread the paper's adaptivity creates.

Mesh scale-out (DESIGN.md §3): pass ``mesh=`` to shard the slot batch
over the mesh's data axes. Each device then owns a contiguous block of
``slots / device_count`` slots, the jit'd step runs fully data-parallel
(no resharding, no cross-device traffic in the elementwise math), and
slot refill remains per-slot — i.e. it happens independently on every
device, so one device's finished slots never stall another device's
in-flight samples. ``refills_per_device`` records that independence.

Device step = repro.launch.sample.make_sample_step (the same unit the
production-mesh dry-run lowers); the host loop only watches t and swaps
slots.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveConfig
from repro.core.sde import SDE

Array = jax.Array


@dataclasses.dataclass
class ImageRequest:
    uid: int
    seed: int
    result: Optional[np.ndarray] = None
    nfe: int = 0
    done: bool = False


class DiffusionBatcher:
    """Slot-refilling sampler around a pjit-able Algorithm-1 step."""

    def __init__(
        self,
        sde: SDE,
        sample_step: Callable,  # (params, state) -> state (from make_sample_step)
        params,
        sample_shape,           # per-sample shape, e.g. (16, 16, 3)
        *,
        slots: int = 8,
        cfg: AdaptiveConfig | None = None,
        mesh=None,
    ):
        self.sde = sde
        self.cfg = cfg or AdaptiveConfig()
        self.params = params
        self.n = slots
        self.shape = tuple(sample_shape)
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.sharding import data_axes, sample_state_shardings

            axes = data_axes(mesh)
            self.n_devices = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            if slots % self.n_devices != 0:
                raise ValueError(
                    f"slots={slots} must divide across {self.n_devices} devices"
                )
            arr_s, vec_s, rep_s = sample_state_shardings(
                mesh, slots, 1 + len(self.shape)
            )
            self._state_shardings = (arr_s, arr_s, vec_s, vec_s, rep_s)
            self.step_fn = jax.jit(sample_step, out_shardings=self._state_shardings)
        else:
            self.n_devices = 1
            self._state_shardings = None
            self.step_fn = jax.jit(sample_step)
        self.slots_per_device = slots // self.n_devices
        #: per-device count of queue→slot assignments (includes the
        #: initial fill); shows refill proceeding independently per device
        self.refills_per_device: List[int] = [0] * self.n_devices
        self.queue: Deque[ImageRequest] = deque()
        self.finished: Dict[int, ImageRequest] = {}
        self._slot_req: List[Optional[ImageRequest]] = [None] * slots
        B = slots
        self._state = (
            jnp.zeros((B,) + self.shape, jnp.float32),   # x
            jnp.zeros((B,) + self.shape, jnp.float32),   # x_prev
            jnp.zeros((B,), jnp.float32),                # t (0 = idle)
            jnp.full((B,), self.cfg.h_init, jnp.float32),
            jax.random.PRNGKey(0),
        )
        self._state = self._shard_state(self._state)

    def _shard_state(self, state):
        if self._state_shardings is None:
            return state
        return tuple(
            jax.device_put(a, s) for a, s in zip(state, self._state_shardings)
        )

    def slot_device(self, slot: int) -> int:
        """Mesh data-axis index owning ``slot`` (contiguous block layout)."""
        return slot // self.slots_per_device

    def submit(self, req: ImageRequest) -> None:
        self.queue.append(req)

    def _refill(self) -> None:
        x, xp, t, h, key = self._state
        tn = np.asarray(t)
        changed = False
        x_host = None
        for i in range(self.n):
            if self._slot_req[i] is not None and tn[i] <= self.sde.t_eps + 1e-9:
                # deliver (final Tweedie denoise is a host-side epilogue
                # amortized per delivery — one extra NFE, as in the paper)
                if x_host is None:
                    x_host = np.asarray(x)
                req = self._slot_req[i]
                req.result = x_host[i]
                req.done = True
                self.finished[req.uid] = req
                self._slot_req[i] = None
            if self._slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                self._slot_req[i] = req
                self.refills_per_device[self.slot_device(i)] += 1
                k = jax.random.PRNGKey(req.seed)
                x = x.at[i].set(
                    self.sde.prior_sample(k, self.shape).astype(x.dtype))
                xp = xp.at[i].set(x[i])
                t = t.at[i].set(self.sde.T)
                h = h.at[i].set(min(self.cfg.h_init,
                                    self.sde.T - self.sde.t_eps))
                changed = True
        if changed or x_host is not None:
            self._state = self._shard_state((x, xp, t, h, key))

    def step(self) -> int:
        """One device step; returns number of busy slots."""
        self._refill()
        busy = sum(1 for r in self._slot_req if r is not None)
        if busy == 0:
            return 0
        self._state = self.step_fn(self.params, self._state)
        for i, r in enumerate(self._slot_req):
            if r is not None:
                r.nfe += 2
        return busy

    def run_to_completion(self, max_steps: int = 100_000) -> Dict[int, ImageRequest]:
        steps = 0
        while (self.queue or any(r is not None for r in self._slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        self._refill()  # deliver stragglers
        return self.finished
