"""Continuous-batching decode scheduler (vLLM-style slots, pure JAX step).

The device step is the same pjit'd ``serve_step`` the dry-run lowers —
fixed batch of SLOTS; the host-side scheduler multiplexes requests onto
slots as they arrive/finish. The per-slot independence mirrors the
paper's per-sample step sizes (Sec. 3.1.5): nobody waits for the slowest
sequence, a finished slot is immediately re-filled.

Mechanics:
  * one shared ring-buffer KV/SSM state of shape (slots, cache_len, …);
  * per-slot position counters live in the cache's ``length``… which is
    *global* in LayerKVCache (lockstep writes). Continuous batching
    therefore gives each slot its own logical stream by masking: a slot
    joining at global step g treats g as its position 0 — valid because
    attention masks by stored absolute positions, and a fresh request's
    prompt replay overwrites its slot's visibility window.
  * to keep slot isolation EXACT (no stale-KV leakage across requests),
    a slot reset invalidates its cache rows via the per-slot validity
    mask maintained here and applied as an extra attention mask.

For the full framework this module provides the host orchestration +
bookkeeping and an end-to-end greedy-decode service loop over reduced
configs (tests + example); the step function is unchanged production
code.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_serve_step
from repro.models import init_decode_state
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) or (P, K) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    remaining_prompt: Deque[int] = dataclasses.field(default_factory=deque)
    new_tokens: int = 0

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Greedy continuous-batching decode over a fixed slot batch."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 cache_len: int = 256):
        assert cfg.num_codebooks == 1, "scheduler demo covers 1-codebook LMs"
        assert all(m != "M" for m in cfg.mixer_pattern), (
            "continuous batching isolates slots by masking KV positions; "
            "SSM state cannot be masked retroactively — use dedicated "
            "batches for SSM archs"
        )
        self.cfg = cfg
        self.params = params
        self.slots = [_Slot() for _ in range(slots)]
        self.n_slots = slots
        self.cache_len = cache_len
        self.state = init_decode_state(cfg, slots, cache_len)
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        # token each slot feeds next step (pad with 0 for free slots)
        self._next_input = np.zeros((slots,), np.int32)
        # global step counter == cache.length; per-slot request start
        self._global_step = 0
        self._start_pos = np.zeros((slots,), np.int32)
        # occupancy accounting, mirroring DiffusionBatcher's wasted-NFE
        # metrics (DESIGN.md §7): every device step costs a full
        # slots-wide forward whether slots are occupied or not. The LM
        # decode step is inherently one token per host sync (the sampled
        # token feeds the next step), so there is no horizon to chunk —
        # but the waste metric is the same shape.
        self.total_steps = 0
        self.useful_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _assign_free_slots(self) -> None:
        for slot in self.slots:
            if slot.free and self.queue:
                req = self.queue.popleft()
                slot.request = req
                slot.remaining_prompt = deque(int(t) for t in np.asarray(req.prompt))
                slot.new_tokens = 0
                i = self.slots.index(slot)
                self._next_input[i] = slot.remaining_prompt.popleft()
                # isolation: this slot only sees KV from its own request
                self._start_pos[i] = self._global_step

    def _advance_slot(self, i: int, sampled: int) -> None:
        slot = self.slots[i]
        req = slot.request
        if req is None:
            return
        if slot.remaining_prompt:
            # still prefilling (by replay): ignore the sample, feed prompt
            self._next_input[i] = slot.remaining_prompt.popleft()
            return
        # decoding: the sampled token is an output
        req.output.append(sampled)
        slot.new_tokens += 1
        hit_eos = req.eos_id is not None and sampled == req.eos_id
        if slot.new_tokens >= req.max_new_tokens or hit_eos:
            req.done = True
            self.finished[req.uid] = req
            slot.request = None
            self._next_input[i] = 0
        else:
            self._next_input[i] = sampled

    @property
    def wasted_step_fraction(self) -> float:
        """Fraction of issued slot-steps that served free slots — the
        decode-side analog of DiffusionBatcher.wasted_nfe_fraction."""
        issued = self.n_slots * self.total_steps
        if issued == 0:
            return 0.0
        return 1.0 - self.useful_steps / issued

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One device step for all slots; returns #active slots."""
        self._assign_free_slots()
        active = sum(0 if s.free else 1 for s in self.slots)
        if active == 0:
            return 0
        self.total_steps += 1
        self.useful_steps += active
        toks = jnp.asarray(self._next_input)[:, None]
        batch = {"tokens": toks, "start_pos": jnp.asarray(self._start_pos)}
        next_tok, self.state = self.step_fn(self.params, batch, self.state)
        self._global_step += 1
        sampled = np.asarray(jax.device_get(next_tok))[:, 0]
        for i in range(self.n_slots):
            self._advance_slot(i, int(sampled[i]))
        return active

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while (self.queue or any(not s.free for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
