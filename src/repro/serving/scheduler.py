"""Continuous-batching schedulers: the LM decode scheduler (vLLM-style
slots, pure JAX step) and the serving-stage policy seam (DESIGN.md §14)
shared with the diffusion batcher — pluggable admission ordering
(FIFO / deadline-priority EDF) and the per-class delivery accounting
stage.

The device step is the same pjit'd ``serve_step`` the dry-run lowers —
fixed batch of SLOTS; the host-side scheduler multiplexes requests onto
slots as they arrive/finish. The per-slot independence mirrors the
paper's per-sample step sizes (Sec. 3.1.5): nobody waits for the slowest
sequence, a finished slot is immediately re-filled.

Mechanics:
  * one shared ring-buffer KV/SSM state of shape (slots, cache_len, …);
  * per-slot position counters live in the cache's ``length``… which is
    *global* in LayerKVCache (lockstep writes). Continuous batching
    therefore gives each slot its own logical stream by masking: a slot
    joining at global step g treats g as its position 0 — valid because
    attention masks by stored absolute positions, and a fresh request's
    prompt replay overwrites its slot's visibility window.
  * to keep slot isolation EXACT (no stale-KV leakage across requests),
    a slot reset invalidates its cache rows via the per-slot validity
    mask maintained here and applied as an extra attention mask.

For the full framework this module provides the host orchestration +
bookkeeping and an end-to-end greedy-decode service loop over reduced
configs (tests + example); the step function is unchanged production
code.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_serve_step
from repro.models import init_decode_state
from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Serving-stage policy seam (DESIGN.md §14). The serve loop decomposes
# into admission → solve → delivery stages; the solve stage is the jitted
# device program (sample_step / driver), these classes are the pluggable
# host-side halves. They are duck-typed over request objects exposing
# ``priority`` (int band, lower = more urgent), ``deadline_at`` (absolute
# clock time or None), ``_submit_t`` (submission clock time) and ``uid``
# — both ``ImageRequest`` and any future request type qualify.
# ---------------------------------------------------------------------------


class AdmissionPolicy:
    """Admission stage: choose which queued requests take free slots.

    The base policy is FIFO — pop in submission order — which preserves
    the pre-policy batcher behaviour exactly (and is what the bitwise
    serving-identity gates pin). ``select`` removes the chosen requests
    from ``queue`` and returns them in seating order; the caller assigns
    them to free slots lowest-index first.
    """

    def select(self, queue: Deque, n_free: int, now: float) -> List:
        chosen = []
        while queue and len(chosen) < n_free:
            chosen.append(queue.popleft())
        return chosen


#: explicit name for the default stage (reads better at call sites)
class FifoAdmission(AdmissionPolicy):
    pass


@dataclasses.dataclass
class EdfPriorityAdmission(AdmissionPolicy):
    """Earliest-deadline-first within priority bands (DESIGN.md §14).

    Ordering key: (effective priority band, deadline, submission time,
    uid) — bands are never inverted, and within a band the request whose
    deadline expires soonest is seated first (no-deadline requests sort
    after every deadlined one in their band; submission time breaks
    ties, keeping the policy FIFO among equals).

    ``aging_s`` is the anti-starvation lever: a request's effective band
    drops by one for every ``aging_s`` seconds it has waited, without a
    floor — so under a saturating flood of urgent short-deadline
    traffic, any waiting request eventually occupies a band *below*
    every fresh arrival and must be seated. None disables aging (pure
    static bands; a saturated top band then starves lower ones — the
    property suite demonstrates both behaviours).
    """

    aging_s: Optional[float] = None

    def order_key(self, req, now: float):
        band = req.priority
        if self.aging_s is not None and self.aging_s > 0:
            band -= int(max(0.0, now - req._submit_t) / self.aging_s)
        deadline = math.inf if req.deadline_at is None else req.deadline_at
        return (band, deadline, req._submit_t, req.uid)

    def select(self, queue: Deque, n_free: int, now: float) -> List:
        ranked = sorted(queue, key=lambda r: self.order_key(r, now))
        chosen = ranked[:n_free]
        for r in chosen:
            queue.remove(r)
        return chosen


@dataclasses.dataclass
class TierStats:
    """Per-tolerance-class delivery counters (DESIGN.md §14), accumulated
    at the batcher's ``_d2h`` accounting seam — the NFE numbers come from
    the same pulled (B,) bookkeeping the waste accounting reads, never an
    extra transfer."""

    delivered: int = 0
    nfe_total: int = 0
    deadline_misses: int = 0
    deadline_met: int = 0
    wait_s_total: float = 0.0  # submission → admission queue wait

    @property
    def mean_nfe(self) -> float:
        return self.nfe_total / self.delivered if self.delivered else 0.0

    def as_dict(self) -> dict:
        return {
            "delivered": self.delivered,
            "mean_nfe": self.mean_nfe,
            "deadline_misses": self.deadline_misses,
            "deadline_met": self.deadline_met,
            "mean_wait_s": (self.wait_s_total / self.delivered
                            if self.delivered else 0.0),
        }


class TierAccounting:
    """Delivery stage: per-class NFE + deadline-miss/violation counters.

    ``on_deliver`` runs once per retired request, right after the
    retired rows crossed ``_d2h`` — the single counted device→host seam
    — so tier accounting adds zero transfers. A delivered-late request
    counts as a miss (``deliver_t > deadline_at``); requests without a
    deadline count under ``deadline_met``.

    ``bind(registry)`` feeds the same deliveries into a shared
    ``MetricsRegistry`` (DESIGN.md §15) as tier-labeled counters —
    ``serve_delivered_total`` / ``serve_tier_nfe_total`` /
    ``serve_deadline_misses_total`` / ``serve_deadline_met_total`` plus
    a ``serve_queue_wait_seconds`` histogram. This is the seam
    unification: before §15, deadline misses were counted here (at
    delivery) while NFE-waste was folded at a different host visit, and
    nothing asserted the two ledgers agreed; bound to one registry,
    both stages write the same books and the observability tests pin
    them to the device-side counters.
    """

    def __init__(self, registry=None):
        self.stats: Dict[str, TierStats] = {}
        self.registry = registry

    def bind(self, registry) -> None:
        """Adopt the serve loop's registry unless one was pinned at
        construction (idempotent; the batcher calls this so a default
        TierAccounting shares the batcher's books)."""
        if self.registry is None:
            self.registry = registry

    def on_deliver(self, req, now: float) -> None:
        name = tier_name(req)
        s = self.stats.setdefault(name, TierStats())
        s.delivered += 1
        s.nfe_total += int(req.nfe)
        wait = max(0.0, req._seat_t - req._submit_t)
        s.wait_s_total += wait
        missed = req.deadline_at is not None and now > req.deadline_at
        req.deadline_missed = missed
        if missed:
            s.deadline_misses += 1
        else:
            s.deadline_met += 1
        if self.registry is not None:
            m = self.registry
            m.counter("serve_delivered_total", tier=name).inc()
            m.counter("serve_tier_nfe_total", tier=name).inc(int(req.nfe))
            m.counter("serve_deadline_misses_total", tier=name).inc(missed)
            m.counter("serve_deadline_met_total", tier=name).inc(not missed)
            m.histogram("serve_queue_wait_seconds", tier=name).observe(wait)


def tier_name(req) -> str:
    """A request's tolerance-class name for accounting: the tier's
    ``name`` (preset string or ToleranceClass), or ``"default"`` for
    untiered requests riding the server's static config."""
    tier = getattr(req, "tier", None)
    if tier is None:
        return "default"
    return tier if isinstance(tier, str) else tier.name


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) or (P, K) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    remaining_prompt: Deque[int] = dataclasses.field(default_factory=deque)
    new_tokens: int = 0

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Greedy continuous-batching decode over a fixed slot batch."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 cache_len: int = 256):
        assert cfg.num_codebooks == 1, "scheduler demo covers 1-codebook LMs"
        assert all(m != "M" for m in cfg.mixer_pattern), (
            "continuous batching isolates slots by masking KV positions; "
            "SSM state cannot be masked retroactively — use dedicated "
            "batches for SSM archs"
        )
        self.cfg = cfg
        self.params = params
        self.slots = [_Slot() for _ in range(slots)]
        self.n_slots = slots
        self.cache_len = cache_len
        self.state = init_decode_state(cfg, slots, cache_len)
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        # token each slot feeds next step (pad with 0 for free slots)
        self._next_input = np.zeros((slots,), np.int32)
        # global step counter == cache.length; per-slot request start
        self._global_step = 0
        self._start_pos = np.zeros((slots,), np.int32)
        # occupancy accounting, mirroring DiffusionBatcher's wasted-NFE
        # metrics (DESIGN.md §7): every device step costs a full
        # slots-wide forward whether slots are occupied or not. The LM
        # decode step is inherently one token per host sync (the sampled
        # token feeds the next step), so there is no horizon to chunk —
        # but the waste metric is the same shape.
        self.total_steps = 0
        self.useful_steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _assign_free_slots(self) -> None:
        for slot in self.slots:
            if slot.free and self.queue:
                req = self.queue.popleft()
                slot.request = req
                slot.remaining_prompt = deque(int(t) for t in np.asarray(req.prompt))
                slot.new_tokens = 0
                i = self.slots.index(slot)
                self._next_input[i] = slot.remaining_prompt.popleft()
                # isolation: this slot only sees KV from its own request
                self._start_pos[i] = self._global_step

    def _advance_slot(self, i: int, sampled: int) -> None:
        slot = self.slots[i]
        req = slot.request
        if req is None:
            return
        if slot.remaining_prompt:
            # still prefilling (by replay): ignore the sample, feed prompt
            self._next_input[i] = slot.remaining_prompt.popleft()
            return
        # decoding: the sampled token is an output
        req.output.append(sampled)
        slot.new_tokens += 1
        hit_eos = req.eos_id is not None and sampled == req.eos_id
        if slot.new_tokens >= req.max_new_tokens or hit_eos:
            req.done = True
            self.finished[req.uid] = req
            slot.request = None
            self._next_input[i] = 0
        else:
            self._next_input[i] = sampled

    @property
    def wasted_step_fraction(self) -> float:
        """Fraction of issued slot-steps that served free slots — the
        decode-side analog of DiffusionBatcher.wasted_nfe_fraction."""
        issued = self.n_slots * self.total_steps
        if issued == 0:
            return 0.0
        return 1.0 - self.useful_steps / issued

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One device step for all slots; returns #active slots."""
        self._assign_free_slots()
        active = sum(0 if s.free else 1 for s in self.slots)
        if active == 0:
            return 0
        self.total_steps += 1
        self.useful_steps += active
        toks = jnp.asarray(self._next_input)[:, None]
        batch = {"tokens": toks, "start_pos": jnp.asarray(self._start_pos)}
        next_tok, self.state = self.step_fn(self.params, batch, self.state)
        self._global_step += 1
        sampled = np.asarray(jax.device_get(next_tok))[:, 0]
        for i in range(self.n_slots):
            self._advance_slot(i, int(sampled[i]))
        return active

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, Request]:
        steps = 0
        while (self.queue or any(not s.free for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
