import jax
import pytest

# Smoke tests and benches must see the single real CPU device — the 512
# placeholder devices are requested by dryrun.py only (in subprocesses).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
