import os

import jax
import pytest

# Smoke tests and benches must see the single real CPU device — the 512
# placeholder devices are requested by dryrun.py only (in subprocesses).
jax.config.update("jax_platform_name", "cpu")

# The default suite is jit-compile dominated, so persist XLA's
# compilation cache across runs: a warm `pytest -q` re-run skips most
# compiles (CI caches the directory keyed on the JAX version). Numerics
# are unaffected — the cache stores compiled executables keyed on the
# exact HLO + compile options. The installed jax/jaxlib version pair is
# part of the directory key: a dependency bump starts a clean
# subdirectory instead of accreting dead entries (stale executables
# never hit — XLA keys on its own compiler version — but they would
# bloat the CI cache archive forever).
_CACHE_DIR = os.environ.get(
    "REPRO_JAX_CACHE",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 ".jax_cache", f"jax-{jax.__version__}-{jax.lib.__version__}"),
)
jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.3)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
