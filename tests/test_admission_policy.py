"""Property tests for the serving-stage policy seam (DESIGN.md §14):
admission ordering (FIFO / EDF-within-priority-bands), anti-starvation
aging, and the delivery stage's deadline accounting.

Runs under hypothesis when installed; otherwise a deterministic
fallback shim replays each property over a fixed-seed sweep of examples
(same pattern as test_property_hypothesis.py).
"""

import dataclasses
import random as _random
from collections import deque
from typing import Optional

import pytest  # noqa: F401

try:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", deadline=None, max_examples=30)
    settings.load_profile("ci")
except ImportError:  # pragma: no cover — dep-less fallback
    _N_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mimics hypothesis.strategies
        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: r.choice(seq))

        @staticmethod
        def lists(elems, min_size, max_size):
            return _Strategy(
                lambda r: [elems.draw(r)
                           for _ in range(r.randint(min_size, max_size))]
            )

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda r: tuple(e.draw(r) for e in elems))

        @staticmethod
        def one_of(*strats):
            return _Strategy(lambda r: r.choice(strats).draw(r))

        @staticmethod
        def none():
            return _Strategy(lambda r: None)

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rnd = _random.Random(0xC0FFEE)
                for _ in range(_N_EXAMPLES):
                    drawn = tuple(s.draw(rnd) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

from repro.serving.scheduler import (  # noqa: E402
    EdfPriorityAdmission, FifoAdmission, TierAccounting,
)


@dataclasses.dataclass
class Req:
    """Minimal duck-typed request for the policy seam."""
    uid: int
    priority: int = 0
    deadline_at: Optional[float] = None
    _submit_t: float = 0.0
    _seat_t: float = 0.0
    tier: Optional[str] = None
    nfe: int = 0
    deadline_missed: bool = False


#: (priority band, deadline offset or None, submit time) draws
req_specs = st.lists(
    st.tuples(
        st.integers(0, 3),
        st.one_of(st.none(), st.floats(0.0, 100.0)),
        st.floats(0.0, 50.0),
    ),
    min_size=1, max_size=24,
)


def _queue_of(specs):
    return deque(
        Req(uid=i, priority=p, deadline_at=d, _submit_t=s)
        for i, (p, d, s) in enumerate(specs)
    )


@given(req_specs, st.integers(1, 8), st.floats(0.0, 200.0))
def test_fifo_is_exactly_popleft(specs, n_free, now):
    """The base policy must reproduce the pre-policy batcher behaviour
    bit for bit: first n_free in submission order, queue order of the
    rest untouched."""
    q = _queue_of(specs)
    want = list(q)[:n_free]
    rest = list(q)[n_free:]
    chosen = FifoAdmission().select(q, n_free, now)
    assert chosen == want
    assert list(q) == rest


@given(req_specs, st.integers(1, 8), st.floats(0.0, 200.0))
def test_edf_bands_never_inverted(specs, n_free, now):
    """No skipped request may rank strictly ahead of a seated one: the
    chosen set is exactly the n_free smallest by the policy's order key
    (bands first, then deadline) and is returned in key order."""
    policy = EdfPriorityAdmission()  # no aging: static bands
    q = _queue_of(specs)
    everyone = list(q)
    chosen = policy.select(q, n_free, now)
    keys = {r.uid: policy.order_key(r, now) for r in everyone}
    # returned in key order …
    got = [keys[r.uid] for r in chosen]
    assert got == sorted(got)
    # … and no unchosen request outranks any chosen one
    left = list(q)
    assert len(chosen) == min(n_free, len(everyone))
    if chosen and left:
        assert max(got) <= min(keys[r.uid] for r in left)
    # bands specifically never invert
    if chosen and left:
        assert max(r.priority for r in chosen) <= \
            min(r.priority for r in left) or any(
                r.priority <= min(x.priority for x in left)
                for r in chosen)


@given(req_specs, st.floats(0.0, 200.0))
def test_edf_within_band(specs, now):
    """Inside one priority band the seated order is
    earliest-deadline-first, no-deadline requests last, submission time
    breaking ties (FIFO among equals)."""
    policy = EdfPriorityAdmission()
    q = _queue_of(specs)
    chosen = policy.select(q, len(specs), now)  # seat everyone: full sort
    for a, b in zip(chosen, chosen[1:]):
        if a.priority == b.priority:
            da = float("inf") if a.deadline_at is None else a.deadline_at
            db = float("inf") if b.deadline_at is None else b.deadline_at
            assert (da, a._submit_t, a.uid) <= (db, b._submit_t, b.uid)
        else:
            assert a.priority < b.priority


def _saturating_flood(aging_s, rounds=40):
    """One old low-urgency request vs a fresh urgent arrival every tick,
    one free slot per tick. Returns the tick the victim was seated, or
    None."""
    policy = EdfPriorityAdmission(aging_s=aging_s)
    q = deque([Req(uid=0, priority=3, _submit_t=0.0)])
    for t in range(1, rounds + 1):
        q.append(Req(uid=1000 + t, priority=0,
                     deadline_at=t + 0.5, _submit_t=float(t)))
        for r in policy.select(q, 1, float(t)):
            if r.uid == 0:
                return t
    return None


def test_aging_prevents_starvation_and_its_absence_demonstrates_it():
    """Under a saturating flood of urgent traffic, static bands starve
    the background request forever; with aging its effective band drops
    without floor, so it must eventually be seated."""
    assert _saturating_flood(aging_s=None) is None
    seated_at = _saturating_flood(aging_s=1.0)
    assert seated_at is not None
    # band 3 decays by 1/s: seated once it drops below fresh band 0
    assert seated_at <= 5


@given(st.lists(
    st.tuples(st.one_of(st.none(), st.floats(0.0, 10.0)),
              st.floats(0.0, 20.0),
              st.integers(0, 500),
              st.sampled_from(["draft", "standard", None])),
    min_size=1, max_size=32,
))
def test_deadline_miss_counters_match_oracle_replay(items):
    """The delivery stage's per-class counters must agree exactly with
    an independent replay of (deadline, delivery-time) pairs: misses are
    deliveries strictly after the deadline, everything else counts as
    met, NFE totals are plain sums."""
    acc = TierAccounting()
    oracle = {}
    for uid, (deadline, deliver_t, nfe, tier) in enumerate(items):
        req = Req(uid=uid, deadline_at=deadline, nfe=nfe, tier=tier)
        acc.on_deliver(req, now=deliver_t)
        name = tier or "default"
        o = oracle.setdefault(name, dict(n=0, miss=0, nfe=0))
        o["n"] += 1
        o["nfe"] += nfe
        missed = deadline is not None and deliver_t > deadline
        o["miss"] += int(missed)
        assert req.deadline_missed is missed
    assert set(acc.stats) == set(oracle)
    for name, o in oracle.items():
        s = acc.stats[name]
        assert s.delivered == o["n"]
        assert s.deadline_misses == o["miss"]
        assert s.deadline_met == o["n"] - o["miss"]
        assert s.nfe_total == o["nfe"]
        assert s.mean_nfe == pytest.approx(o["nfe"] / o["n"])


def test_server_deadline_accounting_matches_request_stamps():
    """End-to-end oracle replay through the batcher with an injected
    fake clock: the per-class miss counters must equal a recount over
    the delivered requests' own (deadline_at, delivery-time) stamps."""
    from repro.core import AdaptiveConfig, VPSDE
    from repro.core.analytic import gaussian_noise_pred
    from repro.launch.sample import make_sample_step
    from repro.models.dit import DiTConfig
    from repro.serving.diffusion_server import (
        DiffusionBatcher, ImageRequest,
    )

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)
    step = make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU := 0.3,
                                                           S0 := 0.5))

    ticks = iter(range(1, 100_000))
    clock = lambda: float(next(ticks))  # 1s per observation

    delivered_log = []

    class LoggingAccounting(TierAccounting):
        def on_deliver(self, req, now):
            delivered_log.append((req.uid, req.deadline_at, now))
            super().on_deliver(req, now)

    acc = LoggingAccounting()
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(16,),
                         slots=4, cfg=cfg, sync_horizon=4,
                         tolerance_classes=True, delivery=acc, clock=clock)
    # deadline 0ms ⇒ certain miss; huge ⇒ certain met; None ⇒ met
    deadlines = [0.0, None, 1e9, 0.0, None, 1e9, 0.0, None]
    for uid, dl in enumerate(deadlines):
        b.submit(ImageRequest(uid=uid, seed=uid, tier="draft",
                              deadline_ms=dl))
    done = b.run_to_completion()
    assert len(done) == len(deadlines)
    oracle_misses = sum(
        1 for _, dl, now in delivered_log if dl is not None and now > dl
    )
    s = acc.stats["draft"]
    assert s.delivered == len(deadlines)
    assert s.deadline_misses == oracle_misses == 3
    assert s.deadline_met == len(deadlines) - 3
    for uid, dl, now in delivered_log:
        assert done[uid].deadline_missed is (dl is not None and now > dl)
