"""Checkpoint round-trips are bit-exact for every param tree the repo
ships — temporal UNet (the new trajectory workload) and DiT (regression)
— under every precision preset, including bf16 trees, which numpy's npz
cannot serialize natively (``repro.checkpoint.io`` encodes extension
dtypes as uint views + json-recorded dtype names)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.precision import PRESETS, resolve_policy
from repro.models.dit import DiTConfig, init_dit
from repro.models.temporal_unet import TemporalUNetConfig, init_temporal_unet

TRAJ_CFG = TemporalUNetConfig(horizon=4, transition_dim=4, base=8,
                              mults=(1, 2), t_dim=16, groups=4,
                              returns_bins=3)
DIT_CFG = DiTConfig(image_size=8, patch=4, d_model=16, num_layers=1,
                    num_heads=2, d_ff=32, num_classes=3)


def _assert_tree_bitwise(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (path, x.dtype, y.dtype)
        assert x.shape == y.shape, path
        # bitwise, not just value-equal: compare the raw bytes
        np.testing.assert_array_equal(
            x.view(np.uint8), y.view(np.uint8),
            err_msg=f"{path} not bit-identical")


def _roundtrip(tmp_path, tree, step=7):
    like = jax.tree_util.tree_map(lambda a: jnp.zeros_like(a), tree)
    save_checkpoint(str(tmp_path), step, tree)
    restored, got_step = restore_checkpoint(str(tmp_path), like)
    assert got_step == step
    _assert_tree_bitwise(tree, restored)


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_temporal_unet_roundtrip_every_preset(tmp_path, preset):
    policy = resolve_policy(preset)
    params = policy.cast_params(
        init_temporal_unet(TRAJ_CFG, jax.random.PRNGKey(0)))
    if preset == "bf16_full":
        assert params["conv_in"].dtype == jnp.bfloat16  # the hard case
    _roundtrip(tmp_path, params)


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_dit_roundtrip_every_preset(tmp_path, preset):
    policy = resolve_policy(preset)
    params = policy.cast_params(init_dit(DIT_CFG, jax.random.PRNGKey(1)))
    _roundtrip(tmp_path, params)


def test_mixed_dtype_tree_roundtrip(tmp_path):
    """fp32 + bf16 + int leaves in one tree: only extension-dtype leaves
    are encoded; natives pass through untouched."""
    tree = {
        "w32": jnp.linspace(-1, 1, 6, dtype=jnp.float32).reshape(2, 3),
        "wbf": jnp.linspace(-1, 1, 6, dtype=jnp.bfloat16).reshape(3, 2),
        "step": jnp.asarray([3], jnp.int32),
    }
    _roundtrip(tmp_path, tree)


def test_restore_validates_structure(tmp_path):
    params = init_temporal_unet(TRAJ_CFG, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, params)
    bad = dict(params)
    bad["extra"] = jnp.zeros((2,))
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), bad)
