"""Device-resident serving hot path (DESIGN.md §12).

The device-resident ``DiffusionBatcher`` folds retirement, shard-local
compaction, and queue admission into on-device programs with donated
carries; the host is consulted only when the scalar events flag fires.
Three properties pin it:

  * **bit-identity** — per-request samples, iteration totals, and waste
    accounting exactly match the host-driven ``_sync`` loop (compaction
    on and off, unconditioned and with per-request condition payloads):
    per-slot PRNG keys make every trajectory independent of where
    retirement/admission decisions are computed;
  * **O(events) host traffic** — device→host transfers (counted by a
    shim around ``jax.device_get``, independently of the batcher's own
    counter) scale with deliveries, not sync horizons: ≥5× fewer than
    the host-driven loop at sync_horizon ≤ 8, and near-constant as the
    horizon shrinks while the host-driven count blows up;
  * **donation** — the driver actually consumes its input carry, so the
    hot loop is not double-buffering state.
"""

import jax
import numpy as np
import pytest

from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.core.guidance import Inpaint
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
D = 32
SLOTS = 4
N_REQ = 12


def _make_step(sde, cfg):
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # signature holder; forward_fn wins
    return make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))


@pytest.fixture(scope="module")
def server_parts():
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    return sde, cfg, _make_step(sde, cfg)


def _drain(b, n_req, cond_for=None):
    for uid in range(n_req):
        b.submit(ImageRequest(uid=uid, seed=uid,
                              cond=cond_for(uid) if cond_for else None))
    done = b.run_to_completion()
    assert len(done) == n_req
    return done


def _run(sde, cfg, step, *, n_req=N_REQ, cond_for=None, **kw):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=SLOTS, cfg=cfg, **kw)
    done = _drain(b, n_req, cond_for)
    return b, np.stack([done[u].result for u in range(n_req)]), done


# ---------------------------------------------------------------------------
# bit-identity vs the host-driven loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compaction", [True, False],
                         ids=["compaction", "monolithic"])
def test_device_resident_bitwise_matches_host_driven(server_parts,
                                                     compaction):
    """Same keys + same request wave ⇒ the device-resident loop delivers
    bit-identical samples AND identical accounting (iterations, per-
    request NFE, waste fraction) to the host-driven ``_sync`` loop —
    retirement/compaction/admission decisions moved devices, the math
    did not. Holds for both turnover disciplines."""
    sde, cfg, step = server_parts
    kw = dict(sync_horizon=4, compaction=compaction)
    b_host, x_host, done_h = _run(sde, cfg, step, **kw)
    b_dev, x_dev, done_d = _run(sde, cfg, step, device_resident=True, **kw)
    np.testing.assert_array_equal(x_host, x_dev)
    assert b_host.total_iterations == b_dev.total_iterations
    assert [done_h[u].nfe for u in range(N_REQ)] == \
        [done_d[u].nfe for u in range(N_REQ)]
    assert b_host.wasted_nfe_fraction == \
        pytest.approx(b_dev.wasted_nfe_fraction)


def test_device_resident_conditioned_bitwise(server_parts):
    """Per-request condition payloads survive on-device compaction and
    admission: payload *indices* (perm/admit masks) are applied on
    device while the ragged payload rows are scattered host-side — each
    delivery must still honor its OWN observation exactly."""
    sde, _, _ = server_parts
    ccfg = AdaptiveConfig(eps_rel=0.05, conditioner=Inpaint())
    step = _make_step(sde, ccfg)

    def cond_for(uid):
        mask = (np.arange(D) % 2 == uid % 2).astype(np.float32)
        return {"mask": mask,
                "observed": np.full(D, 0.1 + 0.05 * uid, np.float32)}

    _, x_host, _ = _run(sde, ccfg, step, cond_for=cond_for, sync_horizon=4)
    _, x_dev, _ = _run(sde, ccfg, step, cond_for=cond_for, sync_horizon=4,
                       device_resident=True)
    np.testing.assert_array_equal(x_host, x_dev)
    for uid in range(N_REQ):
        c = cond_for(uid)
        obs = c["mask"] == 1.0
        np.testing.assert_array_equal(x_dev[uid][obs], c["observed"][obs])


# ---------------------------------------------------------------------------
# host-sync traffic: O(events), not O(horizons)
# ---------------------------------------------------------------------------


class _GetCounter:
    """Counting shim around ``jax.device_get`` — an *independent* witness
    of device→host traffic, not the batcher's own ``host_transfers``."""

    def __init__(self, monkeypatch):
        self.calls = 0
        real = jax.device_get

        def counting(tree):
            self.calls += 1
            return real(tree)

        monkeypatch.setattr(jax, "device_get", counting)


def _transfers(server_parts, monkeypatch, **kw):
    sde, cfg, step = server_parts
    counter = _GetCounter(monkeypatch)
    b, _, _ = _run(sde, cfg, step, **kw)
    monkeypatch.undo()
    return counter.calls, b


def test_host_transfer_reduction_at_small_horizons(server_parts,
                                                   monkeypatch):
    """The acceptance gate: ≥5× fewer device→host transfers per request
    at sync_horizon ≤ 8, counted by the shim. The shim also cross-checks
    the batcher's own ``host_transfers`` counter (every serve-loop pull
    goes through ``_d2h``; the shim may see a handful of extra calls
    from delivery-side numpy conversions outside it)."""
    for horizon in (2, 8):
        n_host, b_host = _transfers(server_parts, monkeypatch,
                                    sync_horizon=horizon)
        n_dev, b_dev = _transfers(server_parts, monkeypatch,
                                  sync_horizon=horizon,
                                  device_resident=True)
        assert n_host >= b_host.host_transfers
        assert n_dev >= b_dev.host_transfers
        if horizon == 2:
            assert n_host >= 5 * n_dev, (horizon, n_host, n_dev)
        else:
            assert n_host > n_dev, (horizon, n_host, n_dev)


def test_device_resident_transfers_scale_with_events_not_horizons(
        server_parts, monkeypatch):
    """Shrinking the horizon 8× explodes the host-driven transfer count
    but barely moves the device-resident one: its traffic is pinned to
    delivery/admission *events*, which the workload (not the horizon)
    determines."""
    n_host_1, _ = _transfers(server_parts, monkeypatch, sync_horizon=1)
    n_host_8, _ = _transfers(server_parts, monkeypatch, sync_horizon=8)
    n_dev_1, _ = _transfers(server_parts, monkeypatch, sync_horizon=1,
                            device_resident=True)
    n_dev_8, _ = _transfers(server_parts, monkeypatch, sync_horizon=8,
                            device_resident=True)
    assert n_host_1 >= 3 * n_host_8          # host: O(horizons)
    assert n_dev_1 <= 2 * n_dev_8            # device: ~O(events)


# ---------------------------------------------------------------------------
# donation: the driver consumes its input carry
# ---------------------------------------------------------------------------


def test_driver_donates_carry_buffers(server_parts):
    """After a device step, the pre-step carry's buffers are donated
    (deleted): the hot loop reuses them instead of allocating a second
    resident copy per horizon window."""
    sde, cfg, step = server_parts
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=SLOTS, cfg=cfg, sync_horizon=4,
                         device_resident=True)
    for uid in range(SLOTS):
        b.submit(ImageRequest(uid=uid, seed=uid))
    before = b._carry.x
    assert b.step() >= 0
    assert before.is_deleted()
    b.run_to_completion()
    assert len(b.finished) == SLOTS
