"""Diffusion-LM: the zoo backbone as a score network + the paper's
solver generating token sequences end to end."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import VPSDE
from repro.models.diffusion_lm import (
    DiffusionLMConfig, diffusion_lm_forward, diffusion_lm_loss, embed,
    generate, init_diffusion_lm, round_to_tokens,
)
from repro.optim import AdamW


@pytest.fixture(scope="module")
def setup():
    bb = get_config("qwen1.5-0.5b").scaled_down().replace(vocab_size=64)
    cfg = DiffusionLMConfig(backbone=bb, embed_dim=32)
    sde = VPSDE()
    key = jax.random.PRNGKey(0)
    params = init_diffusion_lm(cfg, key)
    return cfg, sde, params


def test_forward_shape_and_finite(setup, rng):
    cfg, sde, params = setup
    x = jax.random.normal(rng, (2, 12, cfg.embed_dim))
    t = jnp.linspace(0.1, 0.9, 2)
    out = diffusion_lm_forward(params, x, t, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_rounding_inverts_embedding(setup, rng):
    cfg, sde, params = setup
    toks = jax.random.randint(rng, (2, 16), 0, cfg.backbone.vocab_size)
    x0 = embed(params, toks)
    # exact embeddings round back to the same tokens (unit-norm geometry)
    assert bool(jnp.all(round_to_tokens(params, x0) == toks))


def test_generation_runs_with_adaptive_solver(setup, rng):
    cfg, sde, params = setup
    toks, res = generate(params, cfg, sde, batch=4, seq=8, key=rng,
                         method="adaptive", eps_rel=0.1)
    assert toks.shape == (4, 8)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.backbone.vocab_size
    assert float(res.mean_nfe) > 0


@pytest.mark.slow
def test_training_reduces_loss(setup, rng):
    """Short DSM training on a 2-token repeating language must reduce
    loss (the embedding geometry is learnable-free; only the net moves)."""
    cfg, sde, params = setup
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    def data(key):
        a = jax.random.randint(key, (8, 1), 0, 2) * 3  # tokens 0 or 3
        return jnp.tile(a, (1, 12))

    @jax.jit
    def step(params, opt_state, key):
        key, kd, kl = jax.random.split(key, 3)
        loss, grads = jax.value_and_grad(
            lambda p: diffusion_lm_loss(p, cfg, sde, data(kd), kl)
        )(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, key, loss

    key = rng
    first = None
    for i in range(60):
        params, opt_state, key, loss = step(params, opt_state, key)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9, (first, float(loss))
