"""Diffusion continuous batching: every request completes, samples land
on the data distribution, slot refill beats lockstep batching, and the
horizon-chunked compacting loop is scheduling-invariant (per-slot keys
mean a sample's trajectory does not depend on its slot, its seatmates,
or where the sync horizons fall)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
D = 32


@pytest.fixture(scope="module")
def server_parts():
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    # analytic Gaussian score stands in for the net, in make_sample_step's
    # noise-pred forward_fn convention
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # unused shapes; signature holder
    step = make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))
    return sde, cfg, step


def _drain(b, n_req, seed0=0):
    for uid in range(n_req):
        b.submit(ImageRequest(uid=uid, seed=seed0 + uid))
    return b.run_to_completion()


def test_all_requests_complete_and_distribute(server_parts):
    sde, cfg, step = server_parts
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,), slots=4,
                         cfg=cfg)
    n_req = 12
    done = _drain(b, n_req)
    assert len(done) == n_req
    xs = np.stack([done[u].result for u in range(n_req)])
    assert np.isfinite(xs).all()
    # pooled moments approach the data distribution (pre-denoise state)
    assert abs(xs.mean() - MU) < 0.12
    assert abs(xs.std() - S0) < 0.12
    # every request did real work, with exact device-side accounting
    assert min(done[u].nfe for u in range(n_req)) > 10
    assert all(done[u].nfe % 2 == 0 for u in range(n_req))


def test_refill_uses_fewer_steps_than_lockstep(server_parts):
    """Slot refill: total device iterations < (batches × slowest sample)
    that lockstep batching would pay."""
    sde, cfg, step = server_parts
    n_req, slots = 16, 4
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=slots, cfg=cfg)
    done = _drain(b, n_req, seed0=100)
    assert len(done) == n_req
    per_req_iters = [done[u].nfe // 2 for u in range(n_req)]
    # lockstep: ceil(n/slots) batches, each paying its max
    groups = [per_req_iters[i:i + slots]
              for i in range(0, n_req, slots)]
    lockstep_steps = sum(max(g) for g in groups)
    assert b.total_iterations <= lockstep_steps


def test_horizon_and_compaction_scheduling_invariance(server_parts):
    """Per-request samples are bit-identical across sync horizons and
    with compaction on/off: per-slot keys decouple every trajectory from
    slot placement and sync timing."""
    sde, cfg, step = server_parts
    n_req = 10

    def run(**kw):
        b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                             slots=4, cfg=cfg, **kw)
        done = _drain(b, n_req)
        assert len(done) == n_req
        return b, np.stack([done[u].result for u in range(n_req)])

    _, x_h1 = run(sync_horizon=1)
    b_h8, x_h8 = run(sync_horizon=8)
    b_off, x_off = run(sync_horizon=8, compaction=False)
    np.testing.assert_array_equal(x_h1, x_h8)
    np.testing.assert_array_equal(x_h8, x_off)
    # and the monolithic-wave baseline pays more wasted work
    assert b_off.total_iterations >= b_h8.total_iterations
    assert b_off.wasted_nfe_fraction >= b_h8.wasted_nfe_fraction


def test_compaction_packs_survivors_contiguously(server_parts):
    """After each sync, occupied slots form a contiguous prefix of every
    device block (single device here ⇒ prefix of the whole batch)."""
    sde, cfg, step = server_parts
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, sync_horizon=4)
    for uid in range(6):
        b.submit(ImageRequest(uid=uid, seed=uid))
    seen_occupancies = set()
    while b.queue or any(r is not None for r in b._slot_req):
        if b.step() == 0 and not b.queue:
            break
        flags = [r is not None for r in b._slot_req]
        k = sum(flags)
        seen_occupancies.add(k)
        assert flags == [True] * k + [False] * (4 - k), flags
    b._sync()
    assert len(b.finished) == 6
    assert max(seen_occupancies) == 4  # the batch actually filled up


def test_condition_payloads_travel_with_slots(server_parts):
    """Wave test with per-request conditioning (DESIGN.md §9): every
    request carries its OWN inpainting payload (distinct mask phase and
    observed value), and delivered samples are bit-identical across
    sync horizons and compaction on/off — which can only hold if the
    condition leaves were permuted/admitted with their slots. Each
    delivery is additionally checked against its own observation, so a
    payload landing in the wrong slot fails outright."""
    sde, cfg, step_uncond = server_parts
    from repro.core import AdaptiveConfig
    from repro.core.guidance import Inpaint
    from repro.launch.sample import make_sample_step
    from repro.core.analytic import gaussian_noise_pred
    from repro.models.dit import DiTConfig

    ccfg = AdaptiveConfig(eps_rel=0.05, conditioner=Inpaint())
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)
    step = make_sample_step(net, sde, ccfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))
    n_req = 10

    def req_cond(uid):
        mask = (np.arange(D) % 2 == uid % 2).astype(np.float32)
        return {"mask": mask,
                "observed": np.full(D, 0.1 + 0.05 * uid, np.float32)}

    def run(**kw):
        b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                             slots=4, cfg=ccfg, **kw)
        for uid in range(n_req):
            b.submit(ImageRequest(uid=uid, seed=uid, cond=req_cond(uid)))
        done = b.run_to_completion()
        assert len(done) == n_req
        return np.stack([done[u].result for u in range(n_req)])

    x_h1 = run(sync_horizon=1)
    x_h8 = run(sync_horizon=8)
    x_off = run(sync_horizon=8, compaction=False)
    np.testing.assert_array_equal(x_h1, x_h8)
    np.testing.assert_array_equal(x_h8, x_off)
    # each request honored its own observation: delivery applies the
    # conditioner's exact finalize_project, so observed coords equal
    # the request's OWN observed values bit-for-bit — distinct
    # per-request values rule out any payload cross-wiring
    for uid in range(n_req):
        c = req_cond(uid)
        obs_idx = c["mask"] == 1.0
        np.testing.assert_array_equal(x_h1[uid][obs_idx],
                                      c["observed"][obs_idx])


def test_wasted_nfe_accounting(server_parts):
    """useful + wasted = issued: the wasted fraction is exactly the gap
    between delivered per-request NFE and 2·slots·iterations."""
    sde, cfg, step = server_parts
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, sync_horizon=4)
    done = _drain(b, 8)
    issued = 2 * 4 * b.total_iterations
    useful = sum(done[u].nfe for u in range(8))
    assert useful == b.useful_nfe
    assert 0.0 <= b.wasted_nfe_fraction < 1.0
    assert b.wasted_nfe_fraction == pytest.approx(1.0 - useful / issued)
