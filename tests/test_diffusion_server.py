"""Diffusion continuous batching: every request completes, samples land
on the data distribution, and slot refill beats lockstep batching in
device steps when per-sample NFE varies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaptiveConfig, VPSDE
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
D = 32


@pytest.fixture(scope="module")
def server_parts():
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)

    # analytic Gaussian score stands in for the net: make_sample_step only
    # needs a forward_fn(params, x, t) — adapt signature.
    def forward_fn(params, x, t):
        m, std = sde.marginal(t)
        m = m.reshape((-1,) + (1,) * (x.ndim - 1))
        std = std.reshape((-1,) + (1,) * (x.ndim - 1))
        score = -(x - m * MU) / (m * m * S0 * S0 + std * std)
        # make_sample_step treats forward_fn as noise-pred: score = -out/std
        return -score * std

    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # unused shapes; signature holder
    step = make_sample_step(net, sde, cfg, forward_fn=forward_fn)
    return sde, cfg, step


def test_all_requests_complete_and_distribute(server_parts):
    sde, cfg, step = server_parts
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,), slots=4,
                         cfg=cfg)
    n_req = 12
    for uid in range(n_req):
        b.submit(ImageRequest(uid=uid, seed=uid))
    done = b.run_to_completion()
    assert len(done) == n_req
    xs = np.stack([done[u].result for u in range(n_req)])
    assert np.isfinite(xs).all()
    # pooled moments approach the data distribution (pre-denoise state)
    assert abs(xs.mean() - MU) < 0.12
    assert abs(xs.std() - S0) < 0.12
    # every request did real work
    assert min(done[u].nfe for u in range(n_req)) > 10


def test_refill_uses_fewer_steps_than_lockstep(server_parts):
    """Slot refill: total device steps < (batches × slowest sample) that
    lockstep batching would pay."""
    sde, cfg, step = server_parts
    n_req, slots = 16, 4
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=slots, cfg=cfg)
    for uid in range(n_req):
        b.submit(ImageRequest(uid=uid, seed=100 + uid))
    steps = 0
    while b.queue or any(r is not None for r in b._slot_req):
        if b.step() == 0:
            break
        steps += 1
    b._refill()
    assert len(b.finished) == n_req
    per_req_iters = [b.finished[u].nfe // 2 for u in range(n_req)]
    # lockstep: ceil(n/slots) batches, each paying its max
    groups = [per_req_iters[i:i + slots]
              for i in range(0, n_req, slots)]
    lockstep_steps = sum(max(g) for g in groups)
    assert steps <= lockstep_steps
