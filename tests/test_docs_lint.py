"""The docs-lint gate, run locally as part of tier 1 (DESIGN.md §9):
every ``DESIGN.md §N`` citation in the code resolves to a real section
header and README/DESIGN relative links point at existing files. CI
runs the same checks as the dependency-free ``docs-lint`` job."""

import pathlib
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "tools")
)
import docs_lint  # noqa: E402


def test_design_citations_resolve():
    assert docs_lint.check_citations() == []


def test_doc_relative_links_resolve():
    assert docs_lint.check_links() == []
