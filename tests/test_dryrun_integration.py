"""Integration: the real dry-run (512 placeholder devices) in a
subprocess, one representative combo per step kind. The full 10×4×2
matrix runs via ``python -m repro.launch.dryrun --all`` and is recorded
in EXPERIMENTS.md §Dry-run.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, multi_pod=False):
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape,
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=560, cwd=ROOT)


@pytest.mark.slow
def test_dryrun_train_single_pod():
    r = _run("qwen1.5-0.5b", "train_4k")
    assert r.returncode == 0, r.stdout + r.stderr
    path = os.path.join(ROOT, "experiments", "dryrun",
                        "qwen1.5-0.5b_train_4k_1pod.json")
    rec = json.load(open(path))
    assert rec["devices"] == 256
    assert rec["cost"]["flops"] > 1e11
    assert rec["collectives"]["total_bytes"] > 0


@pytest.mark.slow
def test_dryrun_decode_multi_pod():
    r = _run("qwen1.5-0.5b", "decode_32k", multi_pod=True)
    assert r.returncode == 0, r.stdout + r.stderr
    path = os.path.join(ROOT, "experiments", "dryrun",
                        "qwen1.5-0.5b_decode_32k_2pod.json")
    rec = json.load(open(path))
    assert rec["devices"] == 512
