"""End-to-end paper pipeline: train a score net, sample with every
solver, score sample quality against the known data distribution.

This is the CPU-scale version of the paper's experiment loop; the
benchmarks run the same pipeline at larger sample counts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VPSDE, dsm_loss, sample
from repro.data.images import GMM2D
from repro.models.score_unet import (
    MLPScoreConfig, init_mlp_score, mlp_score_forward,
)
from repro.optim import AdamW, ema_init, ema_params, ema_update


def _w2_gaussianized(x, y):
    """Cheap 2-Wasserstein proxy via moment matching per dimension."""
    return float(
        jnp.abs(x.mean(0) - y.mean(0)).sum()
        + jnp.abs(x.std(0) - y.std(0)).sum()
    )


@pytest.fixture(scope="module")
def trained_score():
    sde = VPSDE()
    gmm = GMM2D(means=((-1.5, 0.0), (1.5, 0.0)), std=0.3, weights=(0.5, 0.5))
    cfg = MLPScoreConfig(dim=2, hidden=96, depth=3)
    key = jax.random.PRNGKey(0)
    params = init_mlp_score(cfg, key)
    opt = AdamW(lr=2e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    ema = ema_init(params)

    def apply_fn(p, x, t):
        # noise-parameterized: net predicts std·score
        _, std = sde.marginal(t)
        return mlp_score_forward(p, x, t, cfg) / std[:, None]

    @jax.jit
    def step(params, opt_state, ema, key):
        key, kd, kl = jax.random.split(key, 3)
        x0 = gmm.sample(kd, 256)
        loss, grads = jax.value_and_grad(
            lambda p: dsm_loss(sde, apply_fn, p, x0, kl)
        )(params)
        params, opt_state = opt.update(grads, opt_state, params)
        ema = ema_update(ema, params, 0.99)
        return params, opt_state, ema, key, loss

    for i in range(400):
        params, opt_state, ema, key, loss = step(params, opt_state, ema, key)

    final = ema_params(ema, params)
    return sde, gmm, cfg, final, apply_fn


@pytest.mark.parametrize("method,kw", [
    ("adaptive", dict(eps_rel=0.05)),
    ("em", dict(n_steps=500)),
])
def test_trained_sampling_matches_data(trained_score, method, kw, rng):
    sde, gmm, cfg, params, apply_fn = trained_score
    res = jax.jit(
        lambda k: sample(sde, lambda x, t: apply_fn(params, x, t),
                         (1024, 2), k, method=method, **kw)
    )(rng)
    data = gmm.sample(jax.random.fold_in(rng, 9), 1024)
    w2 = _w2_gaussianized(res.x, data)
    assert not bool(jnp.any(jnp.isnan(res.x)))
    assert w2 < 0.35, (method, w2)


def test_adaptive_beats_em_at_matched_nfe(trained_score, rng):
    """The paper's same-budget comparison: at the adaptive solver's NFE,
    fixed-step EM with that many steps is no better (usually worse)."""
    sde, gmm, cfg, params, apply_fn = trained_score
    score = lambda x, t: apply_fn(params, x, t)
    res_ad = jax.jit(
        lambda k: sample(sde, score, (1024, 2), k, method="adaptive",
                         eps_rel=0.05)
    )(rng)
    nfe = int(float(res_ad.mean_nfe))
    res_em = jax.jit(
        lambda k: sample(sde, score, (1024, 2), k, method="em",
                         n_steps=max(nfe // 2, 2))  # EM: 1 eval/step
    )(rng)
    data = gmm.sample(jax.random.fold_in(rng, 9), 1024)
    w2_ad = _w2_gaussianized(res_ad.x, data)
    w2_em = _w2_gaussianized(res_em.x, data)
    assert w2_ad <= w2_em + 0.15, (w2_ad, w2_em, nfe)


def test_rejection_rate_low_at_image_dimensionality(rng):
    """Paper claim: 'rarely rejects samples'. The claim is a
    high-dimension concentration effect of the ℓ2 scaled error: measured
    rejection is ~1–2% at CIFAR dimensionality (d=3072) but ~40% at d=2
    (where E₂ has no dimensions to average over). We assert the paper's
    regime; the dimensionality sweep lives in EXPERIMENTS.md."""
    sde = VPSDE()

    def score(x, t):
        m, std = sde.marginal(t)
        m, std = m[:, None], std[:, None]
        return -(x - m * 0.3) / (m * m * 0.25 + std * std)

    res = jax.jit(
        lambda k: sample(sde, score, (32, 3072), k, method="adaptive",
                         eps_rel=0.05)
    )(rng)
    rej_frac = float(res.rejected.sum()) / float(
        (res.accepted + res.rejected).sum()
    )
    assert rej_frac < 0.05, rej_frac
