"""Examples and tooling can't silently rot: every ``examples/*.py``
imports cleanly (no ``__main__`` execution), ``examples/quickstart.py``
runs end-to-end as a subprocess (slow), and every benchmark module on
disk is registered in ``benchmarks/run.py``'s suite registry."""

import importlib.util
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.stem)
def test_example_imports_without_running_main(path):
    """Importing an example must execute only defs/constants — every
    example guards its entry point with ``if __name__ == "__main__"``,
    so exec'ing the module under a different name runs nothing heavy
    and catches rotted imports/signatures at tier-1 speed."""
    text = path.read_text(encoding="utf-8")
    assert 'if __name__ == "__main__":' in text, (
        f"{path.name} lacks a __main__ guard — it would execute on import")
    spec = importlib.util.spec_from_file_location(
        f"_example_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert hasattr(mod, "main"), f"{path.name} defines no main()"


@pytest.mark.slow
def test_quickstart_runs_end_to_end():
    """The README's first runnable command actually runs: train + sample
    + print, in a fresh interpreter with only PYTHONPATH=src."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "quickstart.py")],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.strip(), "quickstart printed nothing"


def test_benchmark_registry_covers_disk():
    """Every ``benchmarks/bench_*.py`` on disk has a ``benchmarks.run``
    suite entry (the audit that caught bench modules existing but being
    unreachable from ``--only``)."""
    from benchmarks.run import SUITES

    registered = {fn.__module__ for fn in SUITES.values()}
    on_disk = {f"benchmarks.{p.stem}"
               for p in (ROOT / "benchmarks").glob("bench_*.py")}
    missing = on_disk - registered
    assert not missing, (
        f"bench modules not registered in benchmarks.run.SUITES: {missing}")
