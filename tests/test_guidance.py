"""Controlled generation: the conditioning seam (DESIGN.md §9).

The contract under test, in three parts:

  * **disabled ⇒ bit-identical** — ``conditioner=None`` (the default),
    ``classifier_free(..., scale=0)``, and ``inpaint(mask=None, ...)``
    all collapse to exactly the unconditional stack: same samples, same
    NFE, same noise stream.
  * **score-field transforms compose** — CFG is one doubled batched
    forward; inpainting projects *after* accept at each slot's own t
    and pins observed data exactly at delivery; colorization is the
    same projection in the rotated channel basis.
  * **payloads ride the carry** — condition pytrees thread through
    ``solve_chunk`` bit-identically to the monolithic solve, and the
    sharding layer gives every payload leaf a batch-axis spec.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaptiveConfig,
    ClassifierFree,
    VPSDE,
    class_conditional,
    classifier_free,
    colorize,
    inpaint,
    sample,
    solve_in_chunks,
)
from repro.core.analytic import (
    class_gaussian_score,
    gaussian_marginal_moments,
    gaussian_score,
    gaussian_w2,
)
from repro.core.guidance import cond_batch, gray_basis, to_gray

MU, S0 = 0.3, 0.5
BATCH, DIM = 64, 8
CLASS_MUS = jnp.linspace(-1.0, 1.0, 10)

KEY = jax.random.PRNGKey(0)


def _uncond(sde, shape=(BATCH, DIM), method="adaptive", **kw):
    return sample(sde, gaussian_score(sde, MU, S0), shape, KEY,
                  method=method, eps_rel=0.05, **kw)


#: every solver that rides the conditioning seam through AdaptiveConfig
#: (DESIGN.md §11) must honor the disabled ⇒ bit-identical contract
CARRY_METHODS = ["adaptive", "momentum", "heun"]


# ---------------------------------------------------------------------------
# disabled ⇒ bit-identical to the unconditional path
# ---------------------------------------------------------------------------


def test_default_config_has_no_conditioner():
    """The new field defaults off, and off means *equal* off — configs
    built before and after the conditioning seam hash/compare the same,
    so nothing downstream (lru caches, jit closures) can fork on it."""
    assert AdaptiveConfig().conditioner is None
    assert AdaptiveConfig() == AdaptiveConfig(conditioner=None)
    assert dataclasses.replace(AdaptiveConfig(), eps_rel=0.05) == \
        AdaptiveConfig(eps_rel=0.05)
    # the zoo fields (DESIGN.md §11) obey the same off-means-equal rule
    assert AdaptiveConfig().momentum == 0.0
    assert AdaptiveConfig().probability_flow is False
    assert AdaptiveConfig() == AdaptiveConfig(momentum=0.0,
                                              probability_flow=False)


@pytest.mark.parametrize("method", CARRY_METHODS)
def test_cfg_scale_zero_bitwise_equals_unconditional(method):
    """CFG at scale=0 evaluates the single null-labeled forward with no
    projection draw — the whole solve (samples, per-sample NFE,
    iteration count) is bit-identical to the unconditional path. Holds
    for every carry family: momentum and Heun reuse the Algorithm-1
    body, so the conditioning seam composes without solver changes."""
    sde = VPSDE()
    res_u = _uncond(sde, method=method)
    conditioner, cond = class_conditional(jnp.arange(BATCH) % 10, 0.0)
    res_c = sample(sde, class_gaussian_score(sde, CLASS_MUS, S0, MU),
                   (BATCH, DIM), KEY, method=method, eps_rel=0.05,
                   conditioner=conditioner, cond=cond)
    np.testing.assert_array_equal(np.asarray(res_u.x), np.asarray(res_c.x))
    np.testing.assert_array_equal(np.asarray(res_u.nfe), np.asarray(res_c.nfe))
    assert int(res_u.iterations) == int(res_c.iterations)


@pytest.mark.parametrize("method", CARRY_METHODS)
def test_inpaint_mask_none_bitwise_equals_unconditional(method):
    """``inpaint(mask=None, ...)`` collapses to (None, None), so feeding
    it straight into ``sample`` must reproduce the unconditional solve
    bit-for-bit — the no-op inpaint cannot perturb the noise stream of
    any carry-family solver."""
    sde = VPSDE()
    conditioner, cond = inpaint(None, None)
    res_u = _uncond(sde, method=method)
    res_c = sample(sde, gaussian_score(sde, MU, S0), (BATCH, DIM), KEY,
                   method=method, eps_rel=0.05,
                   conditioner=conditioner, cond=cond)
    np.testing.assert_array_equal(np.asarray(res_u.x), np.asarray(res_c.x))
    np.testing.assert_array_equal(np.asarray(res_u.nfe), np.asarray(res_c.nfe))


def test_functional_classifier_free_scale_zero_is_identity():
    sde = VPSDE()
    u = gaussian_score(sde, MU, S0)
    c = gaussian_score(sde, MU + 0.2, S0)
    assert classifier_free(c, u, 0.0) is u


def test_inpaint_mask_none_returns_no_conditioner():
    assert inpaint(None, None) == (None, None)
    assert colorize(None) == (None, None)


# ---------------------------------------------------------------------------
# classifier-free guidance
# ---------------------------------------------------------------------------


def test_functional_classifier_free_formula_and_solvers():
    """The functional transform is s_u + w(s_c − s_u) and needs no
    solver support — it runs under the fixed-grid EM baseline too."""
    sde = VPSDE()
    u = gaussian_score(sde, MU, S0)
    c = gaussian_score(sde, MU + 0.4, S0)
    guided = classifier_free(c, u, 2.0)
    x = jax.random.normal(KEY, (8, DIM))
    t = jnp.full((8,), 0.5)
    np.testing.assert_allclose(
        np.asarray(guided(x, t)),
        np.asarray(u(x, t) + 2.0 * (c(x, t) - u(x, t))),
        rtol=1e-6,
    )
    res = sample(sde, guided, (16, DIM), KEY, method="em", n_steps=50)
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_cfg_single_doubled_forward_layout():
    """The conditioner evaluates the guided field as ONE forward over a
    2B stacked batch — [x; x] with labels [y; null] — never two calls."""
    calls = []

    def counting_score(x, t, y):
        calls.append((x.shape[0], np.asarray(y)))
        return jnp.zeros_like(x)

    cond = {"label": jnp.arange(4, dtype=jnp.int32)}
    guided = ClassifierFree(scale=1.5).wrap_score(counting_score, cond)
    guided(jnp.ones((4, DIM)), jnp.full((4,), 0.5))
    assert len(calls) == 1
    b2, y2 = calls[0]
    assert b2 == 8
    np.testing.assert_array_equal(y2[:4], np.arange(4))
    assert (y2[4:] < 0).all()  # null half


def test_cfg_neutral_cond_is_null_label():
    """The serving loop's idle-slot / no-payload filler must mean
    *unconditional* — the null label, never class 0."""
    neutral = ClassifierFree(scale=1.5).neutral_cond(4, (DIM,))
    assert (np.asarray(neutral["label"]) < 0).all()


def test_cfg_steers_per_class_means():
    """At scale=1 the guided field IS the class-conditional field, so
    each sample's delivered mean tracks its class mean."""
    sde = VPSDE()
    labels = jnp.arange(BATCH) % 10
    conditioner, cond = class_conditional(labels, 1.0)
    res = sample(sde, class_gaussian_score(sde, CLASS_MUS, S0, MU),
                 (BATCH, DIM), KEY, method="adaptive", eps_rel=0.05,
                 conditioner=conditioner, cond=cond)
    x = np.asarray(res.x)
    per_class = np.array([x[np.asarray(labels) == k].mean() for k in range(10)])
    # strong signal: per-class means correlate with the true class means
    assert np.corrcoef(per_class, np.asarray(CLASS_MUS))[0, 1] > 0.95


# ---------------------------------------------------------------------------
# inpainting / colorization projections
# ---------------------------------------------------------------------------


def test_inpaint_exact_observed_and_free_marginals_and_nfe():
    """Observed pixels are pinned exactly at delivery; the free region
    stays on the analytic OU marginal (independent pixels ⇒ the
    conditional equals the marginal); NFE overhead ≤ 1.1×."""
    sde = VPSDE()
    res_u = _uncond(sde, denoise=False)
    observed = MU + S0 * jax.random.normal(jax.random.PRNGKey(7), (BATCH, DIM))
    mask = jnp.zeros((BATCH, DIM)).at[:, : DIM // 2].set(1.0)
    conditioner, cond = inpaint(mask, observed)
    res = sample(sde, gaussian_score(sde, MU, S0), (BATCH, DIM), KEY,
                 method="adaptive", eps_rel=0.05, denoise=False,
                 conditioner=conditioner, cond=cond)
    x = np.asarray(res.x)
    np.testing.assert_array_equal(
        x[:, : DIM // 2], np.asarray(observed)[:, : DIM // 2]
    )
    mu_a, s_a = gaussian_marginal_moments(sde, MU, S0)
    free = x[:, DIM // 2:]
    w2 = gaussian_w2(float(free.mean()), float(free.std()), mu_a, s_a)
    assert w2 < 0.08, w2  # the adaptive solver's conformance gate
    assert float(res.mean_nfe) <= 1.1 * float(res_u.mean_nfe), (
        float(res.mean_nfe), float(res_u.mean_nfe),
    )


def test_colorize_pins_gray_component():
    sde = VPSDE()
    shape = (16, 4, 4, 3)
    ref = MU + S0 * jax.random.normal(jax.random.PRNGKey(3), shape)
    gray = to_gray(ref)
    conditioner, cond = colorize(gray)
    res = sample(sde, gaussian_score(sde, MU, S0), shape, KEY,
                 method="adaptive", eps_rel=0.05,
                 conditioner=conditioner, cond=cond)
    np.testing.assert_allclose(
        np.asarray(to_gray(res.x)), np.asarray(gray), atol=1e-5
    )
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_gray_basis_is_orthonormal():
    for c in (3, 4):
        m = np.asarray(gray_basis(c))
        np.testing.assert_allclose(m @ m.T, np.eye(c), atol=1e-6)
        np.testing.assert_allclose(m[0], np.full(c, 1 / np.sqrt(c)), atol=1e-6)


# ---------------------------------------------------------------------------
# payload plumbing: carry, chunking, sharding
# ---------------------------------------------------------------------------


def test_cond_batch_mismatch_raises():
    with pytest.raises(ValueError):
        cond_batch({"a": jnp.zeros((4, 2)), "b": jnp.zeros((5, 2))})
    sde = VPSDE()
    conditioner, cond = inpaint(jnp.zeros((4, DIM)), jnp.zeros((4, DIM)))
    with pytest.raises(ValueError):
        sample(sde, gaussian_score(sde, MU, S0), (BATCH, DIM), KEY,
               method="adaptive", conditioner=conditioner, cond=cond)


def test_chunked_solve_bitwise_with_conditioner():
    """The §7 chunk-≡-monolithic invariant extends to conditioning: the
    payload rides the carry, so horizon boundaries cannot perturb a
    conditioned trajectory. Compared at equal jit granularity (a
    maximal single chunk vs small chunks through the same host chain) —
    the same discipline the unconditional chunking suite uses, since
    XLA fusion across a jit boundary is not part of the invariant."""
    sde = VPSDE()
    observed = jnp.full((BATCH, DIM), 0.25)
    mask = jnp.zeros((BATCH, DIM)).at[:, ::2].set(1.0)
    conditioner, cond = inpaint(mask, observed)
    kw = dict(eps_rel=0.05, conditioner=conditioner)
    score = gaussian_score(sde, MU, S0)
    mono = solve_in_chunks(sde, score, (BATCH, DIM), KEY,
                           max_sync_iters=10**6, cond=cond, **kw)
    chunked = solve_in_chunks(sde, score, (BATCH, DIM), KEY,
                              max_sync_iters=7, cond=cond, **kw)
    np.testing.assert_array_equal(np.asarray(mono.x), np.asarray(chunked.x))
    np.testing.assert_array_equal(np.asarray(mono.nfe),
                                  np.asarray(chunked.nfe))
    one = solve_in_chunks(sde, score, (BATCH, DIM), KEY,
                          max_sync_iters=1, cond=cond, **kw)
    np.testing.assert_array_equal(np.asarray(mono.x), np.asarray(one.x))


def test_solver_carry_shardings_cover_cond_leaves():
    from repro.core.guidance import Inpaint
    from repro.parallel.sharding import solver_carry_shardings

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    struct = Inpaint().cond_struct(8, (DIM,))
    s = solver_carry_shardings(mesh, 8, 2, per_slot_keys=True, cond=struct)
    assert set(s.cond) == {"mask", "observed"}
    # payload leaves shard over the batch axis exactly like the state
    assert s.cond["mask"].spec == s.x.spec
