"""Shape/dtype sweep: flash-attention Pallas kernel (interpret) vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops, ref

CASES = [
    # B, Hq, Hkv, S, D, causal, window
    (2, 4, 4, 128, 64, True, None),
    (1, 8, 2, 256, 64, True, None),
    (2, 4, 2, 200, 32, True, 64),
    (1, 2, 1, 96, 128, False, None),
    (1, 4, 4, 64, 256, True, None),
    (2, 2, 2, 130, 64, True, 16),
    # non-causal with S off the block size: the DiT/temporal-UNet route
    # (bidirectional) must mask the padded tail, not just the causal one
    (2, 4, 2, 200, 32, False, None),
]


@pytest.mark.parametrize("case", CASES, ids=str)
def test_matches_ref(case, rng):
    B, Hq, Hkv, S, D, causal, window = case
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(kk, (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(kv, (B, Hkv, S, D), jnp.float32)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        block_q=64, block_k=64)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_bf16_inputs(rng):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(kv, (1, 2, 128, 64), jnp.bfloat16)
    out = ops.attention(q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_window_equals_full_when_large(rng):
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (1, 2, 96, 32))
    k = jax.random.normal(kk, (1, 2, 96, 32))
    v = jax.random.normal(kv, (1, 2, 96, 32))
    a = ops.attention(q, k, v, causal=True, window=4096, block_q=32, block_k=32)
    b = ops.attention(q, k, v, causal=True, window=None, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_model_layer_uses_flash_consistently(rng):
    """attention_forward(use_flash=True) == jnp reference attention path."""
    from repro.models import ModelConfig
    from repro.models.attention import attention_forward, init_attention

    cfg = ModelConfig(name="t", arch_type="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32)
    params = init_attention(rng, cfg, "A")
    x = jax.random.normal(rng, (2, 96, 64))
    pos = jnp.arange(96)[None, :]
    y1 = attention_forward(params, x, cfg, "A", pos, use_flash=False)
    y2 = attention_forward(params, x, cfg, "A", pos, use_flash=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-5)
