"""Shape/dtype sweep: fused GroupNorm→SiLU Pallas kernel (interpret) vs
the pure-jnp oracle (DESIGN.md §13).

bf16 operands exercise the precision contract (DESIGN.md §8): the
kernel upcasts the tile to fp32, computes two-pass statistics in fp32,
applies scale/bias and SiLU in fp32, and rounds ONCE at the store. The
oracle mirrors that single-rounding contract exactly, so kernel-vs-
oracle agreement is fp32-accumulation-order tight even for bf16 tiles;
the historical unfused ``silu(_groupnorm(...))`` chain rounds twice and
is held to bf16 tolerance instead.

The shape list covers B not divisible by the batch block (grid padding),
C < groups (the ``g = min(groups, C)`` clamp the temporal UNet relies
on), and H/C extents off the TPU lane/sublane sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.groupnorm_silu import ops, ref

CASES = [
    # B, H, C, groups
    (1, 16, 32, 8),
    (4, 32, 64, 8),
    (16, 8, 128, 8),
    (3, 32, 128, 8),   # B not a multiple of block_b=8 → grid padding
    (13, 16, 64, 8),   # likewise, bigger than one block
    (2, 16, 4, 8),     # C < groups → g clamps to C (per-channel norm)
    (8, 30, 96, 6),    # H off the sublane size, C off the lane size
]
DTYPES = [jnp.float32, jnp.bfloat16]
TOLS = {
    jnp.dtype(jnp.float32): dict(rtol=1e-6, atol=1e-6),
    # fp32 math on both sides; only the store rounds — differences are
    # reduction-order last-bits amplified through the bf16 grid
    jnp.dtype(jnp.bfloat16): dict(rtol=1e-2, atol=1e-2),
}


def _f32(a):
    return np.asarray(a, np.float32)


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_matches_ref(case, dtype, rng):
    B, H, C, G = case
    kx, ks, kb = jax.random.split(rng, 3)
    x = jax.random.normal(kx, (B, H, C), dtype)
    # affine params in the operand dtype: a precision policy hands the
    # kernel bf16 copies, and both sides must upcast them identically
    scale = (1.0 + 0.1 * jax.random.normal(ks, (C,))).astype(dtype)
    bias = (0.1 * jax.random.normal(kb, (C,))).astype(dtype)
    out = ops.groupnorm_silu(x, scale, bias, groups=G)
    assert out.dtype == jnp.dtype(dtype)
    want = ref.groupnorm_silu(x, scale, bias, groups=G)
    np.testing.assert_allclose(_f32(out), _f32(want),
                               **TOLS[jnp.dtype(dtype)])


def test_matches_unfused_chain(rng):
    """Kernel vs the temporal UNet's historical unfused jnp chain.

    fp32: both are fp32 end-to-end → tight. bf16: the unfused chain
    rounds twice (GroupNorm store, SiLU store) vs the kernel's once, so
    the bound is one bf16 ulp of the activation scale.
    """
    from repro.models.temporal_unet import _groupnorm

    kx, ks, kb = jax.random.split(rng, 3)
    for dtype, tol in ((jnp.float32, 1e-6), (jnp.bfloat16, 4e-2)):
        x = jax.random.normal(kx, (4, 16, 64), dtype)
        scale = (1.0 + 0.1 * jax.random.normal(ks, (64,))).astype(dtype)
        bias = (0.1 * jax.random.normal(kb, (64,))).astype(dtype)
        fused = ops.groupnorm_silu(x, scale, bias, groups=8)
        chain = jax.nn.silu(_groupnorm(x, scale, bias, 8))
        np.testing.assert_allclose(_f32(fused), _f32(chain),
                                   rtol=tol, atol=tol)


def test_large_offset_stats(rng):
    """fp32-statistics regression at the kernel level: a large common
    offset with small spread must still normalize to zero-mean /
    unit-std output — bf16 statistics would lose the variance entirely
    (100² needs more mantissa than bf16 has). The noise scale sits
    above bf16's quantization step at 100 (0.5) so the spread survives
    input quantization."""
    B, H, C, G = 4, 16, 32, 8
    noise = 2.0 * jax.random.normal(rng, (B, H, C))
    for dtype in (jnp.float32, jnp.bfloat16):
        x = (100.0 + noise).astype(dtype)
        ones = jnp.ones((C,), dtype)
        zeros = jnp.zeros((C,), dtype)
        out = _f32(ops.groupnorm_silu(x, ones, zeros, groups=G))
        # silu(y) ≈ y for |y| ≤ ~4 with mean shifted by the sigmoid;
        # recover the pre-activation scale instead: invert is overkill,
        # just demand the normalized spread survived (bf16 stats would
        # produce rstd from a garbage variance → wildly wrong spread)
        want = _f32(ref.groupnorm_silu(x, ones, zeros, groups=G))
        np.testing.assert_allclose(out, want, rtol=5e-2, atol=5e-2)
        spread = np.std(out)
        assert 0.3 < spread < 1.2, spread


def test_indivisible_channels_raise():
    x = jnp.zeros((2, 8, 30))
    with pytest.raises(ValueError):
        ops.groupnorm_silu(x, jnp.ones((30,)), jnp.zeros((30,)), groups=8)
