"""Shape/dtype sweep: solver_step Pallas kernel (interpret) vs jnp oracle.

bf16 operands exercise the precision-policy contract (DESIGN.md §8):
the kernel upcasts each tile to fp32, keeps the error accumulation in
fp32 (e2 is fp32 for every operand dtype), and rounds only the x''
store back to bf16 — so kernel and oracle agree to fp32-accumulation
tolerance, not bf16 tolerance. The shape list includes D values not
divisible by the 128-lane width, so bf16 zero-padding is exercised too.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.solver_step import ops, ref

SHAPES = [(1, 128), (4, 300), (8, 3072), (3, 17), (16, 1024), (2, 65536)]
DTYPES = [jnp.float32, jnp.bfloat16]
# the fp32 step math is identical on both sides, so even bf16 outputs
# only differ by the final rounding — and e2 (fp32 everywhere) only by
# the kernel's tiled accumulation order
TOLS = {
    jnp.dtype(jnp.float32): dict(rtol=1e-6, atol=1e-6),
    jnp.dtype(jnp.bfloat16): dict(rtol=1e-2, atol=1e-2),
}


def _f32(a):
    return np.asarray(a, np.float32)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_em_step_matches_ref(shape, dtype, rng):
    B, D = shape
    ks = jax.random.split(rng, 6)
    x, s, z = (jax.random.normal(k, shape, dtype) for k in ks[:3])
    c0, c1, c2 = (jax.random.uniform(k, (B,), jnp.float32) for k in ks[3:])
    out = ops.em_step(x, s, z, c0, c1, c2)
    assert out.dtype == jnp.dtype(dtype)
    np.testing.assert_allclose(
        _f32(out), _f32(ref.em_step(x, s, z, c0, c1, c2)),
        **TOLS[jnp.dtype(dtype)],
    )


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("use_prev", [True, False], ids=["prev", "noprev"])
def test_error_step_matches_ref(shape, dtype, use_prev, rng):
    B, D = shape
    ks = jax.random.split(rng, 8)
    x, xp, s2, z, xv = (jax.random.normal(k, shape, dtype) for k in ks[:5])
    e0, d1, d2 = (jax.random.uniform(k, (B,)) for k in ks[5:])
    kw = dict(eps_abs=0.0078, eps_rel=0.05, use_prev=use_prev)
    xh_k, e2_k = ops.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    xh_r, e2_r = ref.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    assert xh_k.dtype == jnp.dtype(dtype)
    # the error/decision output is fp32 regardless of operand dtype
    assert e2_k.dtype == jnp.float32 and e2_r.dtype == jnp.float32
    np.testing.assert_allclose(_f32(xh_k), _f32(xh_r),
                               **TOLS[jnp.dtype(dtype)])
    np.testing.assert_allclose(np.asarray(e2_k), np.asarray(e2_r),
                               rtol=1e-5, atol=1e-6)


def test_error_step_multidim_state(rng):
    """Image-shaped state (B, H, W, C) flattens correctly."""
    shape = (3, 8, 8, 3)
    ks = jax.random.split(rng, 8)
    x, xp, s2, z, xv = (jax.random.normal(k, shape) for k in ks[:5])
    e0, d1, d2 = (jax.random.uniform(k, (3,)) for k in ks[5:])
    kw = dict(eps_abs=0.0078, eps_rel=0.05)
    xh_k, e2_k = ops.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    flat = lambda a: a.reshape(3, -1)
    xh_r, e2_r = ref.error_step(
        flat(x), flat(xp), flat(s2), flat(z), flat(xv), e0, d1, d2, **kw
    )
    assert xh_k.shape == shape
    np.testing.assert_allclose(np.asarray(flat(xh_k)), np.asarray(xh_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(e2_k), np.asarray(e2_r),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("use_prev", [True, False], ids=["prev", "noprev"])
def test_error_step_vec_matches_ref(shape, dtype, use_prev, rng):
    """Per-sample tolerance form (DESIGN.md §14): with (B,) ε vectors of
    *distinct* values the kernel must agree with the oracle row-wise —
    each sample's mixed-error norm sees only its own (atol, rtol)."""
    B, D = shape
    ks = jax.random.split(rng, 10)
    x, xp, s2, z, xv = (jax.random.normal(k, shape, dtype) for k in ks[:5])
    e0, d1, d2 = (jax.random.uniform(k, (B,)) for k in ks[5:8])
    eps_abs = jax.random.uniform(ks[8], (B,), jnp.float32, 1e-3, 0.1)
    eps_rel = jax.random.uniform(ks[9], (B,), jnp.float32, 0.01, 0.5)
    kw = dict(eps_abs=eps_abs, eps_rel=eps_rel, use_prev=use_prev)
    xh_k, e2_k = ops.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    xh_r, e2_r = ref.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    assert xh_k.dtype == jnp.dtype(dtype)
    assert e2_k.dtype == jnp.float32 and e2_r.dtype == jnp.float32
    np.testing.assert_allclose(_f32(xh_k), _f32(xh_r),
                               **TOLS[jnp.dtype(dtype)])
    np.testing.assert_allclose(np.asarray(e2_k), np.asarray(e2_r),
                               rtol=1e-5, atol=1e-6)


def test_error_step_uniform_vec_bitwise_matches_scalar(rng):
    """The bitwise-identity premise the tiered serving gate rests on
    (DESIGN.md §14): a uniform (B,) tolerance vector is the same fp32
    broadcast multiply as the scalar constant — identical bits in both
    x'' and e2, so single-class serving cannot drift from the static
    config path."""
    B, D = 8, 3072
    ks = jax.random.split(rng, 8)
    x, xp, s2, z, xv = (jax.random.normal(k, (B, D)) for k in ks[:5])
    e0, d1, d2 = (jax.random.uniform(k, (B,)) for k in ks[5:])
    ea, er = 0.0078, 0.05
    xh_s, e2_s = ops.error_step(x, xp, s2, z, xv, e0, d1, d2,
                                eps_abs=ea, eps_rel=er)
    xh_v, e2_v = ops.error_step(
        x, xp, s2, z, xv, e0, d1, d2,
        eps_abs=jnp.full((B,), ea, jnp.float32),
        eps_rel=jnp.full((B,), er, jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(xh_s), np.asarray(xh_v))
    np.testing.assert_array_equal(np.asarray(e2_s), np.asarray(e2_v))


def test_fused_solver_matches_jnp_solver(rng):
    """Full Algorithm 1 with use_fused_kernel=True == jnp path."""
    from repro.core import VPSDE, sample
    from repro.core.analytic import gaussian_score

    sde = VPSDE()
    score = gaussian_score(sde, 0.3, 0.5)

    r1 = jax.jit(lambda k: sample(sde, score, (32, 24), k, method="adaptive",
                                  eps_rel=0.02))(rng)
    r2 = jax.jit(lambda k: sample(sde, score, (32, 24), k, method="adaptive",
                                  eps_rel=0.02, use_fused_kernel=True))(rng)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-4, atol=1e-4)
    assert int(r1.iterations) == int(r2.iterations)


@pytest.mark.parametrize("eps_rel", [0.02, 0.004], ids=["mild", "reject-heavy"])
def test_fused_solver_parity_under_rejection(eps_rel, rng):
    """Forced-rejection parity: with a tiny eps_rel the accept/reject mix
    is rejection-dominated, and the fused kernel must walk the *exact*
    same decision path as the jnp oracle — bit-identical per-sample
    accepted/rejected/nfe counters at every chunk boundary and at the
    end — with the states tightly close (the in-VMEM error reduction
    sums in a different order, so x is allclose rather than bitwise)."""
    from repro.core import (
        AdaptiveConfig, VPSDE, finalize, init_carry, solve_chunk,
    )
    from repro.core.analytic import gaussian_score

    sde = VPSDE()
    score = gaussian_score(sde, 0.3, 0.5)

    k_prior, k_solve = jax.random.split(rng)
    x0 = sde.prior_sample(k_prior, (16, 24))
    carries = {}
    steps = {}
    for fused in (False, True):
        cfg = AdaptiveConfig(eps_rel=eps_rel, use_fused_kernel=fused)
        carries[fused] = init_carry(sde, x0, k_solve, config=cfg)
        steps[fused] = jax.jit(
            lambda c, cfg=cfg: solve_chunk(sde, score, c, max_sync_iters=25,
                                           config=cfg)
        )
    while bool(jnp.any(~carries[False].done)):
        for fused in (False, True):
            carries[fused] = steps[fused](carries[fused])
        for name in ("nfe", "accepted", "rejected", "done"):
            np.testing.assert_array_equal(
                np.asarray(getattr(carries[False], name)),
                np.asarray(getattr(carries[True], name)), err_msg=name,
            )
        # t and h follow err into next_step_size, and the kernel's in-VMEM
        # reduction order perturbs err's last bits — tightly close, not
        # bitwise (unlike the integer decision path above)
        np.testing.assert_allclose(
            np.asarray(carries[False].t), np.asarray(carries[True].t),
            rtol=1e-5, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(carries[False].x), np.asarray(carries[True].x),
            rtol=1e-4, atol=1e-4,
        )
    r_jnp = finalize(sde, score, carries[False], denoise=False)
    r_fused = finalize(sde, score, carries[True], denoise=False)
    # the mix genuinely contains both branches
    rej, acc = int(r_jnp.rejected.sum()), int(r_jnp.accepted.sum())
    assert rej > 0 and acc > 0
    if eps_rel < 0.01:
        assert rej / (rej + acc) > 0.2  # rejection-heavy regime
    np.testing.assert_array_equal(np.asarray(r_jnp.nfe), np.asarray(r_fused.nfe))
    np.testing.assert_allclose(np.asarray(r_jnp.x), np.asarray(r_fused.x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("preset", ["bf16", "bf16_full"])
def test_fused_solver_bf16_decision_parity(preset, rng):
    """Acceptance gate (DESIGN.md §8): from an *identical* carry, one
    fused iteration must take the exact accept/reject decision the jnp
    reference takes — both compute the scaled-ℓ2 error in fp32 from the
    same state-dtype inputs, so the per-sample nfe/accepted/rejected
    deltas are bit-identical and the states agree to the state dtype's
    resolution.

    The comparison is per-step from a shared carry (the jnp trajectory),
    sampled along the entire solve. A whole-trajectory counter
    comparison would not be sound under bf16: the kernel's tiled
    reduction perturbs h in its last bits, the bf16-quantized score
    amplifies that into O(1e-3) state divergence, and from then on the
    two paths decide over *different* states."""
    from repro.core import AdaptiveConfig, VPSDE, init_carry, solve_chunk
    from repro.core.analytic import gaussian_score

    sde = VPSDE()
    score = gaussian_score(sde, 0.3, 0.5)
    k_prior, k_solve = jax.random.split(rng)
    x0 = sde.prior_sample(k_prior, (16, 24))
    atol = 5e-3 if preset == "bf16" else 2e-2  # state fp32 vs bf16
    step1 = {}
    for fused in (False, True):
        cfg = AdaptiveConfig(eps_rel=0.02, precision=preset,
                             use_fused_kernel=fused)
        step1[fused] = jax.jit(
            lambda c, cfg=cfg: solve_chunk(sde, score, c, max_sync_iters=1,
                                           config=cfg)
        )
    cfg = AdaptiveConfig(eps_rel=0.02, precision=preset)
    carry = init_carry(sde, x0, k_solve, config=cfg)
    compared = 0
    while bool(jnp.any(~carry.done)):
        a = step1[False](carry)  # jnp reference step
        b = step1[True](carry)   # fused step from the SAME carry
        for name in ("nfe", "accepted", "rejected", "done"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
                err_msg=name,
            )
        np.testing.assert_allclose(_f32(a.x), _f32(b.x), rtol=atol, atol=atol)
        np.testing.assert_allclose(np.asarray(a.h), np.asarray(b.h),
                                   rtol=1e-5, atol=1e-6)
        carry = a  # continue along the jnp trajectory
        compared += 1
    # both branches of the decision were genuinely exercised
    assert int(carry.rejected.sum()) > 0 and int(carry.accepted.sum()) > 0
    assert compared > 20
