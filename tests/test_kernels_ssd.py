"""Shape sweep: SSD Pallas kernel + chunked jnp vs sequential oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ops, ref

CASES = [
    # B, S, H, P, G, N, chunk — the longest-sequence case is slow-only
    (2, 128, 4, 64, 1, 64, 32),
    (1, 100, 8, 32, 2, 32, 32),
    pytest.param((2, 256, 2, 64, 2, 128, 128), marks=pytest.mark.slow),
    (1, 64, 4, 32, 4, 16, 16),
]


def _inputs(case, rng):
    B, S, H, P, G, N, chunk = case
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    C = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, C


def _seq_ref(x, dt, A, Bm, C):
    y, _ = ref.ssd_scan(
        jnp.transpose(x, (0, 2, 1, 3)), jnp.transpose(dt, (0, 2, 1)), A,
        jnp.transpose(Bm, (0, 2, 1, 3)), jnp.transpose(C, (0, 2, 1, 3)),
    )
    return jnp.transpose(y, (0, 2, 1, 3))


@pytest.mark.parametrize("case", CASES, ids=str)
def test_pallas_matches_sequential(case, rng):
    x, dt, A, Bm, C = _inputs(case, rng)
    y = ops.ssd_scan(x, dt, A, Bm, C, chunk=case[-1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(_seq_ref(x, dt, A, Bm, C)),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("case", CASES, ids=str)
def test_chunked_jnp_matches_sequential(case, rng):
    x, dt, A, Bm, C = _inputs(case, rng)
    y = ref.ssd_chunked(x, dt, A, Bm, C, chunk=case[-1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(_seq_ref(x, dt, A, Bm, C)),
                               rtol=3e-4, atol=3e-4)


def test_mamba_decode_matches_forward(rng):
    """Single-token recurrent decode reproduces the parallel forward."""
    from repro.models import ModelConfig, MambaConfig
    from repro.models.mamba2 import (
        init_mamba, init_mamba_decode_state, mamba_decode, mamba_forward,
    )

    cfg = ModelConfig(
        name="m", arch_type="ssm", num_layers=1, d_model=32, num_heads=1,
        num_kv_heads=1, d_ff=0, vocab_size=16, mixer_pattern=("M",),
        mlp_pattern=("N",), mamba=MambaConfig(d_state=16, head_dim=16),
    )
    params = init_mamba(rng, cfg)
    x = jax.random.normal(rng, (2, 10, 32))
    y_full = mamba_forward(params, x, cfg)
    state = init_mamba_decode_state(cfg, 2)
    ys = []
    for i in range(10):
        y, state = mamba_decode(params, x[:, i : i + 1], cfg, state)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
