"""Probability-flow log-likelihood: exact on analytically known
distributions (the flow property of score-based models)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VPSDE, VESDE
from repro.core.likelihood import log_likelihood
from repro.data.images import GMM2D


def _gaussian_score(sde, mu, s0):
    def score(x, t):
        m, std = sde.marginal(t)
        m = m.reshape((-1,) + (1,) * (x.ndim - 1))
        std = std.reshape((-1,) + (1,) * (x.ndim - 1))
        return -(x - m * mu) / (m * m * s0 * s0 + std * std)

    return score


@pytest.mark.parametrize("sde", [VPSDE(), VESDE(sigma_max=10.0)],
                         ids=["vp", "ve"])
def test_gaussian_loglik_exact(sde, rng):
    """For N(mu, s0²) data with its exact score, the PF-ODE likelihood
    must match the closed form."""
    mu, s0 = 0.3, 0.5
    x = mu + s0 * jax.random.normal(rng, (16, 4))
    ll = log_likelihood(sde, _gaussian_score(sde, mu, s0), x, n_steps=300)
    want = -0.5 * (
        jnp.sum(((x - mu) / s0) ** 2, axis=1)
        + 4 * jnp.log(2 * jnp.pi * s0 * s0)
    )
    np.testing.assert_allclose(np.asarray(ll), np.asarray(want),
                               rtol=0.0, atol=0.15)


@pytest.mark.slow
def test_gmm_loglik_matches_closed_form(rng):
    """2-D 4-mode mixture with exact time-t score: PF-ODE likelihood ≈
    the mixture's exact log-density. (slow job: the RK45 likelihood
    solve is the suite's priciest single integral; the Gaussian exact
    and Hutchinson cases keep the fast tier covered)"""
    sde = VPSDE()
    gmm = GMM2D()
    score = gmm.score_at_time(sde)
    x = gmm.sample(rng, 32)
    ll = log_likelihood(sde, score, x, n_steps=400)

    means = jnp.asarray(gmm.means)
    w = jnp.asarray(gmm.weights)

    def exact(xi):
        comp = -0.5 * jnp.sum((xi - means) ** 2, -1) / gmm.std**2 \
            - jnp.log(2 * jnp.pi * gmm.std**2)
        return jax.scipy.special.logsumexp(comp + jnp.log(w))

    want = jax.vmap(exact)(x)
    np.testing.assert_allclose(np.asarray(ll), np.asarray(want),
                               atol=0.2)


def test_hutchinson_agrees_with_exact(rng):
    sde = VPSDE()
    score = _gaussian_score(sde, 0.0, 1.0)
    x = jax.random.normal(rng, (8, 6))
    ll_e = log_likelihood(sde, score, x, n_steps=150, method="exact")
    ll_h = log_likelihood(sde, score, x, n_steps=150, method="hutchinson",
                          key=rng, probes=64)
    np.testing.assert_allclose(np.asarray(ll_h), np.asarray(ll_e), atol=0.5)


def test_higher_density_points_score_higher(rng):
    """Ordering sanity: the mode has higher log-likelihood than the tail."""
    sde = VPSDE()
    score = _gaussian_score(sde, 0.0, 0.5)
    x_mode = jnp.zeros((4, 3))
    x_tail = jnp.full((4, 3), 2.0)
    ll = log_likelihood(sde, score, jnp.concatenate([x_mode, x_tail]),
                        n_steps=150)
    assert float(ll[:4].min()) > float(ll[4:].max())
