"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates the REDUCED variant of the same family (pattern
preserved, ≤2 pattern repeats, d_model ≤ 256, ≤4 experts) and runs one
forward and one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import lm_loss
from repro.launch.steps import make_train_step
from repro.models import decode_step, forward, init_decode_state, init_model
from repro.optim import AdamW

# The bulkiest reduced configs (deep scans / MoE dispatch / vision tower)
# dominate suite wall-clock; they run in CI's slow job, while the default
# run keeps one representative of every mixer family (attention, SSM,
# MoE, multi-codebook) via the remaining archs.
HEAVY_ARCHS = {"jamba-v0.1-52b", "gemma3-12b", "deepseek-moe-16b",
               "llama-3.2-vision-90b", "musicgen-medium", "qwen3-14b"}


def _arch_cases(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow)
        if isinstance(a, str) and a in HEAVY_ARCHS else a
        for a in archs
    ]


def _batch(cfg, key, B=2, S=24):
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks}
    if cfg.vision_dim:
        b["cross_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.vision_dim), jnp.dtype(cfg.dtype)
        )
    return b


@pytest.mark.parametrize("arch", _arch_cases(ARCH_IDS))
def test_reduced_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).scaled_down()
    params = init_model(cfg, rng)
    b = _batch(cfg, rng)
    logits, aux = forward(params, b["tokens"], cfg,
                          cross_embeds=b.get("cross_embeds"))
    B, S = b["tokens"].shape[:2]
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", _arch_cases(ARCH_IDS))
def test_reduced_train_step(arch, rng):
    cfg = get_config(arch).scaled_down()
    params = init_model(cfg, rng)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    b = _batch(cfg, rng)
    params2, opt_state, metrics = step(params, opt_state, b)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), params, params2
    )
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", _arch_cases(ARCH_IDS))
def test_reduced_decode_step(arch, rng):
    cfg = get_config(arch).scaled_down()
    params = init_model(cfg, rng)
    B = 2
    state = init_decode_state(cfg, B, cache_len=8)
    shape = (B, 1) if cfg.num_codebooks == 1 else (B, 1, cfg.num_codebooks)
    tok = jax.random.randint(rng, shape, 0, cfg.vocab_size)
    cross = (
        jax.random.normal(rng, (B, cfg.num_patches, cfg.vision_dim),
                          jnp.dtype(cfg.dtype))
        if cfg.vision_dim else None
    )
    logits, state2 = decode_step(params, tok, state, cfg, cross_embeds=cross)
    assert logits.shape[:2] == (B, 1)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize(
    "arch", _arch_cases([
        "olmo-1b", "gemma3-12b",
        # SSD decode-vs-forward parity is covered fast by
        # tests/test_kernels_ssd.py::test_mamba_decode_matches_forward
        pytest.param("mamba2-2.7b", marks=pytest.mark.slow),
        "deepseek-moe-16b", "musicgen-medium",
    ])
)
def test_decode_matches_teacher_forcing(arch, rng):
    """Incremental decode must reproduce the teacher-forced logits."""
    cfg = get_config(arch).scaled_down()
    params = init_model(cfg, rng)
    B, S = 2, 10
    b = _batch(cfg, rng, B=B, S=S)
    toks = b["tokens"]
    logits_tf, _ = forward(params, toks, cfg,
                           cross_embeds=b.get("cross_embeds"))
    state = init_decode_state(cfg, B, cache_len=S + 2)
    outs = []
    for i in range(S):
        lg, state = decode_step(params, toks[:, i : i + 1], state, cfg,
                                cross_embeds=b.get("cross_embeds"))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    assert float(jnp.abs(logits_tf - logits_dec).max()) < 5e-4


@pytest.mark.slow
def test_loss_decreases_on_reduced_arch(rng):
    """End-to-end: a few train steps reduce CE on the synthetic stream.
    (slow job: tests/test_diffusion_lm.py keeps a fast train-loop e2e)"""
    from repro.launch.train import train_loop

    cfg = get_config("qwen1.5-0.5b").scaled_down()
    _, losses = train_loop(cfg, steps=30, batch=8, seq=64, lr=3e-3,
                           log_every=100)
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]
