"""MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, MoEConfig
from repro.models.moe import _capacity, apply_moe, init_moe


def _cfg(num_experts=4, top_k=2, shared=0, cf=1.25):
    return ModelConfig(
        name="moe-test", arch_type="moe", num_layers=1, d_model=32,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=16,
        mlp_pattern=("E",),
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, expert_ffn=16,
                      num_shared_experts=shared, shared_ffn=16 * max(shared, 1),
                      capacity_factor=cf),
    )


def test_output_shape_and_finite(rng):
    cfg = _cfg()
    params = init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 16, 32))
    y, aux = apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_aux_loss_minimized_by_uniform_routing(rng):
    """The Switch aux loss lower bound (X · Σ f·p = 1 at uniform) scaled
    by the weight — uniform router logits should be near it."""
    cfg = _cfg(num_experts=8, top_k=2)
    params = init_moe(rng, cfg)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jax.random.normal(rng, (4, 64, 32))
    _, aux = apply_moe(params, x, cfg)
    assert float(aux) == pytest.approx(cfg.moe.router_aux_weight, rel=0.05)


def test_capacity_overflow_drops_tokens(rng):
    """With capacity_factor → tiny, most tokens overflow and the routed
    output collapses toward zero (tokens fall through)."""
    cfg_small = _cfg(cf=0.05)
    cfg_big = _cfg(cf=8.0)
    params = init_moe(rng, cfg_small, )
    x = jax.random.normal(rng, (2, 64, 32))
    y_small, _ = apply_moe(params, x, cfg_small)
    y_big, _ = apply_moe(params, x, cfg_big)
    assert float(jnp.abs(y_small).mean()) < float(jnp.abs(y_big).mean())


def test_shared_experts_always_active(rng):
    """Zeroing the routed experts leaves the shared path: output nonzero."""
    cfg = _cfg(shared=2)
    params = init_moe(rng, cfg)
    params["w_out"] = jnp.zeros_like(params["w_out"])
    x = jax.random.normal(rng, (2, 8, 32))
    y, _ = apply_moe(params, x, cfg)
    assert float(jnp.abs(y).mean()) > 1e-3


def test_group_size_does_not_change_small_batch(rng):
    """When all tokens fit in one group at high capacity, grouping is a
    no-op: different group sizes agree."""
    cfg = _cfg(cf=8.0)
    params = init_moe(rng, cfg)
    x = jax.random.normal(rng, (2, 32, 32))
    y1, _ = apply_moe(params, x, cfg, group_size=64)
    y2, _ = apply_moe(params, x, cfg, group_size=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)


def test_capacity_formula():
    mc = MoEConfig(num_experts=8, top_k=2, expert_ffn=4, capacity_factor=1.0)
    assert _capacity(64, mc) == 16  # 2·64/8
    mc2 = MoEConfig(num_experts=8, top_k=2, expert_ffn=4, capacity_factor=1.25)
    assert _capacity(64, mc2) == 20


@pytest.mark.slow
def test_gather_dispatch_matches_einsum(rng):
    """The §Perf gather/scatter dispatch is numerically identical to the
    GShard one-hot einsum baseline, including capacity overflow.
    (slow job: 6 jit variants dominate; the fast tier keeps the einsum
    path covered via the other moe tests)"""
    import numpy as np

    for X, k, cf in [(8, 3, 1.25), (4, 2, 0.5), (16, 2, 2.0)]:
        cfg = _cfg(num_experts=X, top_k=k, cf=cf)
        params = init_moe(rng, cfg)
        x = jax.random.normal(rng, (2, 100, 32))
        y1, a1 = apply_moe(params, x, cfg, group_size=64, dispatch="einsum")
        y2, a2 = apply_moe(params, x, cfg, group_size=64, dispatch="gather")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-5, atol=2e-5)
        assert float(a1) == float(a2)
