"""Per-iteration NFE accounting across the solver zoo (DESIGN.md §7).

Serving's waste accounting converts device loop iterations into issued
score-net evaluations. That conversion factor used to be a hardcoded 2
(right only for the Algorithm-1 families; e.g. for ``pc_hmc`` — whose
grid steps each issue ``1 + corrector_steps·leapfrog`` evaluations — the
issued count undershot the *useful* count and the waste fraction went
negative). It now comes from the registry rule each solver declares at
registration. These tests pin the rules against the solvers' own
measured NFE counters, family by family.
"""

import jax
import pytest

from repro.core import VESDE, VPSDE, sample
from repro.core.analytic import gaussian_score
from repro.core.solvers import base as solvers_base
from repro.core.solvers import solver_nfe_per_iteration

B, D = 16, 8


# name → (sampling kwargs, registry-rule kwargs). Zoo configurations
# (analysis/solver_select.ZOO), shrunk where cost is config-independent.
CASES = {
    "em": (dict(n_steps=50), {}),
    "ddim": (dict(n_steps=25), {}),
    "adaptive": (dict(eps_rel=0.05), {}),
    "momentum": (dict(eps_rel=0.05, momentum=0.15), {}),
    "heun": (dict(eps_rel=0.05, probability_flow=True), {}),
    "ode": ({}, {}),
    "pc": (dict(n_steps=30, corrector_steps=2),
           dict(corrector_steps=2)),
    "pc_hmc": (dict(n_steps=30, corrector_steps=1, hmc_leapfrog=3),
               dict(corrector_steps=1, hmc_leapfrog=3)),
}


@pytest.mark.parametrize("method", list(CASES), ids=list(CASES))
def test_registry_rule_matches_measured_nfe(method, rng):
    """per-iteration rule · iterations == the solver's own issued-NFE
    counter. ``denoise=False`` so the one-off Tweedie evaluation does not
    blur the per-iteration factor; for the adaptive carry families the
    per-sample identity nfe_i = rule·(accepted_i + rejected_i) must hold
    sample-by-sample (iterations only bound the *slowest* sample)."""
    kwargs, rule_kwargs = CASES[method]
    per_iter = solver_nfe_per_iteration(method, **rule_kwargs)
    sde = VPSDE()
    res = jax.jit(
        lambda k: sample(sde, gaussian_score(sde), (B, D), k,
                         method=method, denoise=False, **kwargs)
    )(rng)
    if method in ("adaptive", "momentum", "heun"):
        import numpy as np

        np.testing.assert_array_equal(
            np.asarray(res.nfe),
            per_iter * np.asarray(res.accepted + res.rejected))
        assert int((res.accepted + res.rejected).max()) <= int(res.iterations)
    else:
        assert int(res.nfe.min()) == int(res.nfe.max())  # fixed cost
        # rk45 seeds its FSAL k1 with one evaluation before the loop —
        # a one-off like the Tweedie eval, outside the per-iteration rate
        seed_evals = 1 if method == "ode" else 0
        assert int(res.nfe[0]) == per_iter * int(res.iterations) + seed_evals


def test_rule_values_track_configuration():
    """The callable rules scale with their cost-relevant kwargs."""
    assert solver_nfe_per_iteration("em") == 1
    assert solver_nfe_per_iteration("ddim") == 1
    assert solver_nfe_per_iteration("adaptive") == 2
    assert solver_nfe_per_iteration("ode") == 6
    # pc: 1 predictor + corrector_steps Langevin evaluations
    assert solver_nfe_per_iteration("pc") == 2
    assert solver_nfe_per_iteration("pc", corrector_steps=3) == 4
    # hmc correctors pay leapfrog evaluations per corrector pass
    assert solver_nfe_per_iteration("pc_hmc") == \
        solver_nfe_per_iteration("pc", corrector="hmc")
    assert solver_nfe_per_iteration(
        "pc_hmc", corrector_steps=2, hmc_leapfrog=5) == 11
    # cost-irrelevant kwargs (the solver's full signature) are ignored
    assert solver_nfe_per_iteration("em", n_steps=999) == 1


def test_unknown_or_undeclared_solver_raises(monkeypatch):
    """Accounting must never silently fall back to a wrong constant."""
    with pytest.raises(ValueError, match="unknown solver"):
        solver_nfe_per_iteration("not_a_solver")
    monkeypatch.setitem(solvers_base._REGISTRY, "_norule", lambda: None)
    with pytest.raises(ValueError, match="no per-iteration NFE rule"):
        solver_nfe_per_iteration("_norule")


def test_ve_fixed_grid_accounting(rng):
    """The rule is SDE-independent: same identity under VESDE."""
    sde = VESDE(sigma_max=10.0)
    res = jax.jit(
        lambda k: sample(sde, gaussian_score(sde), (B, D), k,
                         method="pc", n_steps=20, corrector_steps=2,
                         denoise=False)
    )(rng)
    per_iter = solver_nfe_per_iteration("pc", corrector_steps=2)
    assert int(res.nfe[0]) == per_iter * int(res.iterations)
