"""Observability-layer conformance (DESIGN.md §15).

The tentpole contract, pinned from four directions:

  * **Telemetry is structurally invisible when off, and inert when on.**
    For every solver family the Algorithm-1 body hosts (adaptive,
    heavy-ball momentum, probability-flow/Heun) × every serving mode
    (sync_horizon 1 / 8, device-resident), a telemetry-on drain delivers
    bitwise-identical samples, NFE, and accept/reject books to the
    telemetry-off drain — and adds zero host transfers.
  * **The ring records the truth.** A host-replayed oracle — the same
    solve advanced one iteration per host visit, reading (t, h,
    accepted) off the carry before each step — must match the on-device
    ring record for record, including wraparound and chunk-boundary
    invariance of the monotone head cursor.
  * **The books reconcile.** A mixed-tier wave's ``trace_record()``
    must reconcile exactly: ring accept/reject sums == Σ per-request
    books == registry counters == the delivery stage's per-tier stats,
    with ``nfe == 2·(accepted + rejected)`` per request and
    ``head == total_iterations``.
  * **Request ids survive compaction.** Admission spans and delivery
    spans tell one consistent story per uid even as slot compaction
    permutes seats under the requests.

Plus the satellite guards: the ``benchmarks.run`` BENCH_*.json artifact
contract, the quality-proxy gauges (proxy-FID, dynamics-consistency),
and the metrics registry's JSON/Prometheus export.
"""

import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.telemetry import (
    active_records, nfe_percentiles, step_size_vs_t, telemetry_markdown,
)
from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.core.solvers.adaptive import init_carry, solve_chunk
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.observability import (
    NULL_TRACER, MetricsRegistry, StageTracer, dynamics_consistency,
    proxy_fid, telemetry_history,
)
from repro.planning.envs import OUEnv, PointMassEnv
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
D = 32
N_REQ = 6
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: solver families routed through the Algorithm-1 body (DESIGN.md §11):
#: telemetry must be a pure observer for each of them
FAMILIES = {
    "adaptive": {},
    "momentum": dict(momentum=0.3),
    "heun": dict(probability_flow=True),
}
#: serving modes the off==on guarantee must hold under
MODES = {
    "h1": dict(sync_horizon=1),
    "h8": dict(sync_horizon=8),
    "device-resident": dict(sync_horizon=4, device_resident=True),
}
#: the §14 mixed wave (tier names + tier-less defaults) for the
#: reconciliation test
WAVE = ["draft", "high_fidelity", None, "standard", "draft", None,
        "high_fidelity", "draft", "standard", None]


def _active_threshold(t_eps) -> float:
    """The device's activity test (``t > t_eps + 1e-12``) runs in fp32;
    idle serving slots sit at exactly fp32(t_eps), so host-side replicas
    must compare against the fp32-rounded threshold."""
    return float(np.float32(float(t_eps) + 1e-12))


@pytest.fixture(scope="module")
def families():
    sde = VPSDE()
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # unused shapes; signature holder
    out = {}
    for name, over in FAMILIES.items():
        cfg = dataclasses.replace(AdaptiveConfig(eps_rel=0.05), **over)
        step = make_sample_step(net, sde, cfg,
                                forward_fn=gaussian_noise_pred(sde, MU, S0))
        out[name] = (cfg, step)
    return sde, out


def _score_fn(sde):
    """The exact score math make_sample_step builds from the noise-pred
    forward_fn (same ops, same casts — see test_tolerance_tiers)."""
    fwd = gaussian_noise_pred(sde, MU, S0)

    def score(x, t):
        _, std = sde.marginal(t)
        out = fwd(None, x, t).astype(jnp.float32)
        return -out / std.reshape((-1,) + (1,) * (x.ndim - 1))

    return score


def _serve(sde, cfg, step, n_req=N_REQ, tiers=None, **kw):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, **kw)
    for uid in range(n_req):
        tier = tiers[uid % len(tiers)] if tiers else None
        b.submit(ImageRequest(uid=uid, seed=1000 + uid, tier=tier))
    done = b.run_to_completion()
    assert len(done) == n_req
    return b, done


# --------------------------------------------------------------------------
# telemetry-off == telemetry-on, bit for bit, across families × modes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", list(MODES), ids=list(MODES))
@pytest.mark.parametrize("family", list(FAMILIES), ids=list(FAMILIES))
def test_telemetry_off_on_bitwise_identical(families, family, mode):
    """Recording never feeds back: a telemetry-on drain is sample-,
    NFE-, and accept/reject-identical to the telemetry-off drain, adds
    no host transfers, and its monotone ring head equals the serve
    loop's folded iteration counter."""
    sde, fam = families
    cfg, step = fam[family]
    kw = MODES[mode]
    b_off, off = _serve(sde, cfg, step, **kw)
    b_on, on = _serve(sde, cfg, step, telemetry=256, **kw)
    for uid in off:
        np.testing.assert_array_equal(
            np.asarray(off[uid].result), np.asarray(on[uid].result),
            err_msg=f"family={family} mode={mode} uid={uid}")
        assert off[uid].nfe == on[uid].nfe, (family, mode, uid)
        assert off[uid].accepted == on[uid].accepted, (family, mode, uid)
        assert off[uid].rejected == on[uid].rejected, (family, mode, uid)
    assert b_on.host_transfers == b_off.host_transfers, (family, mode)
    assert b_off._carry.telemetry is None
    head = int(np.asarray(b_on._carry.telemetry.head))
    assert head == b_on.total_iterations == b_off.total_iterations


# --------------------------------------------------------------------------
# ring vs host-replayed oracle (+ wraparound, chunk-boundary invariance)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def oracle_runs():
    """One batch-4 solve run four ways: telemetry-off one-iteration-at-
    a-time replay (the oracle), monolithic telemetry-on, small-capacity
    telemetry-on (forced wraparound), and h1-chunked telemetry-on."""
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    score = _score_fn(sde)
    B = 4
    kp, kn = jax.random.split(jax.random.PRNGKey(7))
    x0 = sde.prior_sample(kp, (B, D))
    nk = jax.random.split(kn, B)
    eps = _active_threshold(sde.t_eps)

    step1 = jax.jit(
        lambda c: solve_chunk(sde, score, c, max_sync_iters=1, config=cfg))
    solve_all = jax.jit(
        lambda c: solve_chunk(sde, score, c, max_sync_iters=4096, config=cfg))

    # oracle: telemetry-off, host reads (t, h, accepted) before each
    # single-iteration chunk — exactly what the ring should have written
    c = init_carry(sde, x0, nk, config=cfg)
    ts, hs, dacc = [], [], []
    for _ in range(10_000):
        t_prev, h_prev = np.asarray(c.t), np.asarray(c.h)
        active = t_prev > eps
        if not active.any():
            break
        acc_prev = np.asarray(c.accepted)
        c = step1(c)
        ts.append(t_prev.astype(np.float32))
        hs.append(np.where(active, h_prev, 0.0).astype(np.float32))
        dacc.append((np.asarray(c.accepted) - acc_prev).astype(bool))
    oracle = {
        "t": np.stack(ts, axis=1),
        "h": np.stack(hs, axis=1),
        "accept": np.stack(dacc, axis=1),
        "x": np.asarray(c.x),
        "accepted": np.asarray(c.accepted),
        "rejected": np.asarray(c.rejected),
        "n": len(ts),
    }

    c_on = solve_all(init_carry(sde, x0, nk, config=cfg, telemetry=512))
    assert bool(np.asarray(c_on.done).all())

    c_small = solve_all(init_carry(sde, x0, nk, config=cfg, telemetry=8))

    c_ch = init_carry(sde, x0, nk, config=cfg, telemetry=512)
    while not bool(np.asarray(c_ch.done).all()):
        c_ch = step1(c_ch)

    return sde, oracle, c_on, c_small, c_ch


def test_ring_matches_host_replay_oracle(oracle_runs):
    """Every ring record equals what a host replaying the solve one
    iteration at a time observes: raw entry t, the active-clamped
    attempted h, and the accept delta — with err self-consistent
    (accept ⇔ err ≤ 1 on active records) and the solution untouched."""
    sde, oracle, c_on, _, _ = oracle_runs
    hist = telemetry_history(jax.device_get(c_on.telemetry))
    n = oracle["n"]
    assert hist["iterations"] == hist["records"] == n
    np.testing.assert_array_equal(hist["t"], oracle["t"])
    np.testing.assert_array_equal(hist["h"], oracle["h"])
    np.testing.assert_array_equal(hist["accept"], oracle["accept"])
    active = oracle["t"] > _active_threshold(sde.t_eps)
    np.testing.assert_array_equal(
        hist["accept"], (hist["err"] <= 1.0) & active)
    # the ring's aggregate books == the carry's fold counters
    assert hist["accept"].sum(axis=1).tolist() == oracle["accepted"].tolist()
    np.testing.assert_array_equal(
        (active & ~hist["accept"]).sum(axis=1), oracle["rejected"])
    np.testing.assert_array_equal(np.asarray(c_on.x), oracle["x"])


def test_ring_wraparound_keeps_most_recent_records(oracle_runs):
    """A capacity-8 ring on a >8-iteration solve holds exactly the last
    8 records (head keeps the all-time count), and wrapping perturbs
    nothing about the solve itself."""
    _, oracle, c_on, c_small, _ = oracle_runs
    full = telemetry_history(jax.device_get(c_on.telemetry))
    small = telemetry_history(jax.device_get(c_small.telemetry))
    assert oracle["n"] > 8  # the solve must actually wrap the small ring
    assert small["iterations"] == oracle["n"] and small["records"] == 8
    for k in ("t", "h", "err", "accept"):
        np.testing.assert_array_equal(small[k], full[k][:, -8:], err_msg=k)
    np.testing.assert_array_equal(np.asarray(c_small.x), oracle["x"])


def test_ring_is_chunk_boundary_invariant(oracle_runs):
    """Chaining max_sync_iters=1 chunks writes the identical ring the
    monolithic solve writes — head is monotone across host visits, so
    the record has no seam at chunk boundaries."""
    _, _, c_on, _, c_ch = oracle_runs
    full = telemetry_history(jax.device_get(c_on.telemetry))
    chunked = telemetry_history(jax.device_get(c_ch.telemetry))
    assert chunked["iterations"] == full["iterations"]
    for k in ("t", "h", "err", "accept"):
        np.testing.assert_array_equal(chunked[k], full[k], err_msg=k)


# --------------------------------------------------------------------------
# stage tracing: request-id propagation through compaction
# --------------------------------------------------------------------------

def test_request_id_propagation_through_compaction(families):
    """Every uid admitted is delivered under the same uid with its
    per-request NFE on the delivery span — and compaction visibly moved
    at least one request to a different slot between the two spans."""
    sde, fam = families
    cfg, step = fam["adaptive"]
    tracer = StageTracer()
    b, done = _serve(sde, cfg, step, n_req=10, tracer=tracer,
                     sync_horizon=4)
    admit_slot, deliver_slot, deliver_nfe = {}, {}, {}
    for sp in tracer.spans:
        if sp["name"] == "serve/admission":
            for uid, slot in zip(sp["attrs"]["uids"], sp["attrs"]["slots"]):
                admit_slot[uid] = slot
        elif sp["name"] == "serve/delivery":
            for uid, slot, nfe in zip(sp["attrs"]["uids"],
                                      sp["attrs"]["slots"],
                                      sp["attrs"]["nfe"]):
                deliver_slot[uid] = slot
                deliver_nfe[uid] = nfe
    assert set(admit_slot) == set(deliver_slot) == set(range(10))
    for uid, req in done.items():
        assert deliver_nfe[uid] == req.nfe, uid
    moved = [u for u in admit_slot if admit_slot[u] != deliver_slot[u]]
    assert moved, "no request ever crossed slots — compaction untested"
    # spans carry wall-clock structure: every stage shows up, timed
    hist = tracer.stage_histograms()
    for stage in ("serve/admission", "serve/solve", "serve/delivery"):
        assert hist[stage]["count"] > 0, stage
        assert hist[stage]["total_s"] >= 0.0


# --------------------------------------------------------------------------
# the acceptance-criterion reconciliation: trace record vs device counters
# --------------------------------------------------------------------------

def _reconcile(b, rec):
    """One trace record's cross-ledger identities (DESIGN.md §15)."""
    reqs = rec["requests"]
    m = b.metrics
    for r in reqs:
        assert r["nfe"] == 2 * (r["accepted"] + r["rejected"]), r
    acc_req = sum(r["accepted"] for r in reqs)
    rej_req = sum(r["rejected"] for r in reqs)

    tel = rec["telemetry"]
    t = np.asarray(tel["t"])
    acc = np.asarray(tel["accept"]).astype(bool)
    active = t > _active_threshold(tel["t_eps"])
    # nothing wrapped (capacity >> iterations): the ring is the full
    # history, so its sums are exact, not windowed
    assert tel["records"] == tel["iterations"]
    assert tel["iterations"] == b.total_iterations \
        == int(m.value("serve_iterations_total"))
    # idle-slot records never accept: the unfiltered sum agrees too
    assert int(acc.sum()) == int((acc & active).sum()) == acc_req \
        == int(m.value("serve_accepted_total"))
    assert int((active & ~acc).sum()) == rej_req \
        == int(m.value("serve_rejected_total"))
    assert int(m.value("serve_nfe_useful_total")) \
        == sum(r["nfe"] for r in reqs)

    # seam unification: delivery-stage tier books == registry series
    by_tier = {}
    for r in reqs:
        by_tier.setdefault(r["tier"], []).append(r)
    for tier, rs in by_tier.items():
        stats = b.class_stats[tier]
        assert stats["delivered"] == len(rs) \
            == int(m.value("serve_delivered_total", tier=tier))
        assert int(m.value("serve_tier_nfe_total", tier=tier)) \
            == sum(r["nfe"] for r in rs)
        assert stats["deadline_misses"] \
            == int(m.value("serve_deadline_misses_total", tier=tier))
    assert int(m.total("serve_delivered_total")) == len(reqs)
    assert int(m.total("serve_tier_nfe_total")) \
        == int(m.value("serve_nfe_useful_total"))

    stages = {s["name"] for s in rec["trace"]["spans"]}
    assert {"serve/admission", "serve/solve", "serve/delivery"} <= stages


def test_mixed_wave_trace_reconciles_and_renders(families):
    """The ISSUE's acceptance criterion: a mixed 10-request wave with
    telemetry + tracing on yields a JSON trace whose per-request NFE,
    accept/reject counts, and per-stage spans reconcile exactly with
    the device-side counters — and the record renders to the telemetry
    markdown report CI publishes."""
    sde, fam = families
    cfg, step = fam["adaptive"]
    tracer = StageTracer()
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, sync_horizon=4,
                         tolerance_classes=True, telemetry=4096,
                         tracer=tracer)
    for uid, tier in enumerate(WAVE):
        b.submit(ImageRequest(uid=uid, seed=1000 + uid, tier=tier))
    done = b.run_to_completion()
    assert len(done) == len(WAVE)

    # the record is JSON end to end (what launch/serve --trace-out writes)
    rec = json.loads(json.dumps(b.trace_record()))
    assert [r["uid"] for r in rec["requests"]] == list(range(len(WAVE)))
    _reconcile(b, rec)

    # snapshot gauges recompute from the same counters
    g = rec["metrics"]["gauges"]
    assert g["serve_wasted_nfe_fraction"] == pytest.approx(
        b.wasted_nfe_fraction)
    acc = int(b.metrics.value("serve_accepted_total"))
    rej = int(b.metrics.value("serve_rejected_total"))
    assert g["serve_acceptance_rate"] == pytest.approx(acc / (acc + rej))

    # analysis helpers digest the record with the same fp32 idle filter
    live = active_records(rec["telemetry"])
    t = np.asarray(rec["telemetry"]["t"])
    assert live["t"].size == int(
        (t > _active_threshold(rec["telemetry"]["t_eps"])).sum())
    np.testing.assert_array_equal(live["accept"], live["err"] <= 1.0)
    assert step_size_vs_t(rec["telemetry"])  # non-empty binned curves
    pct = nfe_percentiles(rec["requests"])
    assert pct[0]["nfe"] <= pct[-1]["nfe"]

    md = telemetry_markdown(rec)
    for needle in ("# Serve-loop telemetry report", "## Per-stage latency",
                   "## Per-request NFE CDF",
                   "## Step size and accept rate vs t",
                   "## Per-tier delivery", "draft"):
        assert needle in md, needle
    out_dir = os.path.join(ROOT, "experiments", "observability")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "TELEMETRY.md"), "w") as f:
        f.write(md)


def test_device_resident_trace_reconciles(families):
    """The same reconciliation holds on the device-resident path, whose
    iteration counter folds at a different seam (the multi-horizon
    driver) — one registry, same identities."""
    sde, fam = families
    cfg, step = fam["adaptive"]
    tracer = StageTracer()
    b, done = _serve(sde, cfg, step, sync_horizon=4, device_resident=True,
                     telemetry=4096, tracer=tracer)
    rec = json.loads(json.dumps(b.trace_record()))
    _reconcile(b, rec)
    assert sum(r["nfe"] for r in rec["requests"]) \
        == sum(r.nfe for r in done.values())


def test_no_retrace_with_telemetry_on(families):
    """Telemetry is carry *data*: tier churn and ring writes never
    retrace the host-driven step or the device-resident driver/event
    programs (the §12/§14 no-retrace discipline extends to §15)."""
    sde, fam = families
    cfg, step = fam["adaptive"]
    b, _ = _serve(sde, cfg, step, n_req=len(WAVE), tiers=WAVE,
                  sync_horizon=4, tolerance_classes=True, telemetry=128)
    assert b.step_fn._cache_size() == 1
    bd, _ = _serve(sde, cfg, step, sync_horizon=4, device_resident=True,
                   telemetry=128)
    assert bd._driver_fn._cache_size() == 1
    assert bd._event_fn._cache_size() == 1


# --------------------------------------------------------------------------
# benchmark artifact contract (BENCH_*.json at the repo root)
# --------------------------------------------------------------------------

def test_bench_artifact_contract(tmp_path):
    """benchmarks.run: every suite maps to a distinct repo-root
    BENCH_<suite>.json, emit()-CSV parses into structured gated rows,
    and the written artifact carries the stable schema."""
    if ROOT not in sys.path:
        sys.path.insert(0, ROOT)
    import benchmarks.run as bench_run

    assert len(bench_run.SUITES) >= 16
    paths = {bench_run.artifact_path(n) for n in bench_run.SUITES}
    assert len(paths) == len(bench_run.SUITES)
    for n in bench_run.SUITES:
        p = bench_run.artifact_path(n, tmp_path)
        assert p.name == f"BENCH_{n}.json" and p.parent == tmp_path
    # default location is the repo root, beside README.md
    assert bench_run.artifact_path("x").parent == bench_run.ROOT
    assert (bench_run.ROOT / "README.md").exists()

    rows, notes = bench_run.parse_rows(
        "suite/a,12.5,w2=0.1;pass=1\n"
        "suite/b,3.0,compliant=0|note=x\n"
        "free-form report line\n"
        "name,us_per_call,derived\n")
    assert [r["name"] for r in rows] == ["suite/a", "suite/b"]
    assert rows[0]["us_per_call"] == 12.5
    assert rows[0]["gates"] == {"pass": True}
    assert rows[1]["gates"] == {"compliant": False}
    # non-row lines (incl. the CSV header) are kept verbatim as notes
    assert notes == ["free-form report line", "name,us_per_call,derived"]

    pg = bench_run._parse_gates
    assert pg("mean=3;passed=1") == {"passed": True}
    assert pg("ok=yes|speed=2x") == {"ok": True}
    assert pg("gate_lower_nfe_at_equal_error_pass=0") \
        == {"gate_lower_nfe_at_equal_error_pass": False}
    assert pg("pass=maybe") == {}  # unparseable values skipped, not guessed
    assert pg("w2=0.5") == {}

    path = bench_run.write_artifact("unit", rows, notes, 1.25,
                                    out_dir=tmp_path)
    doc = json.loads(path.read_text())
    assert doc["name"] == "unit" and doc["schema_version"] == 1
    assert set(doc) >= {"name", "schema_version", "config", "wall_time_s",
                        "rows", "notes", "gates"}
    assert {"argv", "backend", "device_count"} <= set(doc["config"])
    assert doc["gates"]["tokens"] == {"suite/a:pass": True,
                                      "suite/b:compliant": False}
    assert doc["gates"]["all_pass"] is False


# --------------------------------------------------------------------------
# quality-proxy gauges
# --------------------------------------------------------------------------

def test_proxy_fid_gauge_properties():
    """proxy-FID: ~0 on identical sets, deterministic in (shape, dim,
    seed), monotone under distribution shift, and shape-strict."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 16))
    b = rng.standard_normal((256, 16))
    assert proxy_fid(a, a) == pytest.approx(0.0, abs=1e-9)
    near = proxy_fid(a, b)
    far = proxy_fid(a, b + 1.0)
    wide = proxy_fid(a, 3.0 * b)
    assert 0.0 <= near < far
    assert near < wide  # covariance drift moves it, not just the mean
    assert proxy_fid(a, b, dim=8, seed=3) == proxy_fid(a, b, dim=8, seed=3)
    # image-shaped samples flatten through the same extractor
    img = rng.standard_normal((64, 4, 4, 2))
    assert proxy_fid(img, img) == pytest.approx(0.0, abs=1e-9)
    with pytest.raises(ValueError):
        proxy_fid(a, rng.standard_normal((64, 8)))


def test_dynamics_consistency_floors_and_regressions():
    """dynamics-consistency: a true deterministic rollout scores ~0, a
    perturbed one scores high, and a stochastic OU rollout sits at the
    σ√dt noise floor."""
    pm = PointMassEnv(dim=2)
    rng = np.random.default_rng(1)
    trajs = []
    for i in range(4):
        s = np.asarray(pm.reset(jax.random.PRNGKey(i)))
        rows = []
        for _ in range(6):
            a = 0.5 * rng.standard_normal(pm.act_dim)
            rows.append(np.concatenate([s, a]))
            s = np.asarray(pm.step(jnp.asarray(s), jnp.asarray(a))[0])
        trajs.append(np.stack(rows))
    trajs = np.stack(trajs)
    dyn_true = dynamics_consistency(pm, trajs, obs_dim=pm.obs_dim,
                                    act_dim=pm.act_dim)
    assert dyn_true <= 1e-6, dyn_true

    bad = trajs.copy()
    bad[:, :, :pm.obs_dim] += 0.5 * rng.standard_normal(
        bad[:, :, :pm.obs_dim].shape)
    dyn_bad = dynamics_consistency(pm, bad, obs_dim=pm.obs_dim,
                                   act_dim=pm.act_dim)
    assert dyn_bad > 0.1, dyn_bad

    ou = OUEnv(obs_dim=2)
    floor = ou.sigma * np.sqrt(ou.dt)
    trajs = []
    for i in range(8):
        key = jax.random.PRNGKey(100 + i)
        s = np.asarray(ou.reset(key))
        rows = []
        for j in range(8):
            key, sk = jax.random.split(key)
            a = 0.3 * rng.standard_normal(ou.act_dim)
            rows.append(np.concatenate([s, a]))
            s = np.asarray(ou.step(jnp.asarray(s), jnp.asarray(a), sk)[0])
        trajs.append(np.stack(rows))
    dyn_ou = dynamics_consistency(ou, np.stack(trajs), obs_dim=ou.obs_dim,
                                  act_dim=ou.act_dim)
    assert 0.5 * floor < dyn_ou < 2.0 * floor, (dyn_ou, floor)
    # (H, D) single-trajectory form accepted too
    assert dynamics_consistency(ou, trajs[0], obs_dim=ou.obs_dim,
                                act_dim=ou.act_dim) > 0.0


# --------------------------------------------------------------------------
# metrics registry + tracer unit behaviour
# --------------------------------------------------------------------------

def test_metrics_registry_export_roundtrip():
    reg = MetricsRegistry()
    reg.counter("reqs_total", tier="draft").inc(3)
    reg.counter("reqs_total", tier="hf").inc()
    assert reg.counter("reqs_total", tier="draft") is reg.counter(
        "reqs_total", tier="draft")  # get-or-create, one series per labels
    reg.gauge("depth").set(2.5)
    h = reg.histogram("wait_seconds", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    with pytest.raises(ValueError):
        reg.counter("reqs_total", tier="draft").inc(-1)

    assert reg.value("reqs_total", tier="draft") == 3
    assert reg.total("reqs_total") == 4
    with pytest.raises(KeyError):
        reg.value("reqs_total")  # label-less series was never created

    j = json.loads(json.dumps(reg.to_json()))
    assert j["counters"]['reqs_total{tier="draft"}'] == 3
    assert j["gauges"]["depth"] == 2.5
    assert j["histograms"]["wait_seconds"]["count"] == 3
    assert j["histograms"]["wait_seconds"]["buckets"] == [1, 1, 1]

    prom = reg.to_prometheus()
    assert "# TYPE reqs_total counter" in prom
    assert 'reqs_total{tier="draft"} 3' in prom
    assert "# TYPE wait_seconds histogram" in prom
    # cumulative le buckets ending at +Inf == count
    assert 'wait_seconds_bucket{le="0.1"} 1' in prom
    assert 'wait_seconds_bucket{le="1.0"} 2' in prom
    assert 'wait_seconds_bucket{le="+Inf"} 3' in prom
    assert "wait_seconds_count 3" in prom


def test_stage_tracer_and_null_tracer():
    ticks = (x * 0.5 for x in range(100))
    tr = StageTracer(clock=lambda: next(ticks))
    with tr.span("a", uid=1) as sp:
        sp["attrs"]["extra"] = 2  # serve loop adds attrs mid-span
    with tr.span("b"):
        pass
    assert [s["name"] for s in tr.spans] == ["a", "b"]
    assert tr.spans[0]["duration_s"] == 0.5
    assert tr.spans[0]["attrs"] == {"uid": 1, "extra": 2}
    hist = tr.stage_histograms()
    assert hist["a"]["count"] == 1 and hist["a"]["mean_s"] == 0.5
    j = json.loads(json.dumps(tr.to_json()))
    assert len(j["spans"]) == 2 and j["bucket_bounds_s"][0] == 1e-4

    with NULL_TRACER.span("x", uid=9) as sp:
        sp["attrs"]["k"] = 1  # the yielded dict is writable on both paths
    assert NULL_TRACER.spans == []
    assert NULL_TRACER.enabled is False and StageTracer.enabled is True
