"""Correctness of the §Perf optimization levers: every variant must be
numerically equivalent to the baseline path (they only change layout /
communication, never math)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.models import (
    ModelConfig, MoEConfig, decode_step, forward, init_decode_state,
    init_model,
)


def _dense_cfg(**kw):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                mixer_pattern=("L", "A"), mlp_pattern=("D", "D"),
                sliding_window=4)
    base.update(kw)
    return ModelConfig(**base)


def _teacher_forced(cfg, params, toks, cross=None):
    logits, _ = forward(params, toks, cfg, cross_embeds=cross)
    return logits


@pytest.mark.parametrize("axis", [
    "model",
    pytest.param("data,model", marks=pytest.mark.slow),  # 2-axis variant
])
def test_flash_decode_matches_teacher_forcing(axis, rng):
    cfg = _dense_cfg()
    params = init_model(cfg, rng)
    toks = jax.random.randint(rng, (2, 10), 0, cfg.vocab_size)
    want = _teacher_forced(cfg, params, toks)

    cfg_fd = cfg.replace(decode_flash_shard=axis)
    with make_host_mesh():
        st = init_decode_state(cfg_fd, 2, cache_len=12)
        step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg_fd))
        outs = []
        for i in range(10):
            lg, st = step(params, toks[:, i : i + 1], st)
            outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=5e-4)


def test_flash_decode_ring_wraparound(rng):
    """Sliding-window layer with cache smaller than the sequence: the
    ring buffer wraps and flash-decode must stay exact."""
    cfg = _dense_cfg(mixer_pattern=("L", "L"), sliding_window=3)
    params = init_model(cfg, rng)
    toks = jax.random.randint(rng, (1, 12), 0, cfg.vocab_size)
    want = _teacher_forced(cfg, params, toks)
    cfg_fd = cfg.replace(decode_flash_shard="model")
    with make_host_mesh():
        st = init_decode_state(cfg_fd, 1, cache_len=4)  # < seq len → wraps
        step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg_fd))
        outs = []
        for i in range(12):
            lg, st = step(params, toks[:, i : i + 1], st)
            outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=5e-4)


def test_expert_padding_preserves_outputs(rng):
    """Padded experts must never be routed to: outputs identical to the
    unpadded model given identical real-expert weights."""
    from repro.models.moe import apply_moe, init_moe

    cfg = ModelConfig(
        name="moe", arch_type="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=16, mlp_pattern=("E",),
        moe=MoEConfig(num_experts=5, top_k=2, expert_ffn=16),
    )
    cfg_pad = cfg.replace(moe=cfg.moe.__class__(
        num_experts=5, top_k=2, expert_ffn=16, padded_experts=8,
    ))
    params_pad = init_moe(rng, cfg_pad)
    # unpadded params = slice of padded params
    params = {
        "router": params_pad["router"][:, :5],
        "w_in": params_pad["w_in"][:5],
        "w_gate": params_pad["w_gate"][:5],
        "w_out": params_pad["w_out"][:5],
    }
    x = jax.random.normal(rng, (2, 64, 32))
    y0, a0 = apply_moe(params, x, cfg)
    y1, a1 = apply_moe(params_pad, x, cfg_pad)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
    assert float(a0) == pytest.approx(float(a1), rel=1e-5)


def test_seq_shard_constraint_is_noop_on_host_mesh(rng):
    """attn_q_seq_shard / residual_seq_shard only change layout: on a
    1×1 mesh the outputs are bit-comparable to the unconstrained path."""
    cfg = _dense_cfg()
    cfg_sp = cfg.replace(attn_q_seq_shard="model", residual_seq_shard="model")
    params = init_model(cfg, rng)
    toks = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    with make_host_mesh():
        l0, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
        l1, _ = jax.jit(lambda p, t: forward(p, t, cfg_sp))(params, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-6, atol=1e-6)
