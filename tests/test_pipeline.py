"""Pipeline parallelism: single-stage degenerate path must equal the
plain scan over the full stack (exact), with any microbatch count."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.parallel.pipeline import pipeline_forward


def _stacked_mlp(key, R, d):
    k1, k2 = jax.random.split(key)
    return {
        "w1": 0.3 * jax.random.normal(k1, (R, d, d)),
        "w2": 0.3 * jax.random.normal(k2, (R, d, d)),
    }


def _body(stage_params, x):
    """Scan over the stage's local super-blocks."""

    def block(x, p):
        h = jax.nn.gelu(x @ p["w1"])
        return x + h @ p["w2"], None

    x, _ = jax.lax.scan(block, x, stage_params)
    return x


def _reference(params, x):
    return _body(params, x)


@pytest.mark.parametrize("microbatches", [1, 2, 4])
def test_single_stage_equals_scan(microbatches, rng):
    R, d, B = 4, 16, 8
    params = _stacked_mlp(rng, R, d)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (B, d))
    want = _reference(params, x)
    with make_host_mesh():  # data axis size 1 → one pipeline stage
        got = pipeline_forward(params, x, _body, axis="data",
                               num_microbatches=microbatches)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_is_jittable(rng):
    R, d, B = 2, 8, 4
    params = _stacked_mlp(rng, R, d)
    x = jax.random.normal(rng, (B, d))
    with make_host_mesh():
        fn = jax.jit(lambda p, x: pipeline_forward(
            p, x, _body, axis="data", num_microbatches=2))
        got = fn(params, x)
    assert bool(jnp.all(jnp.isfinite(got)))
