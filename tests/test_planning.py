"""Trajectory-diffusion planning subsystem (DESIGN.md §10): temporal
score network contract, plan-conditioner guardrails (returns-CFG at
scale 0 and absent state pinning bit-identical to unconditional),
chunked-vs-monolithic bitwise equality with plan payloads aboard, and
the receding-horizon closed loop through the DiffusionBatcher —
re-admission preserves per-request keys and exact NFE accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaptiveConfig, VPSDE, available_solvers, sample
from repro.core.analytic import (
    class_gaussian_noise_pred, class_gaussian_score, gaussian_score,
)
from repro.core.sampling import solve_in_chunks
from repro.core.solvers.adaptive import adaptive
from repro.models.temporal_unet import (
    TemporalUNetConfig, init_temporal_unet, make_score_fn,
    temporal_unet_forward,
)
from repro.planning import (
    OUEnv, PlanConditioner, PlannerConfig, PointMassEnv,
    RecedingHorizonPlanner, first_action, plan, plan_conditioner,
    returns_to_bin, state_pin,
)

MU, S0 = 0.3, 0.5
BINS = 5
BIN_MUS = jnp.linspace(-1.0, 1.0, BINS)

PCFG = PlannerConfig(horizon=8, obs_dim=2, act_dim=2, guidance_scale=1.5)


def _perturbed_unet(cfg, key):
    """Init + perturb every leaf so the forward actually depends on all
    its inputs (the zero-init second convs / output conv of a
    train-free net would otherwise cut the conditioning path)."""
    params = init_temporal_unet(cfg, key)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    leaves = [l + 0.05 * jax.random.normal(k, l.shape, l.dtype)
              for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# temporal score network
# ---------------------------------------------------------------------------


def test_temporal_unet_forward_shapes_and_depths():
    for mults, H in [((1,), 4), ((1, 2), 8), ((1, 2, 4), 16)]:
        cfg = TemporalUNetConfig(horizon=H, transition_dim=5, base=8,
                                 mults=mults, t_dim=16, groups=4)
        p = init_temporal_unet(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (3, H, 5))
        out = temporal_unet_forward(p, x, jnp.full((3,), 0.4), cfg)
        assert out.shape == x.shape and out.dtype == jnp.float32


def test_temporal_unet_rejects_indivisible_horizon():
    with pytest.raises(ValueError):
        TemporalUNetConfig(horizon=6, transition_dim=4, mults=(1, 2, 4))


def test_temporal_unet_policy_dtypes():
    """PR-3 precision contract (DESIGN.md §8): compute dtype through the
    blocks, fp32 time-embedding math, score delivered in state dtype."""
    from repro.core.precision import resolve_policy

    cfg = TemporalUNetConfig(horizon=4, transition_dim=4, base=8,
                             mults=(1, 2), t_dim=16, groups=4)
    p = _perturbed_unet(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 4))
    t = jnp.full((2,), 0.3)
    pol = resolve_policy("bf16")
    out = temporal_unet_forward(p, x, t, cfg, policy=pol)
    assert out.dtype == jnp.bfloat16
    score = make_score_fn(p, cfg, VPSDE(), policy=pol)
    assert score(x, t).dtype == pol.state
    score_full = make_score_fn(p, cfg, VPSDE(),
                               policy=resolve_policy("bf16_full"))
    assert score_full(x, t).dtype == jnp.bfloat16


def test_temporal_unet_null_row_bitwise_unconditional():
    """The returns table's null row is zero-init, so the null-labeled
    forward is bit-identical to the unconditional (y=None) forward —
    what makes ClassifierFree scale=0 on this net collapse exactly
    (DESIGN.md §10)."""
    cfg = TemporalUNetConfig(horizon=4, transition_dim=4, base=8,
                             mults=(1, 2), t_dim=16, groups=4,
                             returns_bins=BINS)
    p = _perturbed_unet(cfg, jax.random.PRNGKey(0))
    # restore the contract the perturbation broke: the null row is zero
    p["ret_emb"] = p["ret_emb"].at[cfg.returns_bins].set(0.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 4))
    t = jnp.full((3,), 0.5)
    out_u = temporal_unet_forward(p, x, t, cfg)
    out_null = temporal_unet_forward(p, x, t, cfg,
                                     y=jnp.full((3,), -1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out_u), np.asarray(out_null))
    # and a real bin label actually changes the field
    out_y = temporal_unet_forward(p, x, t, cfg,
                                  y=jnp.zeros((3,), jnp.int32))
    assert bool(jnp.any(out_y != out_u))


def test_every_registered_solver_runs_on_trajectories():
    """The make_score_fn adapter is workload-agnostic: every registered
    solver consumes the temporal score unmodified (DESIGN.md §10)."""
    cfg = TemporalUNetConfig(horizon=4, transition_dim=3, base=8,
                             mults=(1, 2), t_dim=16, groups=4)
    p = _perturbed_unet(cfg, jax.random.PRNGKey(0))
    sde = VPSDE()
    unet_score = make_score_fn(p, cfg, sde)
    base = gaussian_score(sde, MU, S0)

    # the sweep verifies the (B, H, D) adapter signature on every
    # registered solver; the analytic term keeps the field at a sane
    # magnitude (PC's Langevin step ∝ 1/‖score‖² diverges on the
    # zero/garbage field of an untrained net)
    def score(x, t):
        return base(x, t) + 0.1 * unet_score(x, t)

    # PC's ancestral VP predictor needs a non-degenerate grid (it is
    # NaN-unstable below ~tens of steps on any workload)
    kw = {"em": dict(n_steps=5), "pc": dict(n_steps=50),
          "pc_hmc": dict(n_steps=50), "ddim": dict(n_steps=5),
          "adaptive": dict(eps_rel=0.1), "momentum": dict(eps_rel=0.1),
          "heun": dict(eps_rel=0.1), "ode": {}}
    for solver in available_solvers():
        res = sample(sde, score, (2, 4, 3), jax.random.PRNGKey(1),
                     method=solver, **kw[solver])
        assert res.x.shape == (2, 4, 3)
        assert bool(jnp.all(jnp.isfinite(res.x))), solver


# ---------------------------------------------------------------------------
# plan conditioner guardrails
# ---------------------------------------------------------------------------


def test_plan_conditioner_factory_cases():
    obs = jnp.ones((3, 2))
    labels = jnp.arange(3)
    c, p = plan_conditioner(PCFG, state=None, returns=None)
    assert c is None and p is None
    c, p = plan_conditioner(PCFG, state=obs, returns=None)
    assert type(c).__name__ == "Inpaint" and set(p) == {"mask", "observed"}
    c, p = plan_conditioner(PCFG, state=None, returns=labels)
    assert type(c).__name__ == "ClassifierFree" and set(p) == {"label"}
    c, p = plan_conditioner(PCFG, state=obs, returns=labels)
    assert isinstance(c, PlanConditioner)
    assert set(p) == {"label", "mask", "observed"}
    assert c.has_projection


def test_returns_cfg_scale0_bitwise_unconditional():
    """ISSUE-5 guardrail: returns-CFG at scale=0 is bit-identical to
    unconditional trajectory sampling (the null branch computes the
    same arithmetic; no extra noise draws on the CFG-only path)."""
    sde = VPSDE()
    pcfg = dataclasses.replace(PCFG, guidance_scale=0.0)
    score_u = gaussian_score(sde, MU, S0)
    score_y = class_gaussian_score(sde, BIN_MUS, S0, MU)
    key = jax.random.PRNGKey(0)
    shape = (4,) + pcfg.sample_shape
    res_u = sample(sde, score_u, shape, key, method="adaptive", eps_rel=0.05)
    conditioner, cond = plan_conditioner(pcfg, returns=jnp.arange(4) % BINS)
    res_c = sample(sde, score_y, shape, key, method="adaptive", eps_rel=0.05,
                   conditioner=conditioner, cond=cond)
    np.testing.assert_array_equal(np.asarray(res_u.x), np.asarray(res_c.x))
    np.testing.assert_array_equal(np.asarray(res_u.nfe), np.asarray(res_c.nfe))


def test_state_mask_none_bitwise_unconditional():
    """ISSUE-5 guardrail: no state pin and no returns → plan() IS the
    unconditional trajectory solve, bit for bit."""
    sde = VPSDE()
    score = gaussian_score(sde, MU, S0)
    key = jax.random.PRNGKey(0)
    res_u = sample(sde, score, (4,) + PCFG.sample_shape, key,
                   method="adaptive", eps_rel=0.05)
    res_p = plan(sde, score, None, key, pcfg=PCFG, batch=4, eps_rel=0.05)
    np.testing.assert_array_equal(np.asarray(res_u.x), np.asarray(res_p.x))


def test_plan_pins_state_exactly_and_free_region_on_marginal():
    """Delivered plans pin the current state bit-exactly (finalize
    projection) while the free region stays on the data marginal."""
    sde = VPSDE()
    score = class_gaussian_score(sde, BIN_MUS, S0, MU)
    obs = jnp.asarray([[0.1, -0.2], [0.4, 0.0], [-0.3, 0.25],
                       [0.05, 0.6]], jnp.float32)
    res = plan(sde, score, obs, jax.random.PRNGKey(0), pcfg=PCFG,
               returns=jnp.arange(4) % BINS, eps_rel=0.05)
    x = np.asarray(res.x)
    np.testing.assert_array_equal(x[:, 0, :2], np.asarray(obs))
    a = first_action(res.x, PCFG)
    assert a.shape == (4, 2)
    free = x[:, 1:, :]
    assert abs(free.mean()) < 1.0 and np.isfinite(free).all()


def test_first_action_selects_action_columns():
    """first_action must slice the ACTION coordinates of row context−1
    — distinguishable values per column pin the contract (an obs-column
    slice would have the same shape and slip through shape checks)."""
    x = jnp.arange(2 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 4)
    a = first_action(x, PCFG)  # obs_dim=2, act_dim=2, context=1
    np.testing.assert_array_equal(np.asarray(a), np.asarray(x[:, 0, 2:4]))


def test_returns_to_bin_and_state_pin_shapes():
    bins = returns_to_bin(jnp.asarray([-2.0, 0.0, 2.0]), -1.0, 1.0, BINS)
    assert bins.tolist() == [0, 2, BINS - 1]
    pin = state_pin(PCFG, jnp.ones((2, 2)))
    assert pin["mask"].shape == (2,) + PCFG.sample_shape
    assert float(pin["mask"].sum()) == 2 * PCFG.context * PCFG.obs_dim
    with pytest.raises(ValueError):
        state_pin(PCFG, jnp.ones((2, 3)))  # wrong obs_dim


def test_chunked_plan_bitwise_equals_monolithic_with_payload():
    """ISSUE-5 guardrail: horizon-chunked planning solves are bitwise
    equal to the monolithic solve with the full PlanConditioner payload
    (labels + pin mask) aboard (DESIGN.md §7/§9/§10). Compared at equal
    jit granularity (a maximal single chunk vs small chunks through the
    same host chain), the discipline the §7/§9 chunking suites use."""
    sde = VPSDE()
    score = class_gaussian_score(sde, BIN_MUS, S0, MU)
    obs = 0.2 * jnp.ones((3, 2))
    conditioner, cond = plan_conditioner(PCFG, state=obs,
                                         returns=jnp.arange(3) % BINS)
    cfg = AdaptiveConfig(eps_rel=0.05, conditioner=conditioner)
    key = jax.random.PRNGKey(2)
    shape = (3,) + PCFG.sample_shape
    res_mono = solve_in_chunks(sde, score, shape, key,
                               max_sync_iters=10**6, config=cfg, cond=cond)
    res_chunk = solve_in_chunks(sde, score, shape, key, max_sync_iters=7,
                                config=cfg, cond=cond)
    np.testing.assert_array_equal(np.asarray(res_mono.x),
                                  np.asarray(res_chunk.x))
    np.testing.assert_array_equal(np.asarray(res_mono.nfe),
                                  np.asarray(res_chunk.nfe))
    x = np.asarray(res_mono.x)
    np.testing.assert_array_equal(x[:, 0, :2], np.asarray(obs))


# ---------------------------------------------------------------------------
# receding-horizon closed loop through the batcher
# ---------------------------------------------------------------------------


def _forward():
    sde = VPSDE()
    return sde, class_gaussian_noise_pred(sde, BIN_MUS, S0, MU)


def _rollout(slots, sync_horizon, *, compaction=True, n_envs=4, n_steps=2):
    sde, fwd = _forward()
    rh = RecedingHorizonPlanner(sde, fwd, None, PCFG, OUEnv(obs_dim=2),
                                slots=slots, sync_horizon=sync_horizon,
                                compaction=compaction)
    out = rh.rollout(jax.random.PRNGKey(1), n_envs=n_envs, n_steps=n_steps,
                     returns_label=BINS - 1)
    return rh, out


def test_closed_loop_smoke_plans_pin_and_progress():
    """Tier-1 closed-loop smoke on a tiny horizon: every delivered plan
    pins its request's own pinned state exactly, rewards are finite,
    and every plan did real solver work."""
    rh, out = _rollout(slots=4, sync_horizon=4, n_envs=3, n_steps=2)
    assert out["rewards"].shape == (2, 3)
    assert np.isfinite(out["rewards"]).all()
    assert (out["nfe"] > 10).all() and (out["nfe"] % 2 == 0).all()
    for req in out["finished"].values():
        m = np.asarray(req.cond["mask"])
        o = np.asarray(req.cond["observed"])
        np.testing.assert_array_equal(np.asarray(req.result)[m == 1.0],
                                      o[m == 1.0])


def test_closed_loop_readmission_invariant_to_scheduling():
    """ISSUE-5 acceptance: closed-loop re-admission preserves per-request
    keys — delivered plans and per-request NFE are bit-identical across
    sync horizons and with compaction on/off, with n_envs > slots so
    requests genuinely queue and re-admit into freed slots."""
    _, o1 = _rollout(slots=4, sync_horizon=1, n_envs=6)
    _, o2 = _rollout(slots=4, sync_horizon=8, n_envs=6)
    _, o3 = _rollout(slots=4, sync_horizon=8, n_envs=6, compaction=False)
    assert o1["finished"].keys() == o2["finished"].keys() == o3["finished"].keys()
    for uid in o1["finished"]:
        r1, r2, r3 = (o["finished"][uid] for o in (o1, o2, o3))
        np.testing.assert_array_equal(r1.result, r2.result)
        np.testing.assert_array_equal(r2.result, r3.result)
        assert r1.nfe == r2.nfe == r3.nfe


def test_closed_loop_request_reproducible_standalone():
    """ISSUE-5 acceptance: every request delivered by the closed loop is
    bit-identical to a standalone adaptive() solve of the same (seed,
    payload) at matching batch width, with exact NFE accounting — the
    per-slot-key + payload-compaction contract (DESIGN.md §7/§9)."""
    sde, fwd = _forward()
    rh, out = _rollout(slots=1, sync_horizon=4, n_envs=1, n_steps=3)

    def score_fn(x, t, y=None):  # exactly make_sample_step's wrapping
        _, std = sde.marginal(t)
        return -fwd(None, x, t, y).astype(jnp.float32) / std.reshape(
            (-1,) + (1,) * (x.ndim - 1))

    assert len(out["finished"]) == 3
    for uid, req in sorted(out["finished"].items()):
        k_prior, k_noise = jax.random.split(jax.random.PRNGKey(req.seed))
        x0 = sde.prior_sample(k_prior, PCFG.sample_shape)[None]
        cond = {k: jnp.asarray(v)[None] for k, v in req.cond.items()}
        res = adaptive(sde, score_fn, x0, k_noise[None], config=rh.cfg,
                       cond=cond, denoise=False)
        np.testing.assert_array_equal(np.asarray(res.x[0]), req.result)
        assert int(res.nfe[0]) == req.nfe


def test_solver_carry_shardings_cover_plan_payload():
    """The §9 payload-sharding rule extends to the merged plan payload:
    every PlanConditioner leaf (label (B,), mask/observed (B, H, D))
    gets a batch-axis spec of its own ndim (DESIGN.md §10)."""
    from repro.parallel.sharding import solver_carry_shardings

    mesh = jax.make_mesh((1,), ("data",))
    c = PlanConditioner(scale=1.5)
    struct = c.cond_struct(4, PCFG.sample_shape)
    sh = solver_carry_shardings(mesh, 4, 3, per_slot_keys=True, cond=struct)
    assert set(sh.cond) == {"label", "mask", "observed"}
    for name, leaf in struct.items():
        assert len(sh.cond[name].spec) == leaf.ndim, name


def test_planner_rejects_mismatched_env_dims():
    sde, fwd = _forward()
    with pytest.raises(ValueError):
        RecedingHorizonPlanner(sde, fwd, None, PCFG, OUEnv(obs_dim=3))


@pytest.mark.slow
def test_closed_loop_e2e_pointmass_improves():
    """Slow closed-loop e2e: a longer receding-horizon rollout on the
    deterministic point-mass env with the train-free temporal UNet —
    the full network path through the batcher — completes every round
    and keeps waste accounting sane; and on the OU analytic loop the
    returns guidance measurably steers realized reward in the predicted
    direction (the zero-mean action bin beats the high-action bin,
    which pays quadratic action cost for anti-goal drift)."""
    env = PointMassEnv()
    pcfg = PlannerConfig(horizon=8, obs_dim=env.obs_dim,
                         act_dim=env.act_dim, guidance_scale=1.0)
    cfg = TemporalUNetConfig(horizon=pcfg.horizon,
                             transition_dim=pcfg.transition_dim,
                             base=8, mults=(1, 2), t_dim=16, groups=4,
                             returns_bins=BINS)
    params = init_temporal_unet(cfg, jax.random.PRNGKey(0))
    sde = VPSDE()

    def fwd(p, x, t, y=None):
        return temporal_unet_forward(p, x, t, cfg, y=y)

    rh = RecedingHorizonPlanner(sde, fwd, params, pcfg, env,
                                slots=4, sync_horizon=4)
    out = rh.rollout(jax.random.PRNGKey(3), n_envs=6, n_steps=3,
                     returns_label=BINS - 1)
    assert out["rewards"].shape == (3, 6)
    assert np.isfinite(out["rewards"]).all()
    assert len(out["finished"]) == 18
    assert 0.0 <= out["wasted_nfe_fraction"] < 1.0
    assert 0.0 <= out["passenger_nfe_fraction"] < 1.0

    # analytic OU loop: the returns-bin label is a real control signal —
    # bin mus are linspace(-1, 1, 5), so bin 2 (μ=0) plans near-zero
    # actions (cheap, no anti-goal drift) while bin 4 (μ=+1) plans
    # large positive ones (quadratic action cost + drift away from 0);
    # realized reward must order accordingly, which also fails if
    # first_action ever returned observation columns (pinned near the
    # stationary state) instead of the guided action columns
    def ou_reward(label):
        sde2, fwd2 = _forward()
        rh2 = RecedingHorizonPlanner(sde2, fwd2, None, PCFG,
                                     OUEnv(obs_dim=2),
                                     slots=4, sync_horizon=4)
        out2 = rh2.rollout(jax.random.PRNGKey(4), n_envs=4, n_steps=4,
                           returns_label=label)
        assert np.isfinite(out2["rewards"]).all()
        return float(out2["rewards"].mean())

    assert ou_reward(2) > ou_reward(4)
