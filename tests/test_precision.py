"""Precision-policy subsystem (DESIGN.md §8).

Covers the policy object itself, the dtype contract at every seam
(models → score fn → solver carry → kernels), the fp32-preset
bit-identity guarantee, and the bf16 tier-1 smoke (the fast-job gate CI
runs on every push).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaptiveConfig,
    PrecisionPolicy,
    VPSDE,
    init_carry,
    resolve_policy,
    sample,
    solve_in_chunks,
)
from repro.core.analytic import gaussian_score
from repro.models.dit import DiTConfig, dit_forward, init_dit, make_score_fn

MU, S0 = 0.3, 0.5


def _score(sde):
    return gaussian_score(sde, MU, S0)


# ---------------------------------------------------------------------------
# the policy object
# ---------------------------------------------------------------------------


def test_presets():
    assert PrecisionPolicy("fp32").compute == jnp.float32
    p = PrecisionPolicy("bf16")
    assert (p.compute, p.param, p.state) == (
        jnp.bfloat16, jnp.float32, jnp.float32)
    pf = PrecisionPolicy("bf16_full")
    assert (pf.compute, pf.param, pf.state) == (
        jnp.bfloat16, jnp.bfloat16, jnp.bfloat16)
    assert pf.name == "bf16_full" and not pf.is_fp32
    with pytest.raises(ValueError):
        PrecisionPolicy("fp8")


def test_control_dtype_is_pinned_fp32():
    """There is no knob that downcasts the control path."""
    for preset in ("fp32", "bf16", "bf16_full"):
        assert PrecisionPolicy(preset).control == jnp.float32
    # per-seam overrides exist, but none for control
    p = PrecisionPolicy("bf16", state_dtype="bfloat16")
    assert p.state == jnp.bfloat16 and p.control == jnp.float32
    import inspect

    assert "control_dtype" not in inspect.signature(
        PrecisionPolicy.__init__
    ).parameters


def test_resolve_policy_forms():
    p = PrecisionPolicy("bf16")
    assert resolve_policy(None).is_fp32
    assert resolve_policy("bf16") == p
    assert resolve_policy(p) is p
    with pytest.raises(TypeError):
        resolve_policy(16)


def test_policy_is_static_pytree_and_hashable():
    p = PrecisionPolicy("bf16_full")
    assert jax.tree_util.tree_leaves(p) == []  # static: no traced leaves
    assert hash(p) == hash(PrecisionPolicy("bf16_full"))
    out = jax.jit(lambda pol, x: pol.to_compute(x))(p, jnp.ones((2,)))
    assert out.dtype == jnp.bfloat16


def test_cast_params_touches_only_floating_leaves():
    p = PrecisionPolicy("bf16_full")
    tree = {"w": jnp.ones((2, 2), jnp.float32),
            "steps": jnp.zeros((3,), jnp.int32)}
    cast = p.cast_params(tree)
    assert cast["w"].dtype == jnp.bfloat16
    assert cast["steps"].dtype == jnp.int32


def test_wrap_score_fn_dtypes():
    p = PrecisionPolicy("bf16")
    seen = {}

    def raw(x, t):
        seen["x_dtype"] = x.dtype
        return x * 2.0

    out = p.wrap_score_fn(raw)(jnp.ones((4, 2), jnp.float32), jnp.ones((4,)))
    assert seen["x_dtype"] == jnp.bfloat16  # entry cast → compute
    assert out.dtype == jnp.float32         # exit cast → state


# ---------------------------------------------------------------------------
# solver seams
# ---------------------------------------------------------------------------


def test_carry_state_dtype_follows_policy_control_stays_fp32(rng):
    sde = VPSDE()
    x0 = sde.prior_sample(rng, (4, 8))
    for preset, sdt in (("fp32", jnp.float32), ("bf16", jnp.float32),
                        ("bf16_full", jnp.bfloat16)):
        c = init_carry(sde, x0, rng, config=AdaptiveConfig(precision=preset))
        assert c.x.dtype == sdt and c.x_prev.dtype == sdt, preset
        # control path never downcasts
        assert c.t.dtype == jnp.float32 and c.h.dtype == jnp.float32, preset


def test_fp32_policy_bit_identical_to_default(rng):
    """Acceptance bar: PrecisionPolicy('fp32') — as a config default, a
    preset string, or an explicit object — is bitwise the unpoliced
    solver, chunked and monolithic alike."""
    sde = VPSDE()
    cfg_forms = [
        AdaptiveConfig(eps_rel=0.05),                        # field default
        AdaptiveConfig(eps_rel=0.05, precision="fp32"),      # preset name
        AdaptiveConfig(eps_rel=0.05,
                       precision=PrecisionPolicy("fp32")),   # object
    ]
    results = [
        jax.jit(lambda k, cfg=cfg: sample(sde, _score(sde), (8, 16), k,
                                          config=cfg))(rng)
        for cfg in cfg_forms
    ]
    for other in results[1:]:
        np.testing.assert_array_equal(np.asarray(results[0].x),
                                      np.asarray(other.x))
        np.testing.assert_array_equal(np.asarray(results[0].nfe),
                                      np.asarray(other.nfe))
    chunked = solve_in_chunks(sde, _score(sde), (8, 16), rng,
                              max_sync_iters=7, config=cfg_forms[2])
    np.testing.assert_array_equal(np.asarray(results[0].x),
                                  np.asarray(chunked.x))


def test_bf16_chunking_still_bitwise_vs_monolithic(rng):
    """Horizon-chunking transparency (PR 2's invariant) survives the
    bf16 state: chunk boundaries introduce no extra rounding."""
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05, precision="bf16_full")
    mono = jax.jit(
        lambda k: sample(sde, _score(sde), (8, 16), k, config=cfg)
    )(rng)
    chunked = solve_in_chunks(sde, _score(sde), (8, 16), rng,
                              max_sync_iters=7, config=cfg)
    for field in ("x", "nfe", "accepted", "rejected"):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, field), np.float32),
            np.asarray(getattr(chunked, field), np.float32), err_msg=field,
        )


# ---------------------------------------------------------------------------
# model seams + tier-1 bf16 smoke
# ---------------------------------------------------------------------------


def test_bf16_policy_smoke(rng):
    """Fast-job gate: a DiT forward and a full adaptive solve under the
    bf16 policy produce finite outputs at the right dtypes, close to the
    fp32 run (the tier-1 CI job runs this on every push)."""
    net = DiTConfig(image_size=8, patch=4, d_model=32, num_layers=2,
                    num_heads=2, d_ff=64)
    sde = VPSDE()
    params = init_dit(net, rng)
    x = jax.random.normal(rng, (4, 8, 8, 3))
    t = jnp.full((4,), 0.5)

    out32 = dit_forward(params, x, t, net)
    policy = PrecisionPolicy("bf16")
    outbf = dit_forward(policy.cast_params(params), x, t, net, policy=policy)
    assert outbf.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(outbf, np.float32),
                               np.asarray(out32), rtol=0.1, atol=0.05)

    score = make_score_fn(params, net, sde, policy=policy)
    assert score(x, t).dtype == policy.state  # fp32 under "bf16"
    res = jax.jit(lambda k: sample(sde, score, (4, 8, 8, 3), k,
                                   eps_rel=0.05, precision="bf16"))(rng)
    assert bool(jnp.all(jnp.isfinite(res.x)))
    assert res.x.dtype == jnp.float32  # Tweedie delivery is fp32
    assert int(res.iterations) > 0


def test_score_fn_policy_casts_are_idempotent_with_solver_wrap(rng):
    """make_score_fn(policy=...) + the solver's own wrap must compose:
    double-casting x→compute and out→state changes nothing."""
    sde = VPSDE()
    policy = PrecisionPolicy("bf16_full")
    score = policy.wrap_score_fn(_score(sde))
    x = jax.random.normal(rng, (4, 8), jnp.bfloat16)
    t = jnp.full((4,), 0.5)
    once = score(x, t)
    twice = policy.wrap_score_fn(score)(x, t)
    np.testing.assert_array_equal(np.asarray(once, np.float32),
                                  np.asarray(twice, np.float32))
