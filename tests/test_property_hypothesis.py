"""Property-based tests (hypothesis) on the solver's numeric invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.tolerance import (
    mixed_tolerance,
    next_step_size,
    scaled_error_l2,
    scaled_error_linf,
)

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")

finite = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False)
pos = st.floats(1e-6, 10.0, allow_nan=False, allow_infinity=False)


@given(
    st.lists(finite, min_size=2, max_size=16),
    st.lists(finite, min_size=2, max_size=16),
    pos, pos,
)
def test_mixed_tolerance_bounds(xs, xp, eps_abs, eps_rel):
    n = min(len(xs), len(xp))
    x = jnp.asarray(xs[:n])[None, :]
    p = jnp.asarray(xp[:n])[None, :]
    d = mixed_tolerance(x, p, eps_abs, eps_rel)
    # δ ≥ ε_abs everywhere, and δ ≥ ε_rel·|x'| (element-wise lower bounds)
    assert bool(jnp.all(d >= eps_abs - 1e-7))
    assert bool(jnp.all(d >= eps_rel * jnp.abs(x) - 1e-5))
    # monotonicity: dropping the prev term can only shrink δ
    d_noprev = mixed_tolerance(x, None, eps_abs, eps_rel)
    assert bool(jnp.all(d + 1e-7 >= d_noprev))


@given(
    st.lists(finite, min_size=4, max_size=32),
    st.lists(finite, min_size=4, max_size=32),
    pos,
)
def test_l2_error_bounded_by_linf(xs, ys, eps_abs):
    n = min(len(xs), len(ys))
    x = jnp.asarray(xs[:n])[None, :]
    y = jnp.asarray(ys[:n])[None, :]
    d = mixed_tolerance(x, None, eps_abs, 0.05)
    e2 = scaled_error_l2(x, y, d)
    einf = scaled_error_linf(x, y, d)
    # RMS ≤ max: the paper's ℓ2 norm is never more conservative than ℓ∞
    assert float(e2[0]) <= float(einf[0]) + 1e-5
    # zero error ⇔ identical proposals
    z = scaled_error_l2(x, x, d)
    assert float(z[0]) == 0.0


@given(pos, st.floats(1e-3, 50.0), pos,
       st.floats(0.5, 1.0), st.floats(0.1, 0.99))
def test_next_step_size_invariants(h, err, remaining, r_exp, safety):
    h_new = next_step_size(
        jnp.asarray(h), jnp.asarray(err), jnp.asarray(remaining),
        safety=safety, r_exponent=r_exp,
    )
    # never exceeds the remaining time
    assert float(h_new) <= remaining + 1e-6
    # larger error ⇒ smaller proposed step (monotone in err)
    h2 = next_step_size(
        jnp.asarray(h), jnp.asarray(err * 2.0), jnp.asarray(remaining),
        safety=safety, r_exponent=r_exp,
    )
    assert float(h2) <= float(h_new) + 1e-6
    assert float(h_new) >= 0.0


@given(st.floats(0.01, 0.99), st.floats(0.01, 0.99))
def test_vp_marginal_monotone(t1, t2):
    from repro.core import VPSDE

    sde = VPSDE()
    lo, hi = sorted((t1, t2))
    m_lo, s_lo = sde.marginal(jnp.asarray(lo))
    m_hi, s_hi = sde.marginal(jnp.asarray(hi))
    # corruption increases with t: mean scale shrinks, std grows
    assert float(m_hi) <= float(m_lo) + 1e-6
    assert float(s_hi) + 1e-6 >= float(s_lo)
    # VP: m² + s² ≤ 1 (variance preserved)
    assert float(m_hi**2 + s_hi**2) <= 1.0 + 1e-5


@given(st.integers(1, 6), st.integers(1, 4), st.integers(2, 5))
def test_delay_pattern_roundtrip(B, K, S_mult):
    from repro.data.tokens import apply_delay_pattern

    S = K + S_mult
    toks = jnp.arange(B * S * K).reshape(B, S, K) % 17 + 1
    d = apply_delay_pattern(toks)
    # codebook k shifted right by k with zero padding
    for k in range(K):
        np.testing.assert_array_equal(np.asarray(d[:, :k, k]), 0)
        np.testing.assert_array_equal(
            np.asarray(d[:, k:, k]), np.asarray(toks[:, : S - k, k])
        )
