"""Property-based tests on the solver's numeric invariants.

Runs under hypothesis when installed; otherwise a deterministic
fallback shim replays each property over a fixed-seed sweep of examples
so the invariants are still exercised (weaker — no shrinking, no
adaptive search — but the registry contract never goes untested on a
machine without the optional dependency).
"""

import random as _random

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (fixtures / direct runs)

try:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", deadline=None, max_examples=30)
    settings.load_profile("ci")
except ImportError:  # pragma: no cover — dep-less fallback
    _N_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mimics hypothesis.strategies
        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: r.choice(seq))

        @staticmethod
        def lists(elems, min_size, max_size):
            return _Strategy(
                lambda r: [elems.draw(r)
                           for _ in range(r.randint(min_size, max_size))]
            )

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rnd = _random.Random(0xC0FFEE)
                for _ in range(_N_EXAMPLES):
                    drawn = tuple(s.draw(rnd) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

from repro.core.tolerance import (  # noqa: E402
    mixed_tolerance,
    next_step_size,
    scaled_error_l2,
    scaled_error_linf,
)

finite = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False)
pos = st.floats(1e-6, 10.0, allow_nan=False, allow_infinity=False)


@given(
    st.lists(finite, min_size=2, max_size=16),
    st.lists(finite, min_size=2, max_size=16),
    pos, pos,
)
def test_mixed_tolerance_bounds(xs, xp, eps_abs, eps_rel):
    n = min(len(xs), len(xp))
    x = jnp.asarray(xs[:n])[None, :]
    p = jnp.asarray(xp[:n])[None, :]
    d = mixed_tolerance(x, p, eps_abs, eps_rel)
    # δ ≥ ε_abs everywhere, and δ ≥ ε_rel·|x'| (element-wise lower bounds)
    assert bool(jnp.all(d >= eps_abs - 1e-7))
    assert bool(jnp.all(d >= eps_rel * jnp.abs(x) - 1e-5))
    # monotonicity: dropping the prev term can only shrink δ
    d_noprev = mixed_tolerance(x, None, eps_abs, eps_rel)
    assert bool(jnp.all(d + 1e-7 >= d_noprev))


@given(
    st.lists(finite, min_size=4, max_size=32),
    st.lists(finite, min_size=4, max_size=32),
    pos,
)
def test_l2_error_bounded_by_linf(xs, ys, eps_abs):
    n = min(len(xs), len(ys))
    x = jnp.asarray(xs[:n])[None, :]
    y = jnp.asarray(ys[:n])[None, :]
    d = mixed_tolerance(x, None, eps_abs, 0.05)
    e2 = scaled_error_l2(x, y, d)
    einf = scaled_error_linf(x, y, d)
    # RMS ≤ max: the paper's ℓ2 norm is never more conservative than ℓ∞
    assert float(e2[0]) <= float(einf[0]) + 1e-5
    # zero error ⇔ identical proposals
    z = scaled_error_l2(x, x, d)
    assert float(z[0]) == 0.0


@given(pos, st.floats(1e-3, 50.0), pos,
       st.floats(0.5, 1.0), st.floats(0.1, 0.99))
def test_next_step_size_invariants(h, err, remaining, r_exp, safety):
    h_new = next_step_size(
        jnp.asarray(h), jnp.asarray(err), jnp.asarray(remaining),
        safety=safety, r_exponent=r_exp,
    )
    # never exceeds the remaining time
    assert float(h_new) <= remaining + 1e-6
    # larger error ⇒ smaller proposed step (monotone in err)
    h2 = next_step_size(
        jnp.asarray(h), jnp.asarray(err * 2.0), jnp.asarray(remaining),
        safety=safety, r_exponent=r_exp,
    )
    assert float(h2) <= float(h_new) + 1e-6
    assert float(h_new) >= 0.0


@given(st.floats(0.01, 0.99), st.floats(0.01, 0.99))
def test_vp_marginal_monotone(t1, t2):
    from repro.core import VPSDE

    sde = VPSDE()
    lo, hi = sorted((t1, t2))
    m_lo, s_lo = sde.marginal(jnp.asarray(lo))
    m_hi, s_hi = sde.marginal(jnp.asarray(hi))
    # corruption increases with t: mean scale shrinks, std grows
    assert float(m_hi) <= float(m_lo) + 1e-6
    assert float(s_hi) + 1e-6 >= float(s_lo)
    # VP: m² + s² ≤ 1 (variance preserved)
    assert float(m_hi**2 + s_hi**2) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# registry contract (DESIGN.md §11): one signature across the solver zoo
# ---------------------------------------------------------------------------

from repro.core import VPSDE, available_solvers, sample, solve_chunk  # noqa: E402
from repro.core import AdaptiveConfig, finalize, init_carry  # noqa: E402
from repro.core.analytic import gaussian_score  # noqa: E402

SHAPE = (4, 6)
#: cheap per-solver kwargs — the property under test is the registry
#: contract (signature, finiteness, accounting), not sample accuracy.
#: The pc family needs ≥32 grid steps: its snr-derived Langevin/HMC
#: step ε ∝ (‖z‖/‖s‖)² overshoots on coarser grids and the solve NaNs.
FAST_KWARGS = {
    "adaptive": dict(eps_rel=0.3),
    "momentum": dict(eps_rel=0.3),
    "heun": dict(eps_rel=0.3),
    "em": dict(n_steps=8),
    "ddim": dict(n_steps=8),
    "pc": dict(n_steps=32),
    "pc_hmc": dict(n_steps=32),
    "ode": {},
}

_SOLVE_CACHE = {}  # (method, denoise) → jitted solve; bounds recompiles


def _solve(method, denoise, seed):
    cache_key = (method, denoise)
    if cache_key not in _SOLVE_CACHE:
        sde = VPSDE()
        _SOLVE_CACHE[cache_key] = jax.jit(
            lambda k, m=method, d=denoise, s=sde: sample(
                s, gaussian_score(s, 0.3, 0.5), SHAPE, k,
                method=m, denoise=d, **FAST_KWARGS[m],
            )
        )
    return _SOLVE_CACHE[cache_key](jax.random.PRNGKey(seed))


def test_fast_kwargs_cover_registry():
    """A solver registered without a FAST_KWARGS row escapes the
    property net below — fail loudly instead."""
    assert set(available_solvers()) == set(FAST_KWARGS)


@given(st.sampled_from(sorted(FAST_KWARGS)), st.booleans(),
       st.integers(0, 2**16))
def test_registry_shared_signature_and_finite_samples(method, denoise, seed):
    """Every registered solver accepts the one ``sample(...)`` signature
    and returns finite samples of the requested shape, for any seed."""
    res = _solve(method, denoise, seed)
    assert res.x.shape == SHAPE
    assert bool(jnp.all(jnp.isfinite(res.x)))
    assert res.nfe.shape == (SHAPE[0],)
    assert bool(jnp.all(res.nfe > 0))


@given(st.sampled_from(sorted(FAST_KWARGS)), st.booleans(),
       st.integers(0, 2**16))
def test_registry_nfe_accounting(method, denoise, seed):
    """Score-eval accounting per family: the adaptive carry family obeys
    nfe == 2·(accepted+rejected) (+1 denoise); fixed-grid solvers report
    their exact grid cost with zero accept/reject counters; the
    batch-global RK45 reports one uniform count."""
    res = _solve(method, denoise, seed)
    nfe = np.asarray(res.nfe)
    acc = np.asarray(res.accepted)
    rej = np.asarray(res.rejected)
    extra = 1 if denoise else 0
    if method in ("adaptive", "momentum", "heun"):
        np.testing.assert_array_equal(nfe, 2 * (acc + rej) + extra)
        assert (acc > 0).all()  # every sample took at least one step
    else:
        assert (acc == 0).all() and (rej == 0).all()
        n_steps = FAST_KWARGS[method].get("n_steps")
        if method in ("em", "ddim"):
            np.testing.assert_array_equal(nfe, n_steps + extra)
        elif method == "pc":  # 1 predictor + 1 Langevin eval per step
            np.testing.assert_array_equal(nfe, 2 * n_steps + extra)
        elif method == "pc_hmc":  # 1 predictor + L=3 leapfrog evals
            np.testing.assert_array_equal(nfe, 4 * n_steps + extra)
        else:  # ode: batch-global adaptive RK45 — uniform across samples
            assert (nfe == nfe[0]).all()


@given(st.sampled_from(["adaptive", "momentum", "heun"]),
       st.integers(0, 2**16))
def test_carry_family_respects_t_eps(method, seed):
    """The carry family integrates to exactly t_eps — never below (the
    score blows up at t→0) and done means *at* the floor, for every
    config variant of the Algorithm-1 body."""
    sde = VPSDE()
    cfg_by = {
        "adaptive": AdaptiveConfig(eps_rel=0.3),
        "momentum": AdaptiveConfig(eps_rel=0.3, momentum=0.15),
        "heun": AdaptiveConfig(eps_rel=0.3, probability_flow=True),
    }
    cfg = cfg_by[method]
    cache_key = ("chunk", method)
    if cache_key not in _SOLVE_CACHE:
        _SOLVE_CACHE[cache_key] = jax.jit(
            lambda c, s=sde, cf=cfg: solve_chunk(
                s, gaussian_score(s, 0.3, 0.5), c,
                max_sync_iters=cf.max_iters, config=cf,
            )
        )
    k_prior, k_solve = jax.random.split(jax.random.PRNGKey(seed))
    carry = init_carry(sde, sde.prior_sample(k_prior, SHAPE), k_solve,
                       config=cfg)
    carry = _SOLVE_CACHE[cache_key](carry)
    assert bool(carry.done.all())
    t = np.asarray(carry.t)
    assert (t <= sde.t_eps + 1e-12).all()
    assert (t >= sde.t_eps - 1e-6).all()
    res = finalize(sde, gaussian_score(sde, 0.3, 0.5), carry, denoise=False)
    assert bool(jnp.all(jnp.isfinite(res.x)))


@given(st.integers(1, 6), st.integers(1, 4), st.integers(2, 5))
def test_delay_pattern_roundtrip(B, K, S_mult):
    from repro.data.tokens import apply_delay_pattern

    S = K + S_mult
    toks = jnp.arange(B * S * K).reshape(B, S, K) % 17 + 1
    d = apply_delay_pattern(toks)
    # codebook k shifted right by k with zero padding
    for k in range(K):
        np.testing.assert_array_equal(np.asarray(d[:, :k, k]), 0)
        np.testing.assert_array_equal(
            np.asarray(d[:, k:, k]), np.asarray(toks[:, : S - k, k])
        )
