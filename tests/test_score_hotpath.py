"""Score-network hot-path guardrails (DESIGN.md §13).

Three families:

  * **public attention owner** — ``repro.models.attention.attention`` is
    the single flash/softcap/window dispatch point: ``use_flash=False``
    is bitwise the reference path, the flash path matches to kernel
    tolerance (including the sequence-padding path), and softcap /
    cross-length calls fall back to the reference bitwise.
  * **DiT / temporal-UNet routing** — flash-vs-reference and
    fused-vs-unfused parity per precision preset, and the off-state /
    fresh-block bitwise-neutrality pins: flags default off, a config
    with the flags off produces bit-identical params AND outputs to the
    pre-flag stack, and a freshly-initialized attention block (zero-init
    output projection) is the identity.
  * **_groupnorm fp32-stats regression** — the bf16-preset audit: group
    statistics must be computed in fp32 (a large common offset with
    small spread would lose its variance to bf16 cancellation),
    parametrized over operand dtype.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import resolve_policy
from repro.models.attention import _ref_attention, attention
from repro.models.dit import DiTConfig, dit_forward, init_dit
from repro.models.temporal_unet import (
    TemporalUNetConfig, _groupnorm, _gn_silu, init_temporal_unet,
    temporal_unet_forward,
)

PRESETS = ["fp32", "bf16", "bf16_full"]
# fast-vs-baseline forward tolerance per preset (outputs compared in
# fp32): fp32 differs only by kernel reduction order; the bf16 presets
# add one-vs-two rounding in the norm chain and bf16 matmul inputs
TOLS = {"fp32": dict(rtol=1e-4, atol=1e-4),
        "bf16": dict(rtol=5e-2, atol=5e-2),
        "bf16_full": dict(rtol=5e-2, atol=5e-2)}


def _f32(a):
    return np.asarray(a, np.float32)


def _qkv(rng, B=2, S=37, H=4, D=16):
    kq, kk, kv = jax.random.split(rng, 3)
    # (B, S, H, D) — the model-side layout the owner accepts
    return (jax.random.normal(kq, (B, S, H, D)),
            jax.random.normal(kk, (B, S, H, D)),
            jax.random.normal(kv, (B, S, H, D)))


# --------------------------- attention owner ---------------------------

def test_attention_off_state_bitwise(rng):
    """use_flash=False IS the reference path — bitwise, not allclose."""
    q, k, v = _qkv(rng)
    out = attention(q, k, v, causal=False, window=None, softcap=0.0,
                    use_flash=False)
    want = _ref_attention(q, k, v, causal=False, window=None, softcap=0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_attention_flash_padding_path(rng):
    """S=25 with 8-wide blocks pads 7 key/query rows — the masked tail
    must not leak into the softmax."""
    q, k, v = _qkv(rng, S=25)
    out = attention(q, k, v, causal=False, window=None, softcap=0.0,
                    use_flash=True, block_q=8, block_k=8)
    want = _ref_attention(q, k, v, causal=False, window=None, softcap=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_attention_softcap_falls_back_bitwise(rng):
    """No flash softcap kernel — the owner must take the reference path
    (with the cap applied) even when use_flash=True."""
    q, k, v = _qkv(rng)
    out = attention(q, k, v, causal=False, window=None, softcap=30.0,
                    use_flash=True)
    want = _ref_attention(q, k, v, causal=False, window=None, softcap=30.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_attention_cross_length_falls_back_bitwise(rng):
    """Sq != Sk (cross-attention) has no flash path — reference, bitwise."""
    q, _, _ = _qkv(rng, S=8)
    _, k, v = _qkv(rng, S=16)
    out = attention(q, k, v, causal=False, window=None, softcap=0.0,
                    use_flash=True)
    want = _ref_attention(q, k, v, causal=False, window=None, softcap=0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ------------------------------- DiT ----------------------------------

def _small_dit(**kw):
    return DiTConfig(image_size=16, patch=4, d_model=64, num_layers=2,
                     num_heads=4, d_ff=128, **kw)


@pytest.mark.parametrize("preset", PRESETS)
def test_dit_flash_parity(preset, rng):
    cfg0 = _small_dit()
    cfg1 = dataclasses.replace(cfg0, use_flash=True)
    assert cfg0.use_flash is False  # flag defaults off
    policy = resolve_policy(preset)
    params = policy.cast_params(init_dit(cfg0, rng))
    x = jax.random.normal(rng, (2, 16, 16, 3))
    t = jnp.linspace(0.1, 1.0, 2)
    base = dit_forward(params, x, t, cfg0, policy=policy)
    fast = dit_forward(params, x, t, cfg1, policy=policy)
    np.testing.assert_allclose(_f32(base), _f32(fast), **TOLS[preset])


def test_dit_flash_token_padding(rng):
    """image_size=8 / patch=4 → 4 tokens, under the kernel's minimum
    8-wide block: the owner's flash path must survive the pad-and-mask
    route, not just block-aligned token counts."""
    cfg0 = DiTConfig(image_size=8, patch=4, d_model=32, num_layers=1,
                     num_heads=4, d_ff=64)
    cfg1 = dataclasses.replace(cfg0, use_flash=True)
    params = init_dit(cfg0, rng)
    x = jax.random.normal(rng, (2, 8, 8, 3))
    t = jnp.linspace(0.1, 1.0, 2)
    base = dit_forward(params, x, t, cfg0)
    fast = dit_forward(params, x, t, cfg1)
    np.testing.assert_allclose(_f32(base), _f32(fast), rtol=3e-5, atol=3e-5)


# --------------------------- temporal UNet -----------------------------

UCFG = TemporalUNetConfig(horizon=16, transition_dim=6, base=16,
                          mults=(1, 2), t_dim=32, groups=4, attn_heads=4)


def _liven(params, key, wo=False):
    """Perturb the zero-init leaves (conv2/conv_out, optionally the
    attention output projection) so forwards carry signal — a fresh
    net's output is identically zero and every parity check would pass
    vacuously."""
    ks = iter(jax.random.split(key, 64))
    bump = lambda w: 0.02 * jax.random.normal(next(ks), w.shape, w.dtype)
    blocks = ([d["res"] for d in params["downs"]]
              + [params["mid1"], params["mid2"]]
              + [u["res"] for u in params["ups"]])
    for blk in blocks:
        blk["conv2"] = bump(blk["conv2"])
    params["conv_out"] = bump(params["conv_out"])
    if wo:
        params["attn"]["wo"] = bump(params["attn"]["wo"])
    return params


def _traj_inputs(rng, cfg=UCFG, B=3):
    x = jax.random.normal(rng, (B, cfg.horizon, cfg.transition_dim))
    t = jnp.linspace(0.1, 1.0, B)
    return x, t


def test_unet_param_tree_backcompat(rng):
    """attention=True appends params LAST: every pre-existing leaf is
    bit-identical to the attention=False init from the same key."""
    pa = init_temporal_unet(dataclasses.replace(UCFG, attention=True), rng)
    pb = init_temporal_unet(UCFG, rng)
    attn = pa.pop("attn")
    assert set(attn) == {"gn_s", "gn_b", "wq", "wk", "wv", "wo"}
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), pa, pb)


def test_unet_fresh_attention_block_bitwise_neutral(rng):
    """Zero-init output projection: a freshly-added bottleneck attention
    block is the identity, so attention=True-with-fresh-block and
    attention=False produce bit-identical outputs."""
    cfg_on = dataclasses.replace(UCFG, attention=True)
    params = _liven(init_temporal_unet(cfg_on, rng), rng)  # wo stays zero
    x, t = _traj_inputs(rng)
    on = temporal_unet_forward(params, x, t, cfg_on)
    off = temporal_unet_forward(
        {k: v for k, v in params.items() if k != "attn"}, x, t, UCFG)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))


def test_unet_off_state_is_unfused_chain(rng):
    """use_fused_norm=False is literally the historical
    silu(_groupnorm(...)) chain — bitwise."""
    assert UCFG.use_fused_norm is False and UCFG.use_flash is False
    kx, ks, kb = jax.random.split(rng, 3)
    x = jax.random.normal(kx, (3, 16, 32))
    scale = 1.0 + 0.1 * jax.random.normal(ks, (32,))
    bias = 0.1 * jax.random.normal(kb, (32,))
    a = _gn_silu(x, scale, bias, 4, fused=False)
    b = jax.nn.silu(_groupnorm(x, scale, bias, 4))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("preset", PRESETS)
def test_unet_fast_path_parity(preset, rng):
    """use_flash + use_fused_norm vs the jnp baseline, same (livened)
    params, per preset — the full-forward analog of the kernel sweeps."""
    cfg_base = dataclasses.replace(UCFG, attention=True)
    cfg_fast = dataclasses.replace(cfg_base, use_flash=True,
                                   use_fused_norm=True)
    policy = resolve_policy(preset)
    params = policy.cast_params(
        _liven(init_temporal_unet(cfg_base, rng), rng, wo=True))
    x, t = _traj_inputs(rng)
    base = temporal_unet_forward(params, x, t, cfg_base, policy=policy)
    fast = temporal_unet_forward(params, x, t, cfg_fast, policy=policy)
    np.testing.assert_allclose(_f32(base), _f32(fast), **TOLS[preset])


# ----------------------- _groupnorm fp32 stats -------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=lambda d: jnp.dtype(d).name)
def test_groupnorm_fp32_stats_large_offset(dtype, rng):
    """The bf16-preset audit pin: statistics run in fp32 regardless of
    operand dtype. x = 100 + 2·noise has var ≈ 4 while E[x²] ≈ 10⁴;
    bf16 statistics (or the one-pass E[x²]−μ² form near bf16 precision,
    where the spacing at 10⁴ is 64) would lose the variance to
    cancellation and return garbage normalization. The noise scale is
    chosen above bf16's quantization step at 100 (0.5), so the spread
    survives *input* quantization and any failure is the statistics'.
    The output must be ≈ zero-mean / unit-std per (sample, group) slab."""
    B, H, C, g = 4, 16, 32, 8
    noise = 2.0 * jax.random.normal(rng, (B, H, C))
    x = (100.0 + noise).astype(dtype)
    out = _f32(_groupnorm(x, jnp.ones((C,), dtype), jnp.zeros((C,), dtype), g))
    slabs = out.reshape(B, H, g, C // g)
    mu = slabs.mean(axis=(1, 3))
    sd = slabs.std(axis=(1, 3))
    tol = 5e-3 if dtype == jnp.float32 else 6e-2  # bf16 quantizes x itself
    np.testing.assert_allclose(mu, np.zeros_like(mu), atol=tol)
    np.testing.assert_allclose(sd, np.ones_like(sd), atol=2 * tol)
    # and the fp64 elementwise reference from the quantized operands
    xq = _f32(x).astype(np.float64).reshape(B, H, g, C // g)
    want = ((xq - xq.mean(axis=(1, 3), keepdims=True))
            / np.sqrt(xq.var(axis=(1, 3), keepdims=True) + 1e-6)
            ).reshape(B, H, C)
    np.testing.assert_allclose(out, want, rtol=2e-2, atol=2e-2)
