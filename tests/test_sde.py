"""SDE process invariants: marginals, kernels, priors, calibration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VESDE, VPSDE, SubVPSDE, get_sde


@pytest.mark.parametrize("name", ["ve", "vp", "subvp"])
def test_marginal_endpoints(name):
    sde = get_sde(name)
    m0, s0 = sde.marginal(jnp.asarray(sde.t_eps))
    m1, s1 = sde.marginal(jnp.asarray(1.0))
    # near t=0: almost no corruption
    assert float(m0) == pytest.approx(1.0, abs=1e-2)
    assert float(s0) < 0.15
    # at t=1 the prior: VP/subVP std→1, VE std→sigma_max
    assert float(s1) == pytest.approx(sde.prior_std(), rel=0.05)


@pytest.mark.parametrize("name", ["ve", "vp", "subvp"])
def test_perturb_matches_marginal_stats(name, rng):
    sde = get_sde(name)
    x0 = jnp.full((20000, 1), 0.7)
    t = jnp.full((20000,), 0.5)
    z = jax.random.normal(rng, x0.shape)
    xt = sde.perturb(x0, t, z)
    m, s = sde.marginal(jnp.asarray(0.5))
    np.testing.assert_allclose(float(xt.mean()), float(m) * 0.7, atol=4e-2 * float(s))
    np.testing.assert_allclose(float(xt.std()), float(s), rtol=3e-2)


@pytest.mark.parametrize("name", ["ve", "vp"])
def test_kernel_score_is_gaussian_grad(name, rng):
    """∇ log N(xt; m·x0, s²) must equal the autodiff gradient."""
    sde = get_sde(name)
    x0 = jax.random.normal(rng, (8, 3))
    t = jnp.linspace(0.2, 0.9, 8)
    z = jax.random.normal(jax.random.fold_in(rng, 1), x0.shape)
    xt = sde.perturb(x0, t, z)

    def logp(xt_single, x0_single, t_single):
        m, s = sde.marginal(t_single)
        return jnp.sum(-0.5 * ((xt_single - m * x0_single) / s) ** 2)

    autodiff = jax.vmap(jax.grad(logp))(xt, x0, t)
    np.testing.assert_allclose(
        np.asarray(sde.kernel_score(xt, x0, t)), np.asarray(autodiff),
        rtol=1e-4, atol=1e-5,
    )


def test_paper_abs_tolerances():
    """Paper Sec. 3.1.2: ε_abs = 0.0078 for VP ([-1,1]), 0.0039 for VE ([0,1])."""
    assert VPSDE().abs_tolerance == pytest.approx(2.0 / 256)
    assert VESDE().abs_tolerance == pytest.approx(1.0 / 256)


@pytest.mark.parametrize("name", ["ve", "vp"])
def test_drift_coeff_linearity(name, rng):
    sde = get_sde(name)
    x = jax.random.normal(rng, (4, 5))
    t = jnp.linspace(0.1, 0.9, 4)
    a = sde.drift_coeff(t)
    np.testing.assert_allclose(
        np.asarray(sde.drift(x, t)), np.asarray(a[:, None] * x),
        rtol=1e-6, atol=1e-7,
    )


def test_ve_sigma_geometric():
    sde = VESDE(sigma_min=0.01, sigma_max=50.0)
    assert float(sde.sigma(jnp.asarray(0.0))) == pytest.approx(0.01)
    assert float(sde.sigma(jnp.asarray(1.0))) == pytest.approx(50.0)
    # geometric interpolation: log-linear
    mid = float(sde.sigma(jnp.asarray(0.5)))
    assert mid == pytest.approx((0.01 * 50.0) ** 0.5, rel=1e-5)


def test_tweedie_denoise_recovers_mean(rng):
    """With the exact conditional score, Tweedie returns E[x0|xt] = x0 when
    the data is a point mass."""
    for sde in (VPSDE(), VESDE(sigma_max=5.0)):
        x0 = jnp.full((4096, 2), 0.25)
        t = jnp.full((4096,), sde.t_eps)
        z = jax.random.normal(rng, x0.shape)
        xt = sde.perturb(x0, t, z)
        score = sde.kernel_score(xt, x0, t)
        denoised = sde.tweedie_denoise(xt, score)
        np.testing.assert_allclose(np.asarray(denoised), np.asarray(x0), atol=1e-4)
