"""Continuous-batching scheduler: correctness of slot multiplexing.

The gold standard: every request's output must equal what it would get
decoded ALONE (greedy, same params) — proving (a) prompt replay is
faithful and (b) slot reuse leaks no KV across requests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_decode_state, decode_step, init_model
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model(rng=jax.random.PRNGKey(3)):
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61)
    params = init_model(cfg, rng)
    return cfg, params


def _decode_alone(cfg, params, prompt, n_new):
    """Reference: single-sequence greedy decode."""
    state = init_decode_state(cfg, 1, cache_len=64)
    step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg))
    tok = None
    for t in prompt:
        logits, state = step(params, jnp.asarray([[t]], jnp.int32), state)
        tok = int(jnp.argmax(logits[0, 0]))
    out = [tok]
    for _ in range(n_new - 1):
        logits, state = step(params, jnp.asarray([[out[-1]]], jnp.int32), state)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def test_batched_outputs_match_solo_decoding(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 5, 2, 4, 3, 6)]
    n_new = [4, 3, 5, 2, 4, 3]

    batcher = ContinuousBatcher(cfg, params, slots=2, cache_len=64)
    for uid, (p, n) in enumerate(zip(prompts, n_new)):
        batcher.submit(Request(uid=uid, prompt=p, max_new_tokens=n))
    finished = batcher.run_to_completion()
    assert len(finished) == len(prompts)

    for uid, (p, n) in enumerate(zip(prompts, n_new)):
        want = _decode_alone(cfg, params, p.tolist(), n)
        got = finished[uid].output
        assert got == want, (uid, got, want)


def test_slot_reuse_no_leakage(model):
    """Same prompt submitted twice, separated by other traffic through
    the same slot, must produce identical outputs."""
    cfg, params = model
    p = np.asarray([7, 11, 13], np.int32)
    batcher = ContinuousBatcher(cfg, params, slots=1, cache_len=64)
    batcher.submit(Request(uid=0, prompt=p, max_new_tokens=4))
    batcher.submit(Request(uid=1, prompt=np.asarray([3, 5], np.int32),
                           max_new_tokens=3))
    batcher.submit(Request(uid=2, prompt=p, max_new_tokens=4))
    finished = batcher.run_to_completion()
    assert finished[0].output == finished[2].output


def test_eos_stops_early(model):
    cfg, params = model
    p = np.asarray([1, 2], np.int32)
    # find which token the model actually emits first, use it as EOS
    probe = ContinuousBatcher(cfg, params, slots=1, cache_len=64)
    probe.submit(Request(uid=0, prompt=p, max_new_tokens=1))
    first = probe.run_to_completion()[0].output[0]

    b = ContinuousBatcher(cfg, params, slots=1, cache_len=64)
    b.submit(Request(uid=0, prompt=p, max_new_tokens=10, eos_id=first))
    out = b.run_to_completion()[0].output
    assert out[-1] == first and len(out) <= 10
