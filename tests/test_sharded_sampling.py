"""Mesh-sharded sampling & serving (DESIGN.md §3).

Two layers of coverage:

  * in-process, on the single real CPU device: a degenerate 1-device
    mesh must be a bit-exact no-op for ``sample(..., mesh=...)``, the
    shard_map'd fused kernel, and the sharded ``DiffusionBatcher`` —
    cheap guards that run on every test invocation;
  * subprocess, with ≥2 fake host devices forced via
    ``xla_force_host_platform_device_count`` (the same trick the
    production dry-run uses): ``repro.launch.sharded_selftest`` executes
    the genuinely multi-device path and asserts (a) bit-identical
    samples sharded vs unsharded for a fixed seed, and (b) per-device
    slot refill in the batcher.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AdaptiveConfig, VPSDE, sample
from repro.core.analytic import gaussian_noise_pred, gaussian_score

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MU, S0 = 0.3, 0.5


def _score(sde):
    return gaussian_score(sde, MU, S0)


# ---------------------------------------------------------------------------
# in-process: 1-device mesh is an exact no-op
# ---------------------------------------------------------------------------


def test_sample_mesh_1device_bitwise_noop():
    sde = VPSDE()
    mesh = jax.make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    cfg = AdaptiveConfig(eps_rel=0.05)
    ref = jax.jit(lambda k: sample(sde, _score(sde), (4, 32), k, config=cfg))(key)
    sh = jax.jit(
        lambda k: sample(sde, _score(sde), (4, 32), k, config=cfg, mesh=mesh)
    )(key)
    np.testing.assert_array_equal(np.asarray(ref.x), np.asarray(sh.x))
    np.testing.assert_array_equal(np.asarray(ref.nfe), np.asarray(sh.nfe))


def test_sample_mesh_indivisible_batch_replicates():
    # batch 3 on a 1-device mesh: batch_sharding falls back to replication
    # and sampling still works (the guard for batch % devices != 0).
    sde = VPSDE()
    mesh = jax.make_mesh((1,), ("data",))
    res = sample(sde, _score(sde), (3, 16), jax.random.PRNGKey(1),
                 config=AdaptiveConfig(eps_rel=0.1), mesh=mesh)
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_adaptive_accepts_replicated_sharding():
    # P() has no leading entry — must be treated as "no batch axes",
    # not crash (regression: IndexError on sharding.spec[0])
    from repro.parallel.sharding import replicated

    mesh = jax.make_mesh((1,), ("data",))
    sde = VPSDE()
    res = sample(sde, _score(sde), (2, 16), jax.random.PRNGKey(0),
                 config=AdaptiveConfig(eps_rel=0.1, use_fused_kernel=True),
                 sharding=replicated(mesh))
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_sharded_error_step_1device_matches():
    from repro.kernels.solver_step import ops

    mesh = jax.make_mesh((1,), ("data",))
    ks = jax.random.split(jax.random.PRNGKey(2), 8)
    B, shape = 4, (4, 6, 5)  # D=30: exercises lane padding
    x, xp, s2, z, xv = (jax.random.normal(k, shape) for k in ks[:5])
    e0, d1, d2 = (0.01 * jax.random.normal(k, (B,)) for k in ks[5:])
    kw = dict(eps_abs=1e-2, eps_rel=0.01)
    ref_x, ref_e = ops.error_step(x, xp, s2, z, xv, e0, d1, d2, **kw)
    sh_x, sh_e = ops.sharded_error_step(
        x, xp, s2, z, xv, e0, d1, d2, mesh=mesh, batch_axes=("data",), **kw
    )
    np.testing.assert_array_equal(np.asarray(ref_x), np.asarray(sh_x))
    np.testing.assert_array_equal(np.asarray(ref_e), np.asarray(sh_e))


def test_batcher_mesh_1device():
    from repro.launch.sample import make_sample_step
    from repro.models.dit import DiTConfig
    from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)
    step = make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))
    mesh = jax.make_mesh((1,), ("data",))
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(16,),
                         slots=4, cfg=cfg, mesh=mesh)
    for uid in range(8):
        b.submit(ImageRequest(uid=uid, seed=uid))
    done = b.run_to_completion()
    assert len(done) == 8
    assert b.refills_per_device == [8]
    assert all(np.isfinite(done[u].result).all() for u in range(8))


def test_batcher_slots_must_divide_devices():
    from repro.serving.diffusion_server import DiffusionBatcher

    class TwoDeviceMesh:  # duck-type: pretend 2 data devices
        shape = {"data": 2}
        axis_names = ("data",)

    with pytest.raises(ValueError, match="divide"):
        DiffusionBatcher(VPSDE(), lambda p, s: s, None, (8,), slots=3,
                        mesh=TwoDeviceMesh())


# ---------------------------------------------------------------------------
# subprocess: real multi-device path on ≥2 forced fake devices
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def selftest_results():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               SELFTEST_DEVICES="4")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sharded_selftest"],
        env=env, capture_output=True, text=True, timeout=560, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_selftest_sample_bitwise_equivalence(selftest_results):
    res = selftest_results
    assert res["devices"] >= 2
    for kind in ("sample_jnp", "sample_fused"):
        assert res[kind]["bitwise_equal"], res
        assert res[kind]["max_abs_diff"] == 0.0, res
        assert res[kind]["sharded_over_devices"], res


@pytest.mark.slow
def test_selftest_fused_kernel_sharding(selftest_results):
    assert selftest_results["fused_kernel"]["batch_sharded_bitwise"]
    assert selftest_results["fused_kernel"]["feature_sharded_close"]


@pytest.mark.slow
def test_selftest_batcher_per_device_refill(selftest_results):
    b = selftest_results["batcher"]
    assert b["all_completed"] and b["finite"]
    # every device refilled its slots beyond the initial fill, and every
    # request was assigned exactly once — refill is per-device
    assert b["per_device_refill"], b
    assert b["total_assignments_match"], b
    assert len(b["refills_per_device"]) == selftest_results["devices"]
    # per-slot keys: identical per-request samples for sharded horizon-4
    # vs unsharded horizon-1 serving (shard-local compaction is invisible)
    assert b["scheduling_invariant"], b


@pytest.mark.slow
def test_selftest_device_resident_serving(selftest_results):
    """Device-resident serving on a real multi-device mesh (DESIGN.md
    §12): bit-identical deliveries and accounting vs the host-driven
    sharded loop, with strictly less device→host traffic."""
    dr = selftest_results["device_resident"]
    assert dr["all_completed"] and dr["bitwise_equal"], dr
    assert dr["iterations_equal"], dr
    assert dr["transfers_reduced"], dr
    assert dr["resident_transfers"] < dr["host_transfers"]
