"""Unit tests of the sharding rules against mesh stand-ins (no devices).

The real 256/512-device lowering is exercised by test_dryrun_integration
(subprocess); here we verify the rule logic: divisibility fallbacks,
expert vs ffn sharding, vocab sharding, repeat-axis handling.
"""

import jax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import _spec_for


class FakeMesh:
    """Duck-typed stand-in: .shape mapping + .axis_names."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)


def spec(path, shape, num_experts=None):
    return _spec_for(path, shape, MESH, num_experts)


def test_attention_projections():
    # stacked (R, E, H, Dh): heads shard when divisible
    assert spec("blocks/p0/mixer/wq", (4, 2048, 16, 128)) == P(None, None, "model", None)
    # kv heads = 8 not divisible by 16 → replicated
    assert spec("blocks/p0/mixer/wk", (4, 2048, 8, 128)) == P(None, None, None, None)
    assert spec("blocks/p0/mixer/wo", (4, 16, 128, 2048)) == P(None, "model", None, None)


def test_dense_mlp():
    assert spec("blocks/p0/mlp/w_in", (4, 2048, 8192)) == P(None, None, "model")
    assert spec("blocks/p0/mlp/w_out", (4, 8192, 2048)) == P(None, "model", None)


def test_moe_expert_sharding_divisible():
    # deepseek: 64 experts % 16 == 0 → shard the expert axis
    assert spec("blocks/p0/mlp/w_in", (1, 64, 2048, 1408), 64) == \
        P(None, "model", None, None)
    assert spec("blocks/p0/mlp/w_out", (1, 64, 1408, 2048), 64) == \
        P(None, "model", None, None)


def test_moe_expert_sharding_fallback():
    # granite: 40 experts % 16 != 0 → shard each expert's ffn dim instead
    assert spec("blocks/p0/mlp/w_in", (1, 40, 1536, 512), 40) == \
        P(None, None, None, "model")
    assert spec("blocks/p0/mlp/w_out", (1, 40, 512, 1536), 40) == \
        P(None, None, "model", None)


def test_router_replicated():
    assert spec("blocks/p0/mlp/router", (1, 2048, 64), 64) == P(None, None, None)


def test_vocab_sharding():
    assert spec("embed", (50304, 2048)) == P("model", None)
    assert spec("lm_head", (2048, 50304)) == P(None, "model")
    # audio codebook embeds (K, V, E)
    assert spec("embed", (4, 2048, 1536)) == P(None, "model", None)
    # odd vocab (granite 49155) → replicate rather than crash
    assert spec("embed", (49155, 1536)) == P(None, None)


def test_mamba_projections():
    assert spec("blocks/p0/mixer/in_x", (8, 2560, 5120)) == P(None, None, "model")
    assert spec("blocks/p0/mixer/in_B", (8, 2560, 128)) == P(None, None, None)
    assert spec("blocks/p0/mixer/A_log", (8, 80)) == P(None, "model")
    assert spec("blocks/p0/mixer/out", (8, 5120, 2560)) == P(None, "model", None)


def test_norms_replicated():
    assert spec("blocks/p0/norm1/scale", (4, 2048)) == P(None, None)


def test_kv_cache_policy():
    from repro.parallel.sharding import kv_cache_spec

    sizes = {"data": 16, "model": 16}
    # kv=8 not divisible by model=16 → cache sequence shards over model
    s = kv_cache_spec(sizes, ("data",), batch=128, cache_len=32768, kv_heads=8)
    assert s == P(("data",), "model", None, None)
    # kv=16 divisible → heads shard
    s = kv_cache_spec(sizes, ("data",), batch=128, cache_len=32768, kv_heads=16)
    assert s == P(("data",), None, "model", None)
    # batch=1 long context, kv indivisible: sequence takes data AND model
    s = kv_cache_spec(sizes, ("data",), batch=1, cache_len=524288, kv_heads=8)
    assert s == P(None, ("data", "model"), None, None)
    # batch=1, kv divisible: sequence over data, heads over model
    s = kv_cache_spec(sizes, ("data",), batch=1, cache_len=524288, kv_heads=16)
    assert s == P(None, ("data",), "model", None)
    # multi-pod: batch over (pod, data)
    sizes2 = {"pod": 2, "data": 16, "model": 16}
    s = kv_cache_spec(sizes2, ("pod", "data"), batch=128, cache_len=32768,
                      kv_heads=16)
    assert s == P(("pod", "data"), None, "model", None)
