"""Resumable-solver invariants (DESIGN.md §7).

The horizon-chunked solve must be a pure re-chunking of the monolithic
``adaptive()`` while_loop: same ops, same PRNG threading, so chaining
``solve_chunk`` across any horizon is bit-identical to the one-shot
solve. On top of that, Algorithm-1 accounting obeys exact invariants:
``nfe == 2·(accepted+rejected) (+1 with denoise)``, counters are
per-sample monotone across chunk boundaries, and rejections do not bias
the driving noise (Algorithm 2 retains z across rejections).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaptiveConfig,
    ForwardAdaptiveConfig,
    VPSDE,
    adaptive_forward,
    finalize,
    init_carry,
    inpaint,
    sample,
    solve_chunk,
    solve_in_chunks,
)
from repro.core.analytic import gaussian_score

MU, S0 = 0.3, 0.5


def _score(sde):
    return gaussian_score(sde, MU, S0)


#: the carry-based zoo families (DESIGN.md §11) — every config variant
#: of the Algorithm-1 body must satisfy the same §7 invariants
FAMILY_CONFIGS = {
    "adaptive": AdaptiveConfig(eps_rel=0.05),
    "momentum": AdaptiveConfig(eps_rel=0.05, momentum=0.15),
    "heun": AdaptiveConfig(eps_rel=0.05, probability_flow=True),
}


# ---------------------------------------------------------------------------
# chunked ≡ monolithic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
@pytest.mark.parametrize("horizon", [1, 7, 64])
def test_chained_chunks_bitwise_match_monolithic(horizon, family, rng):
    """The acceptance bar: fixed seed ⇒ solve_in_chunks(max_sync_iters=N)
    equals the monolithic solve bit-for-bit, for any chunk size — for
    every carry-based zoo family (they share the Algorithm-1 body, so
    they must inherit the §7 invariant, not re-prove it)."""
    sde = VPSDE()
    cfg = FAMILY_CONFIGS[family]
    mono = jax.jit(
        lambda k: sample(sde, _score(sde), (8, 16), k, config=cfg)
    )(rng)
    chunked = solve_in_chunks(
        sde, _score(sde), (8, 16), rng, max_sync_iters=horizon, config=cfg
    )
    for field in ("x", "nfe", "accepted", "rejected", "iterations"):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, field)), np.asarray(getattr(chunked, field)),
            err_msg=field,
        )


def test_chunk_respects_horizon_and_done_mask(rng):
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.1)
    k_prior, k_solve = jax.random.split(rng)
    x0 = sde.prior_sample(k_prior, (4, 16))
    carry = init_carry(sde, x0, k_solve, config=cfg)
    assert not bool(carry.done.any())
    step = jax.jit(
        lambda c: solve_chunk(sde, _score(sde), c, max_sync_iters=5, config=cfg)
    )
    carry = step(carry)
    assert int(carry.iterations) == 5  # nobody converges in 5 iterations
    while bool(jnp.any(~carry.done)):
        carry = step(carry)
    # done ⇔ t at t_eps (the serving loop retires on exactly this mask)
    assert bool(jnp.all(carry.t <= sde.t_eps + 1e-12))
    res = finalize(sde, _score(sde), carry, denoise=False)
    assert bool(jnp.all(jnp.isfinite(res.x)))


def test_fused_kernel_chunking_matches_fused_monolithic(rng):
    """Chunk boundaries are also transparent to the fused-kernel path."""
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05, use_fused_kernel=True)
    mono = jax.jit(
        lambda k: sample(sde, _score(sde), (4, 24), k, config=cfg)
    )(rng)
    chunked = solve_in_chunks(
        sde, _score(sde), (4, 24), rng, max_sync_iters=9, config=cfg
    )
    np.testing.assert_array_equal(np.asarray(mono.x), np.asarray(chunked.x))
    np.testing.assert_array_equal(np.asarray(mono.nfe), np.asarray(chunked.nfe))


def test_per_slot_keys_match_shared_key_statistics(rng):
    """A (B, 2) per-slot key carry solves to the same distribution (it
    cannot be bitwise — the noise streams differ by construction)."""
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    k_prior, k_solve = jax.random.split(rng)
    x0 = sde.prior_sample(k_prior, (128, 8))
    keys = jax.random.split(k_solve, 128)  # (128, 2) per-slot
    carry = init_carry(sde, x0, keys, config=cfg)
    assert carry.per_slot_keys
    carry = jax.jit(
        lambda c: solve_chunk(
            sde, _score(sde), c, max_sync_iters=cfg.max_iters, config=cfg
        )
    )(carry)
    res = finalize(sde, _score(sde), carry, denoise=False)
    m, s = sde.marginal(jnp.asarray(sde.t_eps))
    assert float(res.x.mean()) == pytest.approx(float(m) * MU, abs=0.06)


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
def test_compaction_permutation_moves_payload_with_slots(family, rng):
    """Slot compaction in miniature, for every carry family: under
    per-slot keys, permuting every leading-B carry leaf mid-solve —
    state, x_prev (the momentum buffer), keys, AND the cond payload —
    and continuing must equal the unpermuted solve with slots relabeled.
    If a payload leaf failed to travel with its slot, the inpainting
    projection would pin the wrong rows and the comparison would break;
    this is exactly the move DiffusionBatcher's compaction performs."""
    sde = VPSDE()
    B, D = 8, 8
    observed = MU + S0 * jax.random.normal(jax.random.PRNGKey(5), (B, D))
    mask = jnp.zeros((B, D)).at[:, : D // 2].set(1.0)
    conditioner, cond = inpaint(mask, observed)
    cfg = dataclasses.replace(FAMILY_CONFIGS[family], conditioner=conditioner)

    k_prior, k_solve = jax.random.split(rng)
    x0 = sde.prior_sample(k_prior, (B, D))
    keys = jax.random.split(k_solve, B)  # per-slot: noise is slot-invariant
    step = jax.jit(
        lambda c: solve_chunk(sde, _score(sde), c, max_sync_iters=4,
                              config=cfg)
    )

    def run_to_done(carry):
        while bool(jnp.any(~carry.done)):
            carry = step(carry)
        return finalize(sde, _score(sde), carry, denoise=False,
                        conditioner=cfg.conditioner)

    carry = init_carry(sde, x0, keys, config=cfg, cond=cond)
    carry = step(carry)  # mid-flight: slots hold heterogeneous (t, h)

    perm = np.array([3, 0, 7, 1, 5, 2, 6, 4])
    permuted = jax.tree_util.tree_map(
        lambda leaf: leaf[perm]
        if hasattr(leaf, "ndim") and leaf.ndim >= 1 and leaf.shape[0] == B
        else leaf,
        carry,
    )

    res = run_to_done(carry)
    res_p = run_to_done(permuted)
    for field in ("x", "nfe", "accepted", "rejected"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res, field))[perm],
            np.asarray(getattr(res_p, field)),
            err_msg=field,
        )


# ---------------------------------------------------------------------------
# NFE / accounting invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(FAMILY_CONFIGS))
@pytest.mark.parametrize("denoise", [False, True], ids=["raw", "denoise"])
def test_nfe_identity(denoise, family, rng):
    """nfe == 2·(accepted + rejected) (+1 for the Tweedie denoise) — the
    Algorithm-1 accounting invariant, for every carry-based family."""
    sde = VPSDE()
    cfg = dataclasses.replace(FAMILY_CONFIGS[family], eps_rel=0.03)
    res = jax.jit(
        lambda k: sample(sde, _score(sde), (32, 8), k, config=cfg,
                         denoise=denoise)
    )(rng)
    want = 2 * (np.asarray(res.accepted) + np.asarray(res.rejected))
    if denoise:
        want = want + 1
    np.testing.assert_array_equal(np.asarray(res.nfe), want)
    if family == "adaptive":
        # rejections happened, so the identity covers the reject branch
        # too (the stochastic family at this tolerance always rejects;
        # the deterministic Heun path may legitimately never reject)
        assert int(res.rejected.sum()) > 0


def test_counters_monotone_across_chunks(rng):
    """Per-sample nfe/accepted/rejected are non-decreasing at every sync
    horizon, and only grow for samples that were still active."""
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    k_prior, k_solve = jax.random.split(rng)
    carry = init_carry(sde, sde.prior_sample(k_prior, (8, 16)), k_solve,
                       config=cfg)
    step = jax.jit(
        lambda c: solve_chunk(sde, _score(sde), c, max_sync_iters=6, config=cfg)
    )
    for _ in range(10_000):
        if not bool(jnp.any(~carry.done)):
            break
        prev = jax.tree_util.tree_map(np.asarray, carry)
        carry = step(carry)
        for name in ("nfe", "accepted", "rejected"):
            now = np.asarray(getattr(carry, name))
            before = getattr(prev, name)
            assert (now >= before).all(), name
            # frozen samples must not accrue anything
            frozen = prev.done
            assert (now[frozen] == before[frozen]).all(), name
        assert (np.asarray(carry.nfe)
                == 2 * (np.asarray(carry.accepted)
                        + np.asarray(carry.rejected))).all()
    assert bool(carry.done.all())


def test_fixed_step_solvers_report_zero_reject_counters(rng):
    sde = VPSDE()
    for method, kw in [("em", dict(n_steps=20)), ("ddim", dict(n_steps=10))]:
        res = sample(sde, _score(sde), (4, 8), rng, method=method, **kw)
        assert int(res.rejected.sum()) == 0



# ---------------------------------------------------------------------------
# host-loop entry points: closure caching + chunked mass sampling
# ---------------------------------------------------------------------------


def test_solve_in_chunks_reuses_compiled_chunk(rng):
    """Repeat ``solve_in_chunks`` calls with the same configuration hit
    the cached jitted chunk closure instead of retracing. The old code
    built ``jax.jit(lambda c: ...)`` fresh per call — a new callable
    every time, so jax's trace cache never hit and the serving/benchmark
    pattern paid a full recompile per call."""
    from repro.core.sampling import _chunk_jit

    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.1)
    score = _score(sde)  # one closure: part of the cache key
    _chunk_jit.cache_clear()
    r1 = solve_in_chunks(sde, score, (4, 8), rng, max_sync_iters=16,
                         config=cfg)
    assert _chunk_jit.cache_info().misses == 1
    r2 = solve_in_chunks(sde, score, (4, 8), rng, max_sync_iters=16,
                         config=cfg)
    info = _chunk_jit.cache_info()
    assert info.hits >= 1 and info.misses == 1
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))
    # a different configuration is a different closure, not a stale hit
    solve_in_chunks(sde, score, (4, 8), rng, max_sync_iters=32, config=cfg)
    assert _chunk_jit.cache_info().misses == 2


def test_sample_chunked_returns_host_numpy_and_exact_values(rng):
    """``sample_chunked`` must hand back *host* numpy (the old
    ``jnp.concatenate`` re-uploaded every chunk to build the full result
    on device) with values bit-identical to the straightforward
    per-chunk loop, including a tail chunk (n not a multiple)."""
    from repro.core.sampling import sample_chunked

    sde = VPSDE()
    score = _score(sde)
    chunk, n = 4, 10  # 3 chunks, ragged tail
    x, mean_nfe = sample_chunked(sde, score, n, (8,), rng, chunk=chunk,
                                 eps_rel=0.1)
    assert type(x) is np.ndarray and x.shape == (n, 8)
    assert isinstance(mean_nfe, float) and mean_nfe > 0
    # reference: the same key-split sequence, chunks pulled one by one
    fn = jax.jit(lambda k: sample(sde, score, (chunk, 8), k, eps_rel=0.1))
    key, outs, nfes = rng, [], []
    for _ in range(3):
        key, sub = jax.random.split(key)
        res = fn(sub)
        outs.append(np.asarray(res.x))
        nfes.append(np.asarray(res.nfe))
    np.testing.assert_array_equal(x, np.concatenate(outs)[:n])
    assert mean_nfe == pytest.approx(float(np.concatenate(nfes)[:n].mean()))


def test_rejection_retains_noise_without_bias(rng):
    """Algorithm 2 keeps the Gaussian z across rejections. If a rejection
    redrew z (the classic noise-bias bug: retrying until the error test
    passes selects for small-|z| draws), the stationary variance of the
    OU process would shrink. Force a rejection-heavy solve and check the
    stationary distribution is still exact."""
    lam, sigma = -1.0, 0.8
    # large h_init + moderate tolerance: plenty of rejections while the
    # solve still completes well before max_iters
    cfg = ForwardAdaptiveConfig(eps_abs=2e-2, eps_rel=0.1, h_init=0.1)
    res = adaptive_forward(
        drift_fn=lambda x, t: lam * x,
        diffusion_fn=lambda x, t: jnp.full_like(x, sigma),
        x0=jnp.zeros((1024, 2)),
        t_begin=0.0,
        t_end=4.0,  # ≫ relaxation time 1/|λ|
        key=rng,
        config=cfg,
    )
    assert int(res.iterations) < cfg.max_iters  # genuinely finished
    # rejections genuinely happened, many times per sample on average
    assert int(res.rejected.sum()) > 10 * res.x.shape[0]
    want_std = sigma / (2.0 * abs(lam)) ** 0.5
    assert float(res.x.mean()) == pytest.approx(0.0, abs=0.04)
    assert float(res.x.std()) == pytest.approx(want_std, rel=0.06)
