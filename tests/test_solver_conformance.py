"""Analytic solver-conformance suite.

For a linear (OU-class) SDE with Gaussian data x0 ~ N(MU, S0²), every
marginal of the forward process is Gaussian in closed form:

    x_t ~ N(m(t)·MU, m(t)²·S0² + std(t)²)

and the exact score is available, so every registered solver can be
checked against the *analytic* distribution at t = t_eps — no trained
network, no sampling noise floor beyond Monte-Carlo error. The suite
asserts:

  * conformance: each solver's samples land within tolerance of the
    analytic mean/std (exact 1-D Gaussian W2 distance);
  * the paper's core claim as a regression test: the adaptive solver
    reaches EM-1000's error level with a fraction of the NFE.

Every case appends a row to ``experiments/conformance/summary.{md,json}``
so CI can publish the numbers as a step summary.
"""

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.solver_select import select, write_selection, zoo_cases
from repro.core import VESDE, VPSDE, available_solvers, sample
from repro.core.analytic import (
    gaussian_marginal_moments, gaussian_score, gaussian_w2,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "experiments", "conformance")

MU, S0 = 0.3, 0.5
BATCH, DIM = 512, 8

_ROWS = []


@pytest.fixture(scope="module", autouse=True)
def _write_summary():
    yield
    if not _ROWS:
        return
    # the auto-selection report (DESIGN.md §11) is derived from the same
    # rows, so every tier-1 run refreshes selection.{md,json} alongside
    # the summary; bench_solver_zoo writes the same files with timings
    write_selection(select(_ROWS), OUT_DIR)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "summary.json"), "w") as f:
        json.dump(_ROWS, f, indent=1)
    lines = [
        "### Solver conformance (analytic OU marginal at t = t_eps)",
        "",
        "| solver | sde | precision | conditioner | mean err | std err "
        "| W2 | mean NFE | tol |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in _ROWS:
        lines.append(
            f"| {r['solver']} | {r['sde']} | {r.get('precision', 'fp32')} "
            f"| {r.get('conditioner', 'none')} "
            f"| {r['mean_err']:.4f} "
            f"| {r['std_err']:.4f} | {r['w2']:.4f} "
            f"| {r['mean_nfe']:.0f} | {r['tol']:.2f} |"
        )
    with open(os.path.join(OUT_DIR, "summary.md"), "w") as f:
        f.write("\n".join(lines) + "\n")


def analytic_score(sde):
    return gaussian_score(sde, MU, S0)


def analytic_marginal(sde):
    """Exact (mean, std) of x_{t_eps} for Gaussian data N(MU, S0²)."""
    return gaussian_marginal_moments(sde, MU, S0)


def _solve(sde, method, kw, seed=0):
    res = jax.jit(
        lambda k: sample(sde, analytic_score(sde), (BATCH, DIM), k,
                         method=method, denoise=False, **kw)
    )(jax.random.PRNGKey(seed))
    return res


def _moments(x):
    """Sample (mean, std) in fp64 host math from an fp32 upcast — a bf16
    state dtype must not leak bf16 reduction error into the gate."""
    xf = np.asarray(x, np.float32)
    return float(xf.mean()), float(xf.std())


# fp32 adaptive baselines, shared between the per-preset gate runs
# (same kw + seed ⇒ same result; no reason to re-solve per preset)
_FP32_ADAPTIVE = {}


def _fp32_adaptive(sde_name, sde, kw):
    if sde_name not in _FP32_ADAPTIVE:
        _FP32_ADAPTIVE[sde_name] = _solve(sde, "adaptive", kw)
    return _FP32_ADAPTIVE[sde_name]


# (solver, kwargs, W2 tolerance), derived from the shared zoo spec
# (DESIGN.md §11) so the case table, the selection report, and the zoo
# benchmark can never drift apart. PC-family samplers are
# variance-biased on coarse grids (the paper notes PC is "only
# heuristically motivated") — they get a loose gate; the bias is
# quantified in benchmarks/table1. DDIM is VP-only by construction.
CASES = zoo_cases()

#: carry-based zoo families that must also pass the trajectory rows
#: (plus pc_hmc, the MCMC-corrector family) — {vp,ve} × traj16x6
TRAJ_SOLVERS = ["adaptive", "momentum", "heun", "pc_hmc"]

#: adaptive-family solvers: per-sample step control, NFE-vs-EM claims
ADAPTIVE_FAMILY = ("adaptive", "momentum", "heun")


def test_every_registered_solver_has_a_conformance_case():
    """New solvers must register a zoo entry (which is the case table)."""
    assert set(available_solvers()) == set(CASES)


@pytest.mark.parametrize("sde_name,sde", [("vp", VPSDE()),
                                          ("ve", VESDE(sigma_max=10.0))])
@pytest.mark.parametrize("solver", sorted(CASES))
def test_solver_matches_analytic_marginal(solver, sde_name, sde):
    kw, tol = CASES[solver]
    if solver == "ddim" and sde_name != "vp":
        pytest.skip("DDIM is defined for VP only (has its own TypeError test)")
    res = _solve(sde, solver, kw)
    mu_a, s_a = analytic_marginal(sde)
    mu = float(res.x.mean())
    s = float(res.x.std())
    w2 = gaussian_w2(mu, s, mu_a, s_a)
    _ROWS.append({
        "solver": solver, "sde": sde_name, "precision": "fp32",
        "mean_err": abs(mu - mu_a), "std_err": abs(s - s_a), "w2": w2,
        "mean_nfe": float(res.mean_nfe), "tol": tol,
    })
    assert not bool(jnp.any(jnp.isnan(res.x)))
    assert w2 < tol, (solver, sde_name, mu, s, (mu_a, s_a))


@pytest.mark.parametrize("sde_name,sde", [("vp", VPSDE()),
                                          ("ve", VESDE(sigma_max=10.0))])
@pytest.mark.parametrize("preset", ["bf16", "bf16_full"])
def test_adaptive_precision_conformance(preset, sde_name, sde):
    """The precision-policy gate (DESIGN.md §8): under a bf16 policy the
    adaptive solver must stay inside a widened-but-bounded envelope of
    the fp32 run on the same tolerance — marginal-moment error ≤ 2× the
    fp32 W2 (plus the Monte-Carlo floor of the finite batch) and mean
    NFE ≤ 1.25× fp32. The step controller absorbing bf16 score noise
    without NFE blow-up is the whole premise of running the network
    reduced."""
    kw, _ = CASES["adaptive"]
    res32 = _fp32_adaptive(sde_name, sde, kw)
    resbf = _solve(sde, "adaptive", dict(kw, precision=preset))
    mu_a, s_a = analytic_marginal(sde)
    mu_32, s_32 = _moments(res32.x)
    mu_bf, s_bf = _moments(resbf.x)
    w2_32 = gaussian_w2(mu_32, s_32, mu_a, s_a)
    w2_bf = gaussian_w2(mu_bf, s_bf, mu_a, s_a)
    mc_floor = 3.0 * s_a / math.sqrt(BATCH * DIM)
    _ROWS.append({
        "solver": "adaptive", "sde": sde_name, "precision": preset,
        "mean_err": abs(mu_bf - mu_a),
        "std_err": abs(s_bf - s_a), "w2": w2_bf,
        "mean_nfe": float(resbf.mean_nfe), "tol": 2.0 * w2_32 + mc_floor,
    })
    assert not bool(jnp.any(jnp.isnan(resbf.x)))
    assert w2_bf <= 2.0 * w2_32 + mc_floor, (preset, w2_bf, w2_32)
    assert float(resbf.mean_nfe) <= 1.25 * float(res32.mean_nfe), (
        preset, float(resbf.mean_nfe), float(res32.mean_nfe),
    )


@pytest.mark.parametrize("sde_name,sde", [("vp", VPSDE()),
                                          ("ve", VESDE(sigma_max=10.0))])
def test_inpaint_conditioner_conformance(sde_name, sde):
    """The conditioning gate (DESIGN.md §9): an inpainting run on the
    analytic OU SDE must keep the *free* region on the unconditional
    marginal (independent coordinates ⇒ the conditional equals the
    marginal) within the adaptive solver's W2 tolerance, with observed
    coordinates pinned exactly at delivery and mean NFE ≤ 1.1× the
    unconditional solve — post-accept projection must not provoke the
    step controller into extra rejections."""
    from repro.core import inpaint
    from repro.core.analytic import gaussian_score as _gs

    kw, tol = CASES["adaptive"]
    res_u = _fp32_adaptive(sde_name, sde, kw)
    observed = MU + S0 * jax.random.normal(
        jax.random.PRNGKey(11), (BATCH, DIM))
    mask = jnp.zeros((BATCH, DIM)).at[:, : DIM // 2].set(1.0)
    conditioner, cond = inpaint(mask, observed)
    res = _solve(sde, "adaptive", dict(kw, conditioner=conditioner,
                                       cond=cond))
    x = np.asarray(res.x)
    np.testing.assert_array_equal(
        x[:, : DIM // 2], np.asarray(observed)[:, : DIM // 2])
    mu_a, s_a = analytic_marginal(sde)
    free = x[:, DIM // 2:]
    w2 = gaussian_w2(float(free.mean()), float(free.std()), mu_a, s_a)
    nfe_ratio = float(res.mean_nfe) / float(res_u.mean_nfe)
    _ROWS.append({
        "solver": "adaptive", "sde": sde_name, "precision": "fp32",
        "conditioner": "inpaint",
        "mean_err": abs(float(free.mean()) - mu_a),
        "std_err": abs(float(free.std()) - s_a), "w2": w2,
        "mean_nfe": float(res.mean_nfe), "tol": tol,
    })
    assert not bool(jnp.any(jnp.isnan(res.x)))
    assert w2 < tol, (sde_name, w2)
    assert nfe_ratio <= 1.1, (sde_name, nfe_ratio)


#: trajectory workload shape (horizon, transition) — DESIGN.md §10
TRAJ_H, TRAJ_D = 16, 6

# EM-1000 trajectory references, solved once per SDE and shared by every
# parametrized zoo row (same seed ⇒ same result)
_TRAJ_EM = {}


def _traj_em(sde_name, sde):
    if sde_name not in _TRAJ_EM:
        shape = (BATCH, TRAJ_H, TRAJ_D)
        res = jax.jit(
            lambda k: sample(sde, gaussian_score(sde, MU, S0), shape, k,
                             method="em", denoise=False, n_steps=1000)
        )(jax.random.PRNGKey(0))
        mu_a, s_a = analytic_marginal(sde)
        mu_e, s_e = _moments(res.x)
        _TRAJ_EM[sde_name] = res
        # give the trajectory workload its EM baseline row too, so the
        # selection report ranks the zoo against it on this modality
        _ROWS.append({
            "solver": "em", "sde": f"{sde_name}:traj{TRAJ_H}x{TRAJ_D}",
            "precision": "fp32",
            "mean_err": abs(mu_e - mu_a), "std_err": abs(s_e - s_a),
            "w2": gaussian_w2(mu_e, s_e, mu_a, s_a),
            "mean_nfe": float(res.mean_nfe), "tol": CASES["em"][1],
        })
    return _TRAJ_EM[sde_name]


@pytest.mark.parametrize("sde_name,sde", [("vp", VPSDE()),
                                          ("ve", VESDE(sigma_max=10.0))])
@pytest.mark.parametrize("solver", TRAJ_SOLVERS)
def test_trajectory_workload_conformance(solver, sde_name, sde):
    """The tuning-free-across-modalities gate (DESIGN.md §10/§11): on
    the analytic OU *trajectory* prior — (B, H, D) decision-diffuser
    shapes — every zoo family passes its own W2 gate at the same default
    tolerances as the image workload (no per-workload tuning), and the
    adaptive family does it at strictly lower NFE than EM-1000 at equal
    error."""
    kw, tol = CASES[solver]
    shape = (BATCH, TRAJ_H, TRAJ_D)
    score = gaussian_score(sde, MU, S0)

    res = jax.jit(
        lambda k: sample(sde, score, shape, k, method=solver,
                         denoise=False, **kw)
    )(jax.random.PRNGKey(0))
    res_em = _traj_em(sde_name, sde)
    mu_a, s_a = analytic_marginal(sde)
    mu, s = _moments(res.x)
    mu_e, s_e = _moments(res_em.x)
    w2 = gaussian_w2(mu, s, mu_a, s_a)
    w2_em = gaussian_w2(mu_e, s_e, mu_a, s_a)
    mc_floor = 3.0 * s_a / math.sqrt(BATCH * TRAJ_H * TRAJ_D)
    _ROWS.append({
        "solver": solver, "sde": f"{sde_name}:traj{TRAJ_H}x{TRAJ_D}",
        "precision": "fp32",
        "mean_err": abs(mu - mu_a), "std_err": abs(s - s_a), "w2": w2,
        "mean_nfe": float(res.mean_nfe), "tol": tol,
    })
    assert not bool(jnp.any(jnp.isnan(res.x)))
    # the image workload's gate, with the image workload's tolerances
    assert w2 < tol, (solver, sde_name, w2)
    if solver in ADAPTIVE_FAMILY:
        # equal error (up to the MC floor) at strictly lower NFE
        assert w2 <= w2_em + 2 * mc_floor + 0.02, (w2, w2_em)
        assert float(res.mean_nfe) < float(res_em.mean_nfe)


#: the paper's Table-1 ε sweep at the tier presets' points
#: (DESIGN.md §14): high_fidelity=0.01, standard=0.05, draft=0.5
EPS_SWEEP = [0.01, 0.05, 0.5]


@pytest.mark.parametrize("sde_name,sde", [("vp", VPSDE()),
                                          ("ve", VESDE(sigma_max=10.0))])
@pytest.mark.parametrize("workload", ["image", "traj"])
def test_tolerance_sweep_frontier(workload, sde_name, sde):
    """The tolerance-class frontier gate (DESIGN.md §14): sweeping the
    adaptive solver across the tier presets' ε points (the paper's
    Table-1 range), NFE must fall strictly with looser ε while W2 error
    is monotonically non-improving (up to the Monte-Carlo floor of the
    finite batch) — the quality/cost trade the draft / standard /
    high_fidelity tiers sell has to exist on every workload. Each sweep
    point publishes a summary row so CI's conformance table shows the
    frontier the serving tiers move along."""
    shape = (BATCH, DIM) if workload == "image" else (BATCH, TRAJ_H, TRAJ_D)
    sde_tag = (sde_name if workload == "image"
               else f"{sde_name}:traj{TRAJ_H}x{TRAJ_D}")
    score = gaussian_score(sde, MU, S0)
    mu_a, s_a = analytic_marginal(sde)
    mc_floor = 3.0 * s_a / math.sqrt(int(np.prod(shape)))
    nfes, w2s = [], []
    for eps in EPS_SWEEP:
        res = jax.jit(
            lambda k, e=eps: sample(sde, score, shape, k, method="adaptive",
                                    denoise=False, eps_rel=e)
        )(jax.random.PRNGKey(0))
        mu, s = _moments(res.x)
        w2 = gaussian_w2(mu, s, mu_a, s_a)
        _ROWS.append({
            "solver": f"adaptive-eps{eps}", "sde": sde_tag,
            "precision": "fp32",
            "mean_err": abs(mu - mu_a), "std_err": abs(s - s_a), "w2": w2,
            "mean_nfe": float(res.mean_nfe), "tol": eps,
        })
        assert not bool(jnp.any(jnp.isnan(res.x)))
        nfes.append(float(res.mean_nfe))
        w2s.append(w2)
    # cost falls strictly with looser ε …
    assert nfes[0] > nfes[1] > nfes[2], (sde_name, workload, nfes)
    # … while quality never *improves* beyond measurement resolution: on
    # the analytic OU problem every sweep point sits at the finite-batch
    # Monte-Carlo floor, so "non-improving" is asserted up to 2× that
    # floor (the deterministic half of the frontier is the NFE gate)
    for lo, hi in zip(w2s, w2s[1:]):
        assert hi >= lo - 2 * mc_floor, (sde_name, workload, w2s, mc_floor)


def test_adaptive_nfe_below_em_at_equal_error():
    """Paper headline as a regression gate: at EM-1000's error level the
    adaptive solver spends a fraction of the NFE."""
    sde = VPSDE()
    mu_a, s_a = analytic_marginal(sde)
    res_em = _solve(sde, "em", dict(n_steps=1000))
    res_ad = _solve(sde, "adaptive", dict(eps_rel=0.05))
    w2_em = gaussian_w2(float(res_em.x.mean()), float(res_em.x.std()), mu_a, s_a)
    w2_ad = gaussian_w2(float(res_ad.x.mean()), float(res_ad.x.std()), mu_a, s_a)
    # equal error up to the Monte-Carlo floor of 1024 samples
    mc_floor = 3.0 * s_a / math.sqrt(BATCH * DIM)
    assert w2_ad <= w2_em + 2 * mc_floor + 0.02, (w2_ad, w2_em)
    assert float(res_ad.mean_nfe) < 0.5 * float(res_em.mean_nfe)
    _ROWS.append({
        "solver": "adaptive-vs-em1000", "sde": "vp", "precision": "fp32",
        "mean_err": abs(float(res_ad.x.mean()) - mu_a),
        "std_err": abs(float(res_ad.x.std()) - s_a),
        "w2": w2_ad,
        "mean_nfe": float(res_ad.mean_nfe),
        "tol": float(res_em.mean_nfe),
    })


# ---------------------------------------------------------------------------
# registry-vs-summary completeness + auto-selection (DESIGN.md §11) —
# defined last so pytest's in-file ordering runs them after every
# row-producing test above has appended to _ROWS
# ---------------------------------------------------------------------------


def test_summary_rows_cover_every_registered_solver():
    """The latent gap ISSUE-6 closes: ``summary.{md,json}`` must cover
    every solver in ``available_solvers()`` — a registered solver whose
    conformance rows silently vanish (e.g. a skip that outlives its
    reason) would otherwise pass CI with no gate at all. Mirrors the
    bench registry audit from the PR-5 cycle."""
    if not _ROWS:
        pytest.skip("no conformance rows collected (partial test run)")
    covered = {r["solver"] for r in _ROWS}
    missing = set(available_solvers()) - covered
    assert not missing, (
        f"registered solvers with no conformance summary row: "
        f"{sorted(missing)}"
    )


def test_selection_winner_reproduces_or_beats_adaptive():
    """The auto-selection acceptance gate: on every workload the report
    must produce a winner, and that winner's NFE must reproduce or beat
    the adaptive solver's (adaptive itself passes its gate, so a winner
    costing more NFE than adaptive would be a selection bug)."""
    if not _ROWS:
        pytest.skip("no conformance rows collected (partial test run)")
    report = select(_ROWS)
    assert report, "selection report is empty"
    for workload, data in report.items():
        assert data["winner"] is not None, (workload, data["ranking"])
        if data["adaptive_nfe"] is not None:
            assert data["winner_nfe"] <= data["adaptive_nfe"], (
                workload, data["winner"], data["winner_nfe"],
                data["adaptive_nfe"],
            )
