"""Solver correctness against analytically solvable reverse diffusions.

For Gaussian data N(mu, s0²) the exact time-t score is available in
closed form, so every solver must transport the prior back to the data
distribution. This validates the full solver stack end to end without a
neural network in the loop.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    AdaptiveConfig,
    VESDE,
    VPSDE,
    adaptive_forward,
    ForwardAdaptiveConfig,
    sample,
)

from repro.core.analytic import gaussian_score as _gaussian_score

MU, S0 = 0.3, 0.5


def gaussian_score(sde):
    return _gaussian_score(sde, MU, S0)


# (solver, kwargs, std_tolerance). PC's ancestral predictor + finite-step
# Langevin are variance-biased on coarse grids (each step inflates Var by
# O(Δ²/v) — the paper notes PC is "only heuristically motivated"); it gets
# a loose std gate and the bias is quantified in benchmarks/table1.
SOLVERS = [
    ("em", dict(n_steps=200), 0.06),
    ("adaptive", dict(eps_rel=0.05), 0.06),
    ("momentum", dict(eps_rel=0.05), 0.06),
    ("heun", dict(eps_rel=0.05), 0.06),
    ("pc", dict(n_steps=100), 0.20),
    ("pc_hmc", dict(n_steps=100), 0.20),
    ("ode", {}, 0.06),
]


@pytest.mark.parametrize("sde", [VPSDE(), VESDE(sigma_max=10.0)],
                         ids=["vp", "ve"])
@pytest.mark.parametrize("method,kw,std_tol", SOLVERS,
                         ids=[s for s, _, _ in SOLVERS])
def test_solver_recovers_gaussian(sde, method, kw, std_tol, rng):
    res = jax.jit(
        lambda k: sample(sde, gaussian_score(sde), (1024, 8), k,
                         method=method, **kw)
    )(rng)
    x = res.x
    assert not bool(jnp.any(jnp.isnan(x)))
    assert float(x.mean()) == pytest.approx(MU, abs=0.06)
    assert float(x.std()) == pytest.approx(S0, abs=std_tol)


def test_ddim_vp_only(rng):
    sde = VPSDE()
    res = jax.jit(
        lambda k: sample(sde, gaussian_score(sde), (1024, 8), k,
                         method="ddim", n_steps=50)
    )(rng)
    assert float(res.x.mean()) == pytest.approx(MU, abs=0.06)
    assert float(res.x.std()) == pytest.approx(S0, abs=0.08)
    with pytest.raises(TypeError):
        sample(VESDE(), gaussian_score(VESDE()), (8, 2), rng, method="ddim")


def test_adaptive_faster_than_em_at_equal_quality(rng):
    """The paper's headline: adaptive needs far fewer NFE than the
    EM baseline (1000 steps) at comparable quality."""
    sde = VPSDE()
    score = gaussian_score(sde)
    res_em = jax.jit(
        lambda k: sample(sde, score, (512, 8), k, method="em", n_steps=1000)
    )(rng)
    res_ad = jax.jit(
        lambda k: sample(sde, score, (512, 8), k, method="adaptive",
                         eps_rel=0.05)
    )(rng)
    # quality parity (moments within tolerance of each other)
    assert float(res_ad.x.mean()) == pytest.approx(float(res_em.x.mean()), abs=0.05)
    assert float(res_ad.x.std()) == pytest.approx(float(res_em.x.std()), abs=0.05)
    # ≥2× fewer score evaluations (paper reports 2–10×)
    assert float(res_ad.mean_nfe) < 0.5 * float(res_em.mean_nfe)


def test_adaptive_nfe_decreases_with_tolerance(rng):
    sde = VPSDE()
    score = gaussian_score(sde)
    nfes = []
    for eps in (0.01, 0.05, 0.2):
        res = jax.jit(
            lambda k: sample(sde, score, (128, 8), k, method="adaptive",
                             eps_rel=eps)
        )(rng)
        nfes.append(float(res.mean_nfe))
    assert nfes[0] > nfes[1] > nfes[2]


def test_adaptive_per_sample_step_sizes(rng):
    """Samples in one batch finish with different NFE — per-sample h."""
    sde = VESDE(sigma_max=10.0)
    res = jax.jit(
        lambda k: sample(sde, gaussian_score(sde), (64, 8), k,
                         method="adaptive", eps_rel=0.05)
    )(rng)
    assert int(res.accepted.min()) < int(res.accepted.max())


def test_forward_adaptive_ou_process(rng):
    """Algorithm 2 on the linear test SDE dx = λx dt + σ dw (paper App. F):
    stationary distribution N(0, σ²/(2|λ|))."""
    lam, sigma = -1.0, 0.8

    res = adaptive_forward(
        drift_fn=lambda x, t: lam * x,
        diffusion_fn=lambda x, t: jnp.full_like(x, sigma),
        x0=jnp.zeros((1024, 1)),
        t_begin=0.0,
        t_end=4.0,  # ≫ relaxation time 1/|λ| (e^-4 ≈ 2% residual)
        key=rng,
        config=ForwardAdaptiveConfig(eps_abs=1e-2, eps_rel=0.05),
    )
    want_std = sigma / (2.0 * abs(lam)) ** 0.5
    assert float(res.x.mean()) == pytest.approx(0.0, abs=0.05)
    assert float(res.x.std()) == pytest.approx(want_std, rel=0.08)


def test_forward_adaptive_state_dependent_diffusion(rng):
    """Geometric-Brownian-like SDE with g(x,t) = 0.2·|x| exercises the
    Itô s=±1 correction; moments follow the exact GBM solution."""
    mu, sig = 0.05, 0.2
    res = adaptive_forward(
        drift_fn=lambda x, t: mu * x,
        diffusion_fn=lambda x, t: sig * x,
        x0=jnp.ones((4096, 1)),
        t_begin=0.0,
        t_end=1.0,
        key=rng,
        config=ForwardAdaptiveConfig(eps_abs=1e-3, eps_rel=0.01),
    )
    # E[x_T] = e^{μT}
    assert float(res.x.mean()) == pytest.approx(jnp.exp(mu), rel=0.02)
    # Var[x_T] = e^{2μT}(e^{σ²T} − 1)
    want_var = float(jnp.exp(2 * mu) * (jnp.exp(sig**2) - 1.0))
    assert float(res.x.var()) == pytest.approx(want_var, rel=0.25)


def test_extrapolation_is_second_order(rng):
    """The stochastic-Improved-Euler extrapolation (x'' = ½(x'+x̃)) must be
    2nd order: on deterministic drift (g=0), achieved error vs. the exact
    solution scales ≈ NFE⁻², i.e. tightening ε by 100× costs ≈10× NFE.
    (Plain EM would need 100×.) Exercises the real Algorithm-2 code path."""
    lam = -2.0
    errs, nfes = [], []
    for eps in (1e-2, 1e-4):
        res = adaptive_forward(
            drift_fn=lambda x, t: lam * x,
            diffusion_fn=lambda x, t: jnp.zeros_like(x),
            x0=jnp.ones((4, 1)),
            t_begin=0.0,
            t_end=1.0,
            key=rng,
            config=ForwardAdaptiveConfig(eps_abs=eps, eps_rel=eps,
                                         h_init=1e-3),
        )
        exact = float(jnp.exp(lam))
        errs.append(abs(float(res.x.mean()) - exact))
        nfes.append(float(res.mean_nfe))
    # order p satisfies err ∝ NFE^{-p}; demand p ≥ 1.5 (EM gives p ≈ 1)
    import math

    p = math.log(errs[0] / max(errs[1], 1e-12)) / math.log(nfes[1] / nfes[0])
    assert p > 1.5, (errs, nfes, p)


def test_no_extrapolation_matches_em_proposal(rng):
    """With extrapolate=False the accepted proposal is the plain EM step
    (paper App. B 'No Extrapolation ⇒ Euler–Maruyama'): both variants must
    converge to the target; the ablation benchmark quantifies quality."""
    sde = VPSDE()
    score = gaussian_score(sde)
    cfg = AdaptiveConfig(eps_rel=0.05, extrapolate=False)
    res = jax.jit(
        lambda k: sample(sde, score, (1024, 8), k, method="adaptive",
                         config=cfg)
    )(rng)
    assert float(res.x.mean()) == pytest.approx(MU, abs=0.06)
    assert float(res.x.std()) == pytest.approx(S0, abs=0.08)
