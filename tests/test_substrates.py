"""Optimizer / EMA / schedules / checkpoint / data-pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.images import GMM2D, GMMImageConfig, sample_images
from repro.data.tokens import TokenPipelineConfig, lm_loss, synth_batch
from repro.optim import (
    AdamW, ema_init, ema_update, global_norm, warmup_cosine,
)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_minimizes_quadratic(rng):
    target = jax.random.normal(rng, (16,))
    params = {"w": jnp.zeros((16,))}
    opt = AdamW(lr=0.1, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(grads, state, params)

    for _ in range(200):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping_bounds_update(rng):
    params = {"w": jnp.zeros((4,))}
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    p2, _ = opt.update(huge, state, params)
    # first-step Adam update magnitude ≈ lr regardless, but moments were fed
    # the clipped gradient — verify the clipped norm directly:
    assert float(global_norm(jax.tree.map(
        lambda g: g * jnp.minimum(1.0, 1e-3 / global_norm(huge)), huge
    ))) <= 1e-3 * 1.01
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
    # monotone decay after warmup
    vals = [float(sched(jnp.asarray(s))) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_ema_converges_to_constant(rng):
    p = {"w": jnp.zeros((4,))}
    ema = ema_init(p)
    target = {"w": jnp.ones((4,))}
    for _ in range(2000):
        ema = ema_update(ema, target, decay=0.99)
    np.testing.assert_allclose(np.asarray(ema["w"]), 1.0, atol=1e-5)


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "a": jax.random.normal(rng, (3, 4)),
        "nested": {"b": jnp.arange(5), "c": [jnp.ones(2), jnp.zeros(3)]},
    }
    save_checkpoint(str(tmp_path), 7, tree, metadata={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_raises(tmp_path, rng):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"b": jnp.ones(2)})


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

def test_token_stream_deterministic_and_shaped():
    cfg = TokenPipelineConfig(vocab_size=100, seq_len=32, global_batch=4)
    b1 = synth_batch(cfg, 3)
    b2 = synth_batch(cfg, 3)
    b3 = synth_batch(cfg, 4)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert bool(jnp.any(b1 != b3))
    assert b1.shape == (4, 32) and b1.dtype == jnp.int32
    assert int(b1.min()) >= 0 and int(b1.max()) < 100


def test_token_stream_zipfian():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=4096, global_batch=8)
    b = np.asarray(synth_batch(cfg, 0)).ravel()
    # low ids should dominate high ids by a wide margin
    low = np.mean(b < 50)
    high = np.mean(b >= 500)
    assert low > 5 * high


def test_codebook_stream_shape():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2,
                              num_codebooks=4)
    b = synth_batch(cfg, 0)
    assert b.shape == (2, 16, 4)


def test_lm_loss_at_uniform():
    V = 32
    logits = jnp.zeros((2, 10, V))
    toks = jnp.zeros((2, 10), jnp.int32)
    assert float(lm_loss(logits, toks)) == pytest.approx(float(jnp.log(V)), rel=1e-5)


def test_gmm_images_in_range(rng):
    cfg = GMMImageConfig(image_size=16)
    x = sample_images(cfg, rng, 64)
    assert x.shape == (64, 16, 16, 3)
    assert float(x.min()) >= -1.0 and float(x.max()) <= 1.0


def test_gmm2d_score_matches_autodiff(rng):
    """Closed-form mixture score vs autodiff of the exact log-density."""
    from repro.core import VPSDE

    gmm = GMM2D()
    sde = VPSDE()
    score_fn = gmm.score_at_time(sde)
    x = jax.random.normal(rng, (16, 2)) * 2.0
    t = jnp.linspace(0.05, 0.95, 16)

    means = jnp.asarray(gmm.means)
    w = jnp.asarray(gmm.weights)

    def logp(xi, ti):
        m, s = sde.marginal(ti)
        var = (m * gmm.std) ** 2 + s**2
        comp = -0.5 * jnp.sum((xi - m * means) ** 2, -1) / var - jnp.log(var)
        return jax.scipy.special.logsumexp(comp + jnp.log(w))

    want = jax.vmap(jax.grad(logp))(x, t)
    got = score_fn(x, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
