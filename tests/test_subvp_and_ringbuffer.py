"""Extra coverage: sub-VP process through the solver stack, and
hypothesis property tests on the ring-buffer KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SubVPSDE, sample
from repro.models.kvcache import cache_write, init_kv_cache, valid_mask

settings.register_profile("ci2", deadline=None, max_examples=25)
settings.load_profile("ci2")


# --------------------------------------------------------------------------
# sub-VP
# --------------------------------------------------------------------------

def test_subvp_solvers_recover_gaussian(rng):
    sde = SubVPSDE()
    mu, s0 = 0.2, 0.4

    def score(x, t):
        m, std = sde.marginal(t)
        m, std = m[:, None], std[:, None]
        return -(x - m * mu) / (m * m * s0 * s0 + std * std)

    for method, kw in [("em", dict(n_steps=300)),
                       ("adaptive", dict(eps_rel=0.05))]:
        res = jax.jit(lambda k: sample(sde, score, (1024, 8), k,
                                       method=method, **kw))(rng)
        assert float(res.x.mean()) == pytest.approx(mu, abs=0.06), method
        assert float(res.x.std()) == pytest.approx(s0, abs=0.06), method


def test_subvp_diffusion_smaller_than_vp():
    """sub-VP: g²(t) = β(t)(1−e^{−2∫β}) ≤ β(t) = g²_VP(t)."""
    from repro.core import VPSDE

    sub, vp = SubVPSDE(), VPSDE()
    for t in (0.1, 0.5, 0.9):
        assert float(sub.diffusion(jnp.asarray(t))) <= \
            float(vp.diffusion(jnp.asarray(t))) + 1e-6


# --------------------------------------------------------------------------
# ring-buffer cache properties
# --------------------------------------------------------------------------

@given(st.integers(1, 24), st.integers(2, 8), st.integers(0, 6))
def test_ring_buffer_holds_most_recent(n_writes, cache_len, window_off):
    """After n writes into a length-L ring, the valid slots are exactly
    the most recent min(n, L, window) positions."""
    cache = init_kv_cache(1, cache_len, 1, 4, jnp.float32)
    for i in range(n_writes):
        kv = jnp.full((1, 1, 1, 4), float(i))
        cache = cache_write(cache, kv, kv)
    window = window_off + 1
    m = np.asarray(valid_mask(cache, window))
    visible_positions = sorted(
        int(p) for p, ok in zip(np.asarray(cache.pos), m) if ok and p >= 0
    )
    want_lo = max(n_writes - min(window, cache_len, n_writes), 0)
    assert visible_positions == list(range(want_lo, n_writes))


@given(st.integers(1, 20), st.integers(2, 8))
def test_ring_buffer_slot_contents(n_writes, cache_len):
    """The slot holding position p must contain the value written at p."""
    cache = init_kv_cache(1, cache_len, 1, 4, jnp.float32)
    for i in range(n_writes):
        kv = jnp.full((1, 1, 1, 4), float(i))
        cache = cache_write(cache, kv, kv)
    pos = np.asarray(cache.pos)
    k = np.asarray(cache.k)[0, :, 0, 0]
    for slot, p in enumerate(pos):
        if p >= 0:
            assert k[slot] == float(p), (slot, p, k)


@given(st.integers(2, 12), st.integers(0, 10))
def test_start_pos_mask_excludes_history(cache_len, start):
    """Continuous-batching isolation: no position < start_pos is ever
    visible, regardless of ring state."""
    cache = init_kv_cache(2, cache_len, 1, 4, jnp.float32)
    for i in range(cache_len + 3):
        kv = jnp.ones((2, 1, 1, 4))
        cache = cache_write(cache, kv, kv)
    sp = jnp.asarray([0, start], jnp.int32)
    m = np.asarray(valid_mask(cache, None, sp))  # (2, L)
    pos = np.asarray(cache.pos)
    for slot in range(cache_len):
        if pos[slot] >= 0 and pos[slot] < start:
            assert not m[1, slot]
        # lane 0 (start 0) sees everything valid
    assert m[0].sum() >= m[1].sum()
