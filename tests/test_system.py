"""System-level behaviour: the public API wires together end-to-end."""

import jax
import jax.numpy as jnp

from repro.core import (
    VESDE, VPSDE, available_solvers, get_sde, sample,
)


def test_solver_registry_complete():
    have = set(available_solvers())
    assert {"adaptive", "em", "pc", "ode", "ddim",
            "momentum", "heun", "pc_hmc"} <= have


def test_sde_factory():
    assert isinstance(get_sde("ve"), VESDE)
    assert isinstance(get_sde("vp"), VPSDE)


def test_arch_registry_complete():
    from repro.configs import ARCH_IDS

    want = {
        "olmo-1b", "qwen1.5-0.5b", "qwen3-14b", "jamba-v0.1-52b",
        "llama-3.2-vision-90b", "granite-moe-3b-a800m", "gemma3-12b",
        "mamba2-2.7b", "deepseek-moe-16b", "musicgen-medium",
    }
    assert set(ARCH_IDS) == want


def test_shape_policy():
    from repro.configs import apply_shape_policy, get_config, get_shape

    # pure full-attention arch gets the SWA override on long_500k only
    olmo = get_config("olmo-1b")
    long = get_shape("long_500k")
    assert apply_shape_policy(olmo, long).mixer_pattern == ("L",)
    assert apply_shape_policy(olmo, get_shape("train_4k")).mixer_pattern == ("A",)
    # natively sub-quadratic archs unchanged
    mamba = get_config("mamba2-2.7b")
    assert apply_shape_policy(mamba, long).mixer_pattern == ("M",)
    gemma = get_config("gemma3-12b")
    assert apply_shape_policy(gemma, long) == gemma


def test_sampling_is_deterministic_given_key(rng):
    sde = VPSDE()

    def score(x, t):
        m, s = sde.marginal(t)
        return -(x - m[:, None] * 0.1) / (m[:, None] ** 2 * 0.04 + s[:, None] ** 2)

    r1 = sample(sde, score, (8, 4), rng, method="adaptive", eps_rel=0.05)
    r2 = sample(sde, score, (8, 4), rng, method="adaptive", eps_rel=0.05)
    assert bool(jnp.all(r1.x == r2.x))
    assert bool(jnp.all(r1.nfe == r2.nfe))
