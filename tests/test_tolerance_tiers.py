"""Mixed-tier serving conformance (DESIGN.md §14).

The tentpole contract: per-slot tolerances travel through the serving
stack exactly like condition payloads, so a wave mixing draft /
standard / high_fidelity requests delivers every sample *bit-identical*
to a solo ``adaptive()`` run at that request's own tolerance — across
sync horizons, compaction on/off, and the device-resident event
program — with exact per-request NFE. Plus the no-retrace discipline:
admitting a different tolerance class is a carry *value* change, never
a new trace of the solve step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.diffusion import TOLERANCE_CLASSES, ToleranceClass
from repro.core import AdaptiveConfig, VPSDE
from repro.core.analytic import gaussian_noise_pred
from repro.core.solvers.adaptive import adaptive
from repro.launch.sample import make_sample_step
from repro.models.dit import DiTConfig
from repro.serving.diffusion_server import DiffusionBatcher, ImageRequest

MU, S0 = 0.3, 0.5
D = 32

#: one wave mixing every preset plus tier-less (default-class) requests
WAVE = ["draft", "high_fidelity", None, "standard", "draft", None,
        "high_fidelity", "draft", "standard", None]


@pytest.fixture(scope="module")
def server_parts():
    sde = VPSDE()
    cfg = AdaptiveConfig(eps_rel=0.05)
    net = DiTConfig(image_size=4, patch=4, d_model=8, num_layers=1,
                    num_heads=1, d_ff=8)  # unused shapes; signature holder
    step = make_sample_step(net, sde, cfg,
                            forward_fn=gaussian_noise_pred(sde, MU, S0))
    return sde, cfg, step


def _score_fn(sde):
    """The exact score math make_sample_step builds from the noise-pred
    forward_fn — same ops, same casts, so solo solves are bit-comparable
    to served ones."""
    fwd = gaussian_noise_pred(sde, MU, S0)

    def score(x, t):
        _, std = sde.marginal(t)
        out = fwd(None, x, t).astype(jnp.float32)
        return -out / std.reshape((-1,) + (1,) * (x.ndim - 1))

    return score


def _request_eps(sde, cfg, tier):
    """(atol, rtol) a request of ``tier`` must solve at — the server's
    resolution rule (tier eps, defaults from sde/config)."""
    default_atol = float(
        sde.abs_tolerance if cfg.eps_abs is None else cfg.eps_abs
    )
    if tier is None:
        return default_atol, float(cfg.eps_rel)
    t = TOLERANCE_CLASSES[tier]
    return (default_atol if t.eps_abs is None else float(t.eps_abs),
            float(t.eps_rel))


def _solo_reference(sde, cfg, seed, tier):
    """Solo batch-1 ``adaptive()`` at the request's own tolerance, under
    the server's admission key discipline (PRNGKey(seed) split into
    prior/noise keys)."""
    k_prior, k_noise = jax.random.split(jax.random.PRNGKey(seed))
    x0 = sde.prior_sample(k_prior, (D,))[None]
    atol, rtol = _request_eps(sde, cfg, tier)
    res = adaptive(sde, _score_fn(sde), x0, k_noise[None], config=cfg,
                   denoise=False, atol=atol, rtol=rtol)
    return np.asarray(res.x[0]), int(np.asarray(res.nfe)[0])


def _serve_wave(sde, cfg, step, **kw):
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, tolerance_classes=True, **kw)
    for uid, tier in enumerate(WAVE):
        b.submit(ImageRequest(uid=uid, seed=1000 + uid, tier=tier))
    done = b.run_to_completion()
    assert len(done) == len(WAVE)
    return b, done


@pytest.mark.parametrize("kw", [
    dict(sync_horizon=1),
    dict(sync_horizon=8),
    dict(sync_horizon=8, compaction=False),
    dict(sync_horizon=4, device_resident=True),
], ids=["h1", "h8", "h8-nocompact", "device-resident"])
def test_mixed_wave_bit_identical_to_solo_at_own_tolerance(
        server_parts, kw):
    """Every request in a mixed-tier wave delivers the exact sample (and
    NFE) a solo adaptive() run at that request's tolerance produces:
    per-slot tolerances ride compaction permutations, sync horizons, and
    the device-resident event program without perturbing any
    trajectory."""
    sde, cfg, step = server_parts
    _, done = _serve_wave(sde, cfg, step, **kw)
    for uid, tier in enumerate(WAVE):
        x_ref, nfe_ref = _solo_reference(sde, cfg, 1000 + uid, tier)
        np.testing.assert_array_equal(
            np.asarray(done[uid].result), x_ref,
            err_msg=f"uid={uid} tier={tier} kw={kw}")
        assert done[uid].nfe == nfe_ref, (uid, tier, done[uid].nfe, nfe_ref)


def test_mixed_wave_nfe_ordering_and_class_stats(server_parts):
    """Draft requests must come in far cheaper than high-fidelity ones
    in the same batch (the paper's ε frontier, served), and the per-class
    accounting at the _d2h seam must agree exactly with the per-request
    NFE the requests themselves report."""
    sde, cfg, step = server_parts
    b, done = _serve_wave(sde, cfg, step, sync_horizon=4)
    by_tier = {}
    for uid, tier in enumerate(WAVE):
        by_tier.setdefault(tier or "default", []).append(done[uid].nfe)
    mean = {k: sum(v) / len(v) for k, v in by_tier.items()}
    assert mean["draft"] <= 0.5 * mean["high_fidelity"], mean
    assert mean["draft"] <= mean["standard"] <= mean["high_fidelity"], mean
    stats = b.class_stats
    for name, nfes in by_tier.items():
        assert stats[name]["delivered"] == len(nfes)
        assert stats[name]["mean_nfe"] == pytest.approx(
            sum(nfes) / len(nfes))


def test_tiered_default_class_bitwise_matches_untiered_server(
        server_parts):
    """Acceptance criterion: a tiered server fed only tier-less requests
    is bitwise identical to the pre-tier (untiered) server — on the
    host-driven and device-resident paths. The per-slot tolerance vector
    holds the static config's values, and an fp32 broadcast multiply by
    an equal-valued vector is the same bits as the scalar constant."""
    sde, cfg, step = server_parts

    def run(tiered, **kw):
        b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                             slots=4, cfg=cfg,
                             tolerance_classes=(True if tiered else None),
                             **kw)
        for uid in range(8):
            b.submit(ImageRequest(uid=uid, seed=uid))
        done = b.run_to_completion()
        return {u: (done[u].nfe, np.asarray(done[u].result))
                for u in done}

    for kw in (dict(sync_horizon=4), dict(sync_horizon=4,
                                          device_resident=True)):
        base, tier = run(False, **kw), run(True, **kw)
        assert base.keys() == tier.keys()
        for u in base:
            assert base[u][0] == tier[u][0], (u, kw)
            np.testing.assert_array_equal(base[u][1], tier[u][1],
                                          err_msg=f"uid={u} kw={kw}")


def test_tier_change_does_not_retrace_solve_step(server_parts):
    """No-retrace discipline (PR-7 / DESIGN.md §14): tolerance classes
    are carry *data* — serving waves of different tiers reuses the one
    compiled solve step (and, device-resident, the one driver + event
    program). A retrace per tier would recompile the score network."""
    sde, cfg, step = server_parts

    def drain(b, tiers, seed0):
        for uid, tier in enumerate(tiers):
            b.submit(ImageRequest(uid=seed0 + uid, seed=seed0 + uid,
                                  tier=tier))
        b.run_to_completion()

    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=4, cfg=cfg, tolerance_classes=True,
                         sync_horizon=4)
    drain(b, ["draft"] * 4, 0)
    n_after_first = b.step_fn._cache_size()
    drain(b, ["high_fidelity"] * 4, 100)
    drain(b, ["standard", "draft", None, "high_fidelity"], 200)
    assert b.step_fn._cache_size() == n_after_first == 1

    bd = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                          slots=4, cfg=cfg, tolerance_classes=True,
                          sync_horizon=4, device_resident=True)
    drain(bd, ["draft"] * 4, 0)
    drain(bd, ["high_fidelity", "standard", None, "draft"], 100)
    assert bd._driver_fn._cache_size() == 1
    assert bd._event_fn._cache_size() == 1


def test_custom_tolerance_class_and_bad_tier_rejected(server_parts):
    """A server-local registry (custom ToleranceClass dict) resolves its
    own names and rejects unknown ones; untiered servers refuse tiered
    requests instead of silently ignoring the class."""
    sde, cfg, step = server_parts
    custom = ToleranceClass("bulk", eps_rel=0.3, priority=2)
    b = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                         slots=2, cfg=cfg,
                         tolerance_classes={"bulk": custom})
    b.submit(ImageRequest(uid=0, seed=0, tier="bulk"))
    with pytest.raises(KeyError):
        b.submit(ImageRequest(uid=1, seed=1, tier="draft"))
    done = b.run_to_completion()
    x_ref, nfe_ref = None, None
    k_prior, k_noise = jax.random.split(jax.random.PRNGKey(0))
    x0 = sde.prior_sample(k_prior, (D,))[None]
    res = adaptive(sde, _score_fn(sde), x0, k_noise[None], config=cfg,
                   denoise=False,
                   atol=float(sde.abs_tolerance), rtol=0.3)
    np.testing.assert_array_equal(np.asarray(done[0].result),
                                  np.asarray(res.x[0]))
    assert done[0].nfe == int(np.asarray(res.nfe)[0])

    b_plain = DiffusionBatcher(sde, step, params=None, sample_shape=(D,),
                               slots=2, cfg=cfg)
    with pytest.raises(ValueError):
        b_plain.submit(ImageRequest(uid=0, seed=0, tier="draft"))
