"""docs-lint: keep code↔docs citations and doc links resolvable.

Three checks (DESIGN.md §9 introduced the citation discipline this
enforces; CI runs this as the fast ``docs-lint`` job):

  1. every ``DESIGN.md §N`` citation in ``src/``, ``tests/``,
     ``benchmarks/``, and ``examples/`` names a section that actually
     exists as a ``## §N`` header in ``docs/DESIGN.md``;
  2. every relative markdown link in ``README.md`` and
     ``docs/DESIGN.md`` points at a file or directory that exists
     (anchors and external http(s)/mailto links are skipped);
  3. the inverse of (1): every ``## §N`` section in DESIGN.md is cited
     at least once from the code dirs — a design section nothing
     references is either dead doc or missing its code anchors.

Pure stdlib; exits non-zero with a per-finding report.

  python tools/docs_lint.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DESIGN = ROOT / "docs" / "DESIGN.md"
CODE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
MD_FILES = ("README.md", "docs/DESIGN.md")

SECTION_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)
CITATION_RE = re.compile(r"DESIGN\.md\s+§(\d+)")
# [text](target) — skip images' inner part handled the same way;
# external schemes and pure anchors are filtered below
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def design_sections() -> set:
    return set(SECTION_RE.findall(DESIGN.read_text(encoding="utf-8")))


def check_citations() -> list:
    """Every DESIGN.md §N cited from code resolves to a real section."""
    sections = design_sections()
    errors = []
    for d in CODE_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            text = path.read_text(encoding="utf-8")
            for lineno, line in enumerate(text.splitlines(), 1):
                for n in CITATION_RE.findall(line):
                    if n not in sections:
                        errors.append(
                            f"{path.relative_to(ROOT)}:{lineno}: cites "
                            f"DESIGN.md §{n} but DESIGN.md has no '## §{n}' "
                            f"header (have §{', §'.join(sorted(sections))})"
                        )
    return errors


def check_links() -> list:
    """Relative links in the doc layer point at existing paths."""
    errors = []
    for rel in MD_FILES:
        path = ROOT / rel
        text = path.read_text(encoding="utf-8")
        for lineno, line in enumerate(text.splitlines(), 1):
            for target in LINK_RE.findall(line):
                if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:
                    continue
                if target.startswith("#"):  # in-page anchor
                    continue
                candidate = (path.parent / target.split("#", 1)[0]).resolve()
                if not candidate.exists():
                    try:
                        shown = candidate.relative_to(ROOT)
                    except ValueError:  # resolves outside the repo root
                        shown = candidate
                    errors.append(
                        f"{rel}:{lineno}: link target '{target}' does not "
                        f"exist (resolved {shown})"
                    )
    return errors


def check_section_coverage() -> list:
    """Every ``## §N`` section in DESIGN.md is cited ≥ 1× from code."""
    cited = set()
    for d in CODE_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            cited |= set(
                CITATION_RE.findall(path.read_text(encoding="utf-8"))
            )
    return [
        f"docs/DESIGN.md: section '## §{n}' is never cited from "
        f"{'/'.join(CODE_DIRS)} — dead doc, or code missing its "
        f"'DESIGN.md §{n}' anchors"
        for n in sorted(design_sections() - cited, key=int)
    ]


def main() -> int:
    errors = check_citations() + check_links() + check_section_coverage()
    for e in errors:
        print(f"docs-lint: {e}", file=sys.stderr)
    if errors:
        print(f"docs-lint: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    print("docs-lint: all DESIGN.md §-citations and doc links resolve; "
          "every section is cited")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
